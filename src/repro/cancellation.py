"""Time-sliced cooperative cancellation for pure-compute loops.

Cooperative cancel is normally checked at host-interface calls (chain /
await / state pull-push).  A long pure-compute loop — e.g. a decode loop
dispatching jitted kernels for seconds — has no such checkpoint, so a
cancelled speculative twin used to run to completion in an executor slot.

This module closes that gap without making kernel dispatch pay a per-call
price: the runtime installs a per-thread cancel check around each function
execution, and the kernel dispatch wrappers call :func:`checkpoint` — a
thread-local read plus one ``time.monotonic`` compare.  The installed check
only actually runs once per ``slice_s`` of elapsed time, so cancellation is
honoured within a bounded slice while the steady-state cost stays at ~100ns
per dispatch.

Lives at the package root — outside ``repro.core`` — so that importing it
from ``repro.kernels.common`` does not execute the ``repro.core`` package
``__init__`` (which would drag the whole runtime into every kernel import,
and would turn into a circular import the day a core module imports a
kernel).  Keep it free of jax/runtime imports.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

DEFAULT_SLICE_S = 0.005          # max extra latency a cancel can see per slice

_tls = threading.local()

# repro.analysis.sanitizer installs its checkpoint guard here (enable()):
# it reports any checkpoint reached while a stripe/key lock is held — a
# cancel raising under one would unwind past the release.  None (the
# default) keeps the disabled cost at a single module-global compare.
_SAN_GUARD: Optional[Callable[[], None]] = None


def install(check: Callable[[], None],
            slice_s: float = DEFAULT_SLICE_S,
            beat: Optional[Callable[[], None]] = None,
            budget: Optional[Callable[[], float]] = None) -> None:
    """Arm this thread's cancel checkpoint.  ``check`` raises (e.g.
    ``CallCancelled``) when the current call should stop.

    ``beat`` is an optional liveness callback (the host heartbeat) run once
    per elapsed slice *before* the cancel check: a pure-compute loop that
    only ever reaches these checkpoints would otherwise stop beating for
    the whole kernel and be declared dead by any ``heartbeat_timeout``
    shorter than one long dispatch.

    ``budget`` is an optional callable returning the call's remaining
    end-to-end deadline budget in seconds (``Deadline.remaining``).  When
    installed, the checkpoint tightens its slice as the budget runs down
    (to ~budget/4, floored at 0.5 ms), so a deadline lands within a small
    fraction of the remaining budget instead of up to a full default slice
    late.  Read once per *elapsed* slice, never per checkpoint — calls
    without a deadline pay nothing."""
    _tls.check = check
    _tls.beat = beat
    _tls.slice_s = slice_s
    _tls.budget = budget
    _tls.deadline = time.monotonic() + slice_s


def clear() -> None:
    """Disarm the checkpoint (call finished; executor thread is reused)."""
    _tls.check = None
    _tls.beat = None
    _tls.budget = None


def checkpoint() -> None:
    """Run the installed cancel check if the time slice elapsed.  No-op (one
    attribute read) on threads with nothing installed."""
    if _SAN_GUARD is not None:
        _SAN_GUARD()
    check: Optional[Callable[[], None]] = getattr(_tls, "check", None)
    if check is None:
        return
    now = time.monotonic()
    if now >= _tls.deadline:
        slice_s = _tls.slice_s
        budget = getattr(_tls, "budget", None)
        if budget is not None:
            # deadline-aware: approach the expiry in quarter-budget steps
            # so the cancel fires close to it, not a full slice late
            slice_s = max(min(slice_s, budget() / 4.0), 0.0005)
        _tls.deadline = now + slice_s
        beat = getattr(_tls, "beat", None)
        if beat is not None:
            beat()                   # stay alive before maybe raising
        check()
