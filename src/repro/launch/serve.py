"""Serving launcher: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Builds prefill + serve steps for the selected architecture and runs a batched
request loop (greedy decode) — the per-request orchestration that the FAASM
runtime drives in `examples/inference_serving.py`.

``--faasm-requests N`` additionally pushes an N-request wave through the FAASM
runtime's batch invocation path (``invoke_many`` + ``wait_all`` on a shared
completion latch) and reports p50/p99 dispatch latency and batch throughput.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_shape, smoke_config
from repro.configs.base import ShapeConfig
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import ExecConfig, build_model
from repro.telemetry import clock as tclock
from repro.telemetry import metrics as tmetrics
from repro.telemetry import spans as tspans


def make_infer_function(model, treedef, host_leaves, prompt_len: int = 16,
                        cache_key=("serve", "fwd"), state_wire: str = None):
    """Build the FAASM ``infer`` FunctionDef for a single-shot forward pass.

    The jitted executable lands in the runtime's ExecutableCache under
    ``cache_key``; the (numpy, picklable) weights travel in the Proto-Faaslet
    snapshot.  Shared by :func:`run_faasm_fanout` and
    ``examples/inference_serving.py``.

    With ``state_wire`` set, each request additionally accumulates the
    predicted token into the shared ``serve/stats`` histogram and pushes the
    delta with that wire format (``"int8"`` = the quantised
    ``kernels/state_push`` path; ``"auto"`` = the per-key adaptive
    ``WirePolicy``) — the stateful-serving traffic the wire choice is
    about.  The warm-replica refresh before each push rides the wire fabric
    too: only the retained delta is pulled."""
    from repro.core import FunctionDef

    def _build_fwd():
        fwd = jax.jit(lambda p, t: model.logits(p, t))
        p = jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(x) for x in host_leaves])
        fwd(p, jnp.zeros((1, prompt_len), jnp.int32)).block_until_ready()
        return fwd

    def init(api):
        api.runtime.exec_cache.get_or_build(cache_key, _build_fwd)
        return {"params": host_leaves}

    def infer(api):
        state = api.host.user_state(api.faaslet)
        fwd, _, _ = api.runtime.exec_cache.get_or_build(cache_key, _build_fwd)
        p = jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(x) for x in state["params"]])
        tokens = np.frombuffer(api.read_call_input(),
                               np.int32).reshape(1, -1)
        logits = fwd(p, jnp.asarray(tokens))
        tok = int(np.asarray(jnp.argmax(logits[0, -1])))
        if state_wire is not None:
            from repro.state.ddo import VectorAsync
            stats = VectorAsync(api, "serve/stats")
            stats.pull(track_delta=True)
            stats.add([tok], 1.0)
            stats.push_delta(wire=state_wire)
        api.write_call_output(np.int32(tok).tobytes())
        return 0

    return FunctionDef("infer", infer, init_fn=init)


# canonical overload return codes live with the overload control plane;
# re-exported here for back-compat (this module defined SHED_RC first)
from repro.overload import SHED_RC  # noqa: E402

_SHED_CHUNK = 32      # degradation re-check granularity within one wave


def submit_degradable(rt, fn: str, payloads, *, min_alive_hosts: int = 1,
                      state_hint=None, timeout: float = 600.0) -> dict:
    """Submit a request wave with fail-fast shedding (graceful degradation).

    A healthy cluster takes the whole wave through the batched
    ``invoke_many`` path.  Once the alive-host count drops below
    ``min_alive_hosts`` the cluster is **degraded**: requests from that
    point on are shed immediately (code :data:`SHED_RC`, never queued)
    instead of piling onto the survivors — a bounded brown-out in place of
    a collapse.  The wave is submitted in :data:`_SHED_CHUNK`-sized slices
    so a host dying mid-wave starts shedding within one slice, not after
    the whole wave queued.

    Returns ``{"codes": [...], "call_ids": [...], "shed": n,
    "degraded": bool}`` — ``call_ids[i]`` is ``None`` for shed requests.
    Shed requests are the caller's to retry (e.g.
    ``repro.core.chain.scatter_gather``) once capacity returns.
    """
    n = len(payloads)
    codes: list = [SHED_RC] * n
    call_ids: list = [None] * n
    degraded = False
    submitted: list = []                 # (index, call_id)
    for lo in range(0, n, _SHED_CHUNK):
        chunk = payloads[lo:lo + _SHED_CHUNK]
        if len(rt.alive_hosts()) < min_alive_hosts:
            degraded = True              # fail fast: shed the rest of the slice
            continue
        cids = rt.invoke_many(fn, chunk, state_hint=state_hint)
        submitted.extend(zip(range(lo, lo + len(chunk)), cids))
    if submitted:
        rcs = rt.wait_all([c for _, c in submitted], timeout=timeout)
        for (i, cid), rc in zip(submitted, rcs):
            codes[i], call_ids[i] = rc, cid
    shed = sum(1 for c in call_ids if c is None)
    return {"codes": codes, "call_ids": call_ids, "shed": shed,
            "degraded": degraded or shed > 0}


def run_faasm_fanout(model, params, vocab_size: int, n_requests: int,
                     prompt_len: int = 16, n_hosts: int = 1,
                     capacity: int = 8, state_wire: str = None,
                     min_alive_hosts: int = 1,
                     max_queue_depth: int = None,
                     default_deadline_ms: float = None) -> dict:
    """Serve ``n_requests`` single-shot requests through the FAASM runtime.

    Each request is one Faaslet call running the jitted forward pass; the
    whole wave is submitted with ``invoke_many`` and awaited on one shared
    latch (``wait_all``), the thousand-call fan-out path.  ``state_wire``
    turns on the shared serving-stats state (see
    :func:`make_infer_function`) and picks its push wire format; the batch
    then also carries a ``state_hint`` so placement prefers hosts already
    holding the stats replica.

    ``max_queue_depth`` / ``default_deadline_ms`` arm the overload control
    plane (``repro.overload``): bounded per-host admission queues with
    spill-to-peer, and an end-to-end deadline stamped on every request.
    Requests refused everywhere settle with ``SHED_RC``; requests whose
    deadline expires settle with ``overload.DEADLINE_RC``.  Both are
    reported in the returned dict instead of inflating the latency tail."""
    from repro import overload as oload
    from repro.core import FaasmRuntime
    from repro.state.ddo import VectorAsync

    flat, treedef = jax.tree_util.tree_flatten(params)
    host_leaves = [np.asarray(x) for x in flat]
    policy = None
    if max_queue_depth is not None or default_deadline_ms is not None:
        policy = oload.OverloadPolicy(
            max_queue_depth=max_queue_depth,
            default_deadline_s=(default_deadline_ms / 1e3
                                if default_deadline_ms else None))
    rt = FaasmRuntime(n_hosts=n_hosts, capacity=capacity, overload=policy)
    hint = ["serve/stats"] if state_wire is not None else None
    try:
        if state_wire is not None:
            VectorAsync.create(rt.global_tier, "serve/stats",
                               np.zeros(vocab_size, np.float32))
        rt.upload(make_infer_function(model, treedef, host_leaves,
                                      prompt_len=prompt_len,
                                      state_wire=state_wire))
        rng = np.random.default_rng(0)
        payloads = [rng.integers(0, vocab_size, prompt_len,
                                 dtype=np.int32).tobytes()
                    for _ in range(n_requests)]
        # warm every executor before timing the wave
        rt.wait_all(rt.invoke_many("infer", payloads[:capacity],
                                   state_hint=hint), timeout=300)
        rt.global_tier.reset_metrics()
        t0 = tclock.now()
        wave = submit_degradable(rt, "infer", payloads,
                                 min_alive_hosts=min_alive_hosts,
                                 state_hint=hint, timeout=600)
        wall = tclock.now() - t0
        from repro.overload import DEADLINE_RC
        ok_codes = (0, SHED_RC, DEADLINE_RC)
        assert all(r in ok_codes for r in wave["codes"]), wave["codes"]
        served = [c for c, r in zip(wave["call_ids"], wave["codes"])
                  if c is not None and r == 0]
        n_deadline = sum(1 for r in wave["codes"] if r == DEADLINE_RC)
        n_shed = (wave["shed"]
                  + sum(1 for r in wave["codes"] if r == SHED_RC))
        # one source of truth: per-request latency lands in the runtime's
        # registry (mirrored to the process registry for --metrics-port)
        hist = rt.metrics.histogram("faasm_serve_request_ms",
                                    "end-to-end request latency")
        mirror = tmetrics.registry().histogram("faasm_serve_request_ms",
                                               "end-to-end request latency")
        for c in served:
            ms = rt.call(c).latency * 1e3
            hist.observe(ms)
            mirror.observe(ms)
        out = {"requests": n_requests, "wall_s": wall,
               "throughput_rps": len(served) / wall,
               "p50_ms": hist.percentile(0.50) if served else 0.0,
               "p99_ms": hist.percentile(0.99) if served else 0.0,
               "degraded": wave["degraded"], "shed": n_shed,
               "deadline_expired": n_deadline}
        if state_wire is not None:
            out["state_wire"] = state_wire
            out["state_push_mb"] = sum(
                rt.global_tier.bytes_pushed.values()) / 1e6
        return out
    finally:
        rt.shutdown()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--faasm-requests", type=int, default=0,
                    help="also fan out N requests through the FAASM runtime "
                         "(invoke_many/wait_all batch path)")
    ap.add_argument("--faasm-hosts", type=int, default=1)
    ap.add_argument("--min-alive-hosts", type=int, default=1,
                    help="graceful-degradation floor: shed requests (fail "
                         "fast) once fewer hosts than this are alive")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="bound each host's admission queue at this many "
                         "calls beyond its executor capacity; overflow "
                         "spills to a peer with room or is shed (SHED_RC)")
    ap.add_argument("--default-deadline-ms", type=float, default=None,
                    help="stamp this end-to-end deadline (ms) on every "
                         "request; expired work settles with DEADLINE_RC "
                         "at admission, dequeue, or the next checkpoint")
    ap.add_argument("--state-wire", choices=("auto", "exact", "int8"),
                    default=None,
                    help="track shared serving stats through the state tier "
                         "and move deltas with this wire format (auto = "
                         "per-key adaptive WirePolicy)")
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="expose the telemetry registry as Prometheus text "
                         "on this port (0 = off)")
    args = ap.parse_args()

    reg = tmetrics.registry()
    if args.metrics_port:
        tmetrics.serve_http(reg, args.metrics_port)
        print(f"metrics: http://127.0.0.1:{args.metrics_port}/metrics")

    if args.smoke:
        cfg = smoke_config(args.arch)
        ec = ExecConfig(backend="xla", loss_chunk=0)
    else:
        cfg = get_config(args.arch)
        ec = ExecConfig(backend="auto", loss_chunk=0)
    model = build_model(cfg, ec)
    params = model.init(jax.random.PRNGKey(0))

    B, S = args.batch, args.prompt_len
    max_len = S + args.new_tokens + (cfg.n_image_tokens
                                     if cfg.family == "vlm" else 0)
    rng = np.random.default_rng(0)
    St = S
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, St)), jnp.int32)
    extra = None
    if cfg.family == "vlm":
        extra = jnp.asarray(rng.normal(size=(B, cfg.n_image_tokens,
                                             cfg.d_model)), jnp.bfloat16)
    if cfg.family == "encdec":
        extra = jnp.asarray(rng.normal(size=(B, cfg.n_frames, cfg.d_model)),
                            jnp.bfloat16)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    cache = model.init_cache(B, max_len)
    tel = tspans.tracer()
    t0 = tclock.now()
    logits, cache, n = prefill(params, tokens, cache, extra)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t1 = tclock.now()
    reg.histogram("faasm_serve_prefill_ms").observe((t1 - t0) * 1e3)
    if tel is not None:
        tel.record("serve.prefill", "serve", t0, t1, arch=cfg.name, tokens=S)
    n_total = int(n) if not hasattr(n, "shape") else S + (
        cfg.n_image_tokens if cfg.family == "vlm" else 0)

    out = [tok]
    t0 = tclock.now()
    for i in range(args.new_tokens - 1):
        idx = jnp.full((B,), n_total + i, jnp.int32)
        logits, cache = decode(params, tok, cache, idx)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t1 = tclock.now()
    reg.histogram("faasm_serve_decode_ms").observe((t1 - t0) * 1e3)
    if tel is not None:
        tel.record("serve.decode", "serve", t0, t1, arch=cfg.name,
                   steps=args.new_tokens - 1)
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    # the printed line reads the registry — the timers above are its only
    # writers, so the log and /metrics can never disagree
    snap = reg.snapshot()
    prefill_s = snap["faasm_serve_prefill_ms_sum"] / 1e3
    decode_s = snap["faasm_serve_decode_ms_sum"] / 1e3
    print(f"{cfg.name}: prefill {S} toks in {prefill_s * 1e3:.1f}ms; "
          f"{args.new_tokens - 1} decode steps in {decode_s * 1e3:.1f}ms "
          f"({(args.new_tokens - 1) * B / max(decode_s, 1e-9):.1f} tok/s)")
    print("generated ids[0]:", gen[0][:12], "...")

    if args.faasm_requests > 0:
        r = run_faasm_fanout(model, params, cfg.vocab_size,
                             args.faasm_requests, prompt_len=S,
                             n_hosts=args.faasm_hosts,
                             state_wire=args.state_wire,
                             min_alive_hosts=args.min_alive_hosts,
                             max_queue_depth=args.max_queue_depth,
                             default_deadline_ms=args.default_deadline_ms)
        print(f"faasm fan-out: {r['requests']} reqs in {r['wall_s']:.2f}s "
              f"({r['throughput_rps']:.1f} req/s) "
              f"p50={r['p50_ms']:.1f}ms p99={r['p99_ms']:.1f}ms")
        if r.get("degraded"):
            print(f"  DEGRADED: {r['shed']} requests shed (alive hosts "
                  f"below --min-alive-hosts={args.min_alive_hosts})")
        if r.get("deadline_expired"):
            print(f"  {r['deadline_expired']} requests expired their "
                  f"--default-deadline-ms={args.default_deadline_ms} budget")
        if "state_push_mb" in r:
            print(f"  serve/stats pushes ({r['state_wire']} wire): "
                  f"{r['state_push_mb']:.2f}MB to the global tier")


if __name__ == "__main__":
    main()
