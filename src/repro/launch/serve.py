"""Serving launcher: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Builds prefill + serve steps for the selected architecture and runs a batched
request loop (greedy decode) — the per-request orchestration that the FAASM
runtime drives in `examples/inference_serving.py`.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_shape, smoke_config
from repro.configs.base import ShapeConfig
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import ExecConfig, build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.smoke:
        cfg = smoke_config(args.arch)
        ec = ExecConfig(backend="xla", loss_chunk=0)
    else:
        cfg = get_config(args.arch)
        ec = ExecConfig(backend="auto", loss_chunk=0)
    model = build_model(cfg, ec)
    params = model.init(jax.random.PRNGKey(0))

    B, S = args.batch, args.prompt_len
    max_len = S + args.new_tokens + (cfg.n_image_tokens
                                     if cfg.family == "vlm" else 0)
    rng = np.random.default_rng(0)
    St = S
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, St)), jnp.int32)
    extra = None
    if cfg.family == "vlm":
        extra = jnp.asarray(rng.normal(size=(B, cfg.n_image_tokens,
                                             cfg.d_model)), jnp.bfloat16)
    if cfg.family == "encdec":
        extra = jnp.asarray(rng.normal(size=(B, cfg.n_frames, cfg.d_model)),
                            jnp.bfloat16)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    cache = model.init_cache(B, max_len)
    t0 = time.perf_counter()
    logits, cache, n = prefill(params, tokens, cache, extra)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    prefill_s = time.perf_counter() - t0
    n_total = int(n) if not hasattr(n, "shape") else S + (
        cfg.n_image_tokens if cfg.family == "vlm" else 0)

    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.new_tokens - 1):
        idx = jnp.full((B,), n_total + i, jnp.int32)
        logits, cache = decode(params, tok, cache, idx)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"{cfg.name}: prefill {S} toks in {prefill_s * 1e3:.1f}ms; "
          f"{args.new_tokens - 1} decode steps in {decode_s * 1e3:.1f}ms "
          f"({(args.new_tokens - 1) * B / max(decode_s, 1e-9):.1f} tok/s)")
    print("generated ids[0]:", gen[0][:12], "...")


if __name__ == "__main__":
    main()
