"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

Production path: builds the pjit train step for the selected architecture
under the production mesh (on a real TPU slice the same code runs unchanged;
on this CPU container use ``--smoke`` for a reduced config on one device).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config, get_shape, smoke_config, smoke_shape
from repro.configs.base import ShapeConfig
from repro.data import PipelineConfig, make_batch
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import ExecConfig, build_model
from repro.optim import SGD, AdamW, warmup_cosine
from repro.telemetry import clock as tclock
from repro.telemetry import metrics as tmetrics
from repro.telemetry import spans as tspans


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, single device, tiny batch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--optimizer", choices=["sgd", "adamw"], default="sgd")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="artifacts/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.smoke:
        cfg = smoke_config(args.arch)
        shape = smoke_shape("train")
        mesh = None
        ec = ExecConfig(backend="xla", loss_chunk=16)
    else:
        cfg = get_config(args.arch)
        shape = get_shape(args.shape)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        ec = ExecConfig(backend="auto", loss_chunk=512)

    model = build_model(cfg, ec)
    sched = warmup_cosine(args.lr, warmup=max(1, args.steps // 10),
                          total=args.steps)
    opt = SGD(lr=sched) if args.optimizer == "sgd" else AdamW(lr=sched)
    ck = Checkpointer(args.ckpt_dir, keep=2)

    print(f"train {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{shape.name}, opt={args.optimizer}")

    if mesh is not None:
        rules = ShardingRules(mesh, cfg)
        with mesh:
            step_fn, _ = make_train_step(model, opt, rules, shape)
    else:
        def raw_step(params, state, batch):
            (loss, m), grads = jax.value_and_grad(model.loss, has_aux=True)(
                params, batch)
            params, state = opt.update(grads, state, params)
            m = dict(m, loss=loss)
            return params, state, m
        step_fn = jax.jit(raw_step)

    params = model.init(jax.random.PRNGKey(0))
    state = opt.init(params)
    start = 0
    if args.resume and ck.latest_step() is not None:
        (params, state), start, _ = ck.restore((params, state))
        print(f"resumed at step {start}")

    pc = PipelineConfig(seed=0)
    # step timing flows through the telemetry registry; the printed log
    # reads the histogram back, so it and any scrape agree by construction
    hist = tmetrics.registry().histogram("faasm_train_step_ms")
    tel = tspans.tracer()
    for step in range(start, args.steps):
        s0 = tclock.now()
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch(cfg, shape, pc, step).items()}
        params, state, metrics = step_fn(params, state, batch)
        s1 = tclock.now()
        hist.observe((s1 - s0) * 1e3)
        if tel is not None:
            tel.record("train.step", "train", s0, s1, step=step)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):8.4f} "
                  f"gnorm {float(metrics.get('grad_norm', 0.0)):8.3f} "
                  f"({hist.sum / 1e3:6.1f}s, "
                  f"p50 {hist.percentile(0.5):5.0f}ms)")
        if args.ckpt_every and step and step % args.ckpt_every == 0:
            ck.save(step, (params, state))
    ck.save(args.steps, (params, state), blocking=True)
    print("done")


if __name__ == "__main__":
    main()
