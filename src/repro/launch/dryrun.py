import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST stay first — jax locks the device count on
# first init.  (This also precludes `from __future__ import annotations`.)

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: the production
meshes (16×16 single-pod, 2×16×16 multi-pod) are built from 512 placeholder
CPU devices (the XLA_FLAGS line above MUST precede any jax import), every
assigned cell is ``.lower().compile()``d, and the compiled artifact yields

  * ``memory_analysis()``  — per-device bytes (proves it fits),
  * ``cost_analysis()``    — per-device HLO FLOPs / bytes accessed,
  * collective bytes       — parsed from the SPMD HLO text,

from which the three roofline terms are derived (TPU v5e constants).
Artifacts land in ``artifacts/dryrun/<mesh>/<arch>__<shape>.json`` and feed
EXPERIMENTS.md §Dry-run / §Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import (ARCHS, SHAPES, get_config, get_shape,
                           shape_applicable)
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.hlo_analysis import analyze as analyze_hlo
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import dummy_args, make_step_for_shape
from repro.models import ExecConfig, build_model
from repro.optim import SGD

# ----------------------------------------------------------------- hardware --
# TPU v5e, per chip.
PEAK_FLOPS = 197e12            # bf16 FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum per-device collective op bytes from post-SPMD HLO, by op kind."""
    out: Dict[str, float] = {"all-gather": 0.0, "all-reduce": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0}
    counts: Dict[str, int] = {k: 0 for k in out}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if kind.endswith("-done"):
            continue
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        if dims:
            for d in dims.split(","):
                if d:
                    nbytes *= int(d)
        out[kind] += float(nbytes)
        counts[kind] += 1
    out["counts"] = counts            # type: ignore[assignment]
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference).

    N counts matmul-involved params: the embedding *lookup* is free, but the
    unembed matmul always costs V·d per token (for tied embeddings the table
    is counted once in active_param_count and used as the unembed matmul)."""
    n = cfg.active_param_count()
    if not cfg.tie_embeddings:
        n -= cfg.vocab_size * cfg.d_model
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n * shape.tokens_per_step


def exec_for(cfg: ModelConfig, shape: ShapeConfig,
             overrides: Optional[dict] = None) -> ExecConfig:
    """Per-cell execution plan (the §Perf baseline; overrides hillclimb it)."""
    kw: Dict = dict(backend="xla", remat="full", scan_layers=True)
    if shape.kind == "train":
        kw["loss_chunk"] = 512
        if cfg.name == "kimi-k2-1t-a32b":
            # §Perf cell B: microbatches=1 strictly dominates (fewest FSDP
            # weight re-gathers); grads stay bf16 with no accumulator.
            kw["microbatches"] = 1
            kw["moe_group_size"] = 256
            kw["accum_dtype"] = "bfloat16"
        elif cfg.n_experts:
            kw["moe_group_size"] = 256
    else:
        kw["loss_chunk"] = 0
        kw["moe_group_size"] = 128
        if shape.kind == "decode" and cfg.n_experts:
            # single-group capacity dispatch: honest FLOPs accounting (the
            # sorted/ragged path lowers dense on CPU), <0.1% drops at cf=4
            kw["moe_decode_impl"] = "einsum"
            kw["moe_capacity_override"] = 4.0
            kw["moe_group_size"] = 8192
    if overrides:
        kw.update(overrides)
    return ExecConfig(**kw)


def run_cell(arch: str, shape_id: str, mesh, mesh_name: str,
             overrides: Optional[dict] = None, fsdp: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_id)
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_id, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    n_dev = mesh.size
    ec = exec_for(cfg, shape, overrides)
    model = build_model(cfg, ec)
    rules = ShardingRules(mesh, cfg, fsdp=fsdp)
    t0 = time.perf_counter()
    with mesh:
        jitted, args = make_step_for_shape(model, rules, shape,
                                           optimizer=SGD(lr=0.01))
        lowered = jitted.lower(*dummy_args(model, shape, args, SGD(lr=0.01)))
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else (ca or {})
        hlo = compiled.as_text()
    # Static HLO analysis: XLA-CPU cost_analysis counts while bodies once, so
    # scanned-layer programs need the trip-count-aware traversal.
    costs = analyze_hlo(hlo)
    hlo_len = len(hlo)
    del hlo, compiled, lowered, jitted

    flops = costs.flops
    bytes_accessed = costs.bytes
    coll = {k: v for k, v in costs.collective.items()}
    coll["counts"] = costs.collective_counts
    coll_total = costs.collective_bytes
    xla_flops = float(ca.get("flops", 0.0))

    # roofline terms, seconds (per-device program => per-chip terms).
    # "corrected" strips XLA-CPU's bf16->f32 emulation traffic/copies, which
    # do not exist on TPU (native bf16).
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_memory_corr = max(0.0, bytes_accessed - costs.bf16_convert_bytes) / HBM_BW
    t_coll = coll_total / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory_corr, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    peak_corr = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes
                 - costs.bf16_convert_static_bytes)

    mf = model_flops(cfg, shape)
    useful_ratio = mf / (flops * n_dev) if flops else 0.0

    rec = {
        "arch": arch, "shape": shape_id, "mesh": mesh_name,
        "status": "ok", "n_devices": n_dev,
        "exec": {k: getattr(ec, k) for k in
                 ("backend", "remat", "moe_impl", "moe_group_size",
                  "microbatches", "loss_chunk", "attn_block_k")},
        "fsdp": fsdp,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "hlo_bytes": hlo_len,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes
                           - mem.alias_size_in_bytes),
            "bf16_emulation_bytes": costs.bf16_convert_static_bytes,
            "peak_bytes_corrected": peak_corr,
        },
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_total,
        "collectives": coll,
        "xla_cost_flops": xla_flops,
        "analysis_warnings": sorted(set(costs.warnings)),
        "roofline": {
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_memory_corrected_s": t_memory_corr,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops": mf,
            "useful_flops_ratio": useful_ratio,
            "roofline_fraction": (t_compute / max(t_compute, t_memory, t_coll)
                                  if max(t_compute, t_memory, t_coll) else 0.0),
        },
    }
    return rec


def main():
    ap = argparse.ArgumentParser(description=DOC)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape id (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute existing")
    ap.add_argument("--tag", default="", help="artifact suffix (perf variants)")
    ap.add_argument("--override", default="",
                    help="ExecConfig overrides, e.g. 'moe_group_size=512,remat=dots'")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override.split(","):
        if "=" in kv:
            k, v = kv.split("=", 1)
            overrides[k.strip()] = (int(v) if v.strip().lstrip("-").isdigit()
                                    else v.strip())

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        out_dir = os.path.join(args.out, mesh_name)
        os.makedirs(out_dir, exist_ok=True)
        for arch in archs:
            for shape_id in shapes:
                tag = f"__{args.tag}" if args.tag else ""
                path = os.path.join(out_dir, f"{arch}__{shape_id}{tag}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip-cached] {mesh_name} {arch} {shape_id}")
                    continue
                try:
                    rec = run_cell(arch, shape_id, mesh, mesh_name,
                                   overrides=overrides or None,
                                   fsdp=not args.no_fsdp)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_id, "mesh": mesh_name,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                results.append(rec)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"[ok] {mesh_name} {arch:>18s} {shape_id:<12s} "
                          f"compile={rec['compile_s']:7.1f}s "
                          f"peak={rec['memory']['peak_bytes_corrected']/2**30:7.2f}GiB "
                          f"Tc={r['t_compute_s']*1e3:9.3f}ms "
                          f"Tm={r['t_memory_corrected_s']*1e3:9.3f}ms "
                          f"Tx={r['t_collective_s']*1e3:9.3f}ms "
                          f"dom={r['dominant']:<10s} "
                          f"useful={r['useful_flops_ratio']:.3f}", flush=True)
                elif rec["status"] == "skipped":
                    print(f"[skipped] {mesh_name} {arch} {shape_id}: "
                          f"{rec['reason']}", flush=True)
                else:
                    print(f"[ERROR] {mesh_name} {arch} {shape_id}: "
                          f"{rec['error']}", flush=True)
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"done: {len(results)} cells, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
