"""pjit-compiled train / prefill / serve steps with explicit shardings.

These factories are shared by the real drivers (``train.py`` / ``serve.py``)
and the multi-pod dry-run (which lowers them against ShapeDtypeStructs).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import ShardingRules
from repro.models.model import Model
from repro.optim.grad_accum import accumulate_grads


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def make_train_step(model: Model, optimizer, rules: ShardingRules,
                    shape: ShapeConfig, *, donate: bool = True):
    """Returns (jitted_step, arg_specs) where
    jitted_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    ec = model.ec

    def step_fn(params, opt_state, batch):
        grads, loss, metrics = accumulate_grads(
            model.loss, params, batch, ec.microbatches,
            accum_dtype=jnp.dtype(ec.accum_dtype))
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return new_params, new_opt, metrics

    pshapes = model.init_shapes()
    pspecs = rules.params_specs(pshapes)
    oshapes = jax.eval_shape(optimizer.init, pshapes)
    ospecs = rules.opt_specs(oshapes, pshapes)
    input_specs = model.input_specs(shape)
    bspecs = rules.batch_specs(input_specs, shape)
    mesh = rules.mesh

    mspecs = {"loss": P(), "aux_loss": P(), "grad_norm": P()}
    jitted = jax.jit(
        step_fn,
        in_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                      _named(mesh, bspecs)),
        out_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                       _named(mesh, mspecs)),
        donate_argnums=(0, 1) if donate else (),
    )
    args = {"params": pshapes, "opt_state": oshapes, "batch": input_specs,
            "param_specs": pspecs, "opt_specs": ospecs, "batch_specs": bspecs}
    return jitted, args


def make_prefill_step(model: Model, rules: ShardingRules, shape: ShapeConfig):
    """jitted(params, tokens, cache[, extra]) -> (logits, cache, len)."""
    mesh = rules.mesh
    cfg = model.cfg

    input_specs = model.input_specs(shape)
    bspecs = rules.batch_specs(input_specs, shape)
    pshapes = model.init_shapes()
    pspecs = rules.params_specs(pshapes)

    extra_key = ("frames" if cfg.family == "encdec"
                 else "image_embeds" if cfg.family == "vlm" else None)

    def step_fn(params, tokens, cache, extra=None):
        logits, cache, n = model.prefill(params, tokens, cache, extra)
        return logits, cache, n

    in_sh = [_named(mesh, pspecs), _named(mesh, bspecs["tokens"]),
             _named(mesh, bspecs["cache"])]
    lspec = rules.logits_spec(shape.global_batch)
    out_sh = (_named(mesh, lspec), _named(mesh, bspecs["cache"]), None)
    if extra_key:
        in_sh.append(_named(mesh, bspecs[extra_key]))
        jitted = jax.jit(step_fn, in_shardings=tuple(in_sh),
                         out_shardings=out_sh, donate_argnums=(2,))
    else:
        jitted = jax.jit(lambda p, t, c: step_fn(p, t, c),
                         in_shardings=tuple(in_sh), out_shardings=out_sh,
                         donate_argnums=(2,))
    return jitted, {"params": pshapes, "batch": input_specs,
                    "batch_specs": bspecs, "extra_key": extra_key,
                    "param_specs": pspecs}


def make_serve_step(model: Model, rules: ShardingRules, shape: ShapeConfig):
    """One decode step: jitted(params, token, cache, index) -> (logits, cache)."""
    mesh = rules.mesh
    input_specs = model.input_specs(shape)
    bspecs = rules.batch_specs(input_specs, shape)
    pshapes = model.init_shapes()
    pspecs = rules.params_specs(pshapes)

    def step_fn(params, token, cache, index):
        return model.decode_step(params, token, cache, index)

    lspec = rules.logits_spec(shape.global_batch)
    jitted = jax.jit(
        step_fn,
        in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs["token"]),
                      _named(mesh, bspecs["cache"]),
                      _named(mesh, bspecs["index"])),
        out_shardings=(_named(mesh, lspec), _named(mesh, bspecs["cache"])),
        donate_argnums=(2,),
    )
    return jitted, {"params": pshapes, "batch": input_specs,
                    "batch_specs": bspecs, "param_specs": pspecs}


def make_step_for_shape(model: Model, rules: ShardingRules, shape: ShapeConfig,
                        optimizer=None):
    """Dispatch on the shape kind (train/prefill/decode)."""
    if shape.kind == "train":
        assert optimizer is not None
        return make_train_step(model, optimizer, rules, shape)
    if shape.kind == "prefill":
        return make_prefill_step(model, rules, shape)
    return make_serve_step(model, rules, shape)


def dummy_args(model: Model, shape: ShapeConfig, args: Dict[str, Any],
               optimizer=None):
    """ShapeDtypeStruct argument tuple for ``lower()`` (no allocation)."""
    sds = args["batch"]
    if shape.kind == "train":
        return (args["params"], args["opt_state"], sds)
    if shape.kind == "prefill":
        base = (args["params"], sds["tokens"], sds["cache"])
        if args.get("extra_key"):
            base = base + (sds[args["extra_key"]],)
        return base
    return (args["params"], sds["token"], sds["cache"], sds["index"])
