"""Production mesh construction.

Defined as functions (not module-level constants) so importing this module
never touches JAX device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2, 4) on 8 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def data_axes(mesh) -> tuple:
    """Axes treated as pure data parallelism (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
