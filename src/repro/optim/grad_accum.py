"""Gradient accumulation (microbatching) as a scan over the loss function.

Slices the per-step batch into ``n`` microbatches along the batch axis and
accumulates mean gradients — bounds activation memory for the big train cells
(the microbatch count is an ExecConfig hillclimb lever).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def accumulate_grads(loss_fn: Callable, params, batch: Dict[str, Any],
                     n_micro: int, accum_dtype=jnp.float32):
    """loss_fn(params, batch) -> (loss, metrics).  Returns (grads, loss, metrics).

    ``accum_dtype=jnp.bfloat16`` halves accumulator memory — the lever that
    lets the 1T-param config fit (paper-style SGD tolerates the precision)."""
    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return grads, loss, metrics

    def slice_micro(x, i):
        B = x.shape[0]
        mb = B // n_micro
        return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

    def body(carry, i):
        acc, loss_acc = carry
        micro = jax.tree.map(lambda x: slice_micro(x, i), batch)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, micro)
        acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads)
        return (acc, loss_acc + loss), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
    (acc, loss_sum), metrics = jax.lax.scan(
        body, (zeros, jnp.zeros(())), jnp.arange(n_micro))
    grads = jax.tree.map(lambda a: a / n_micro, acc)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return grads, loss_sum / n_micro, metrics
