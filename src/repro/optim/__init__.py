from repro.optim.sgd import SGD, AdamW, SGDState, AdamWState, warmup_cosine
from repro.optim.grad_accum import accumulate_grads
from repro.optim import compression

__all__ = ["SGD", "AdamW", "SGDState", "AdamWState", "warmup_cosine",
           "accumulate_grads", "compression"]
