"""Optimizers as pure pytree transforms (pjit-friendly).

SGD is the paper's training optimizer (HOGWILD! SGD, §6.2); AdamW is provided
for completeness.  Both keep their state as a pytree sharded like the params
(ZeRO-style under the FSDP rules in ``distributed/sharding.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Any                      # pytree or None-like empty tuple


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-2
    momentum: float = 0.0
    weight_decay: float = 0.0

    def init(self, params) -> SGDState:
        mom = (jax.tree.map(jnp.zeros_like, params)
               if self.momentum else ())
        return SGDState(step=jnp.zeros((), jnp.int32), momentum=mom)

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: SGDState, params) -> Tuple[Any, SGDState]:
        lr = self._lr(state.step)

        if self.momentum:
            new_mom = jax.tree.map(
                lambda m, g: self.momentum * m + g.astype(m.dtype),
                state.momentum, grads)
            upd = new_mom
        else:
            new_mom = ()
            upd = grads

        def apply(p, g):
            gp = g.astype(jnp.float32)
            if self.weight_decay:
                gp = gp + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * gp).astype(p.dtype)

        new_params = jax.tree.map(apply, params, upd)
        return new_params, SGDState(step=state.step + 1, momentum=new_mom)


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params) -> AdamWState:
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros32, params),
                          nu=jax.tree.map(zeros32, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self._lr(state.step)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)

        def apply(p, m, v):
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            upd = upd + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new_params = jax.tree.map(apply, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    """LR schedule usable as the ``lr`` field of either optimizer."""

    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * (step + 1) / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return sched
