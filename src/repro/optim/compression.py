"""Gradient/push compression for the cross-pod global-tier synchronisation.

Faasm pushes deltas from the local to the global tier; at pod scale the
analogous transfer is the cross-pod gradient/update all-reduce.  Two
compressors, both with **error feedback** (the residual of the lossy step is
carried into the next push so compression error doesn't accumulate as bias):

  * int8 per-tensor-row quantisation (the wire format of
    ``kernels/state_push``) — 4× fewer ICI bytes than f32, ~2× vs bf16;
  * top-k sparsification — send only the k largest-magnitude entries.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any                      # error-feedback pytree


def init_state(params_like) -> CompressionState:
    return CompressionState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params_like))


# -- int8 -----------------------------------------------------------------------

def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row (last-axis) int8 quantisation: (q, scales)."""
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(x2).max(axis=1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x2 / scale), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    q2 = q.reshape(-1, q.shape[-1]).astype(jnp.float32) * scale
    return q2.reshape(q.shape)


def compress_int8(grads, state: CompressionState):
    """Returns (wire pytree of (q, scale), decoded pytree, new state)."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        dec = dequantize_int8(q, s)
        return (q, s), dec, x - dec

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(state.residual)
    wire, dec, res = zip(*[one(g, r) for g, r in zip(flat_g, flat_r)])
    return (jax.tree.unflatten(tree, wire),
            jax.tree.unflatten(tree, dec),
            CompressionState(residual=jax.tree.unflatten(tree, res)))


# -- top-k ------------------------------------------------------------------------

def compress_topk(grads, state: CompressionState, frac: float = 0.01):
    """Keep the top ``frac`` of entries per tensor (by magnitude)."""

    def one(g, r):
        x = (g.astype(jnp.float32) + r).reshape(-1)
        k = max(1, int(x.size * frac))
        _, idx = jax.lax.top_k(jnp.abs(x), k)
        vals = x[idx]
        dec = jnp.zeros_like(x).at[idx].set(vals)
        return (idx, vals), dec.reshape(g.shape), (x - dec).reshape(g.shape)

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(state.residual)
    wire, dec, res = zip(*[one(g, r) for g, r in zip(flat_g, flat_r)])
    return (jax.tree.unflatten(tree, wire),
            jax.tree.unflatten(tree, dec),
            CompressionState(residual=jax.tree.unflatten(tree, res)))


def wire_bytes_int8(wire) -> int:
    total = 0
    for q, s in jax.tree.leaves(wire, is_leaf=lambda x: isinstance(x, tuple)):
        total += q.size + s.size * 4
    return total
