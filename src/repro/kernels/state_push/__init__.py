from repro.kernels.state_push.ops import apply_delta, push, quantize_delta
from repro.kernels.state_push.ref import (apply_delta_ref, push_ref,
                                          quantize_delta_ref)

__all__ = ["apply_delta", "push", "quantize_delta",
           "apply_delta_ref", "push_ref", "quantize_delta_ref"]
