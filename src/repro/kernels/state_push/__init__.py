"""Fused two-tier state push kernels.

Re-exports are lazy (PEP 562): ``ops``/``ref`` import jax at module scope,
but ``hostcodec`` — the numpy-only host wire codec — must stay importable
without jax (``state/wire.py`` imports it at module scope and
``scripts/check_jax_pin.py`` exercises it before touching jax).
"""

_OPS = ("apply_delta", "apply_pull", "dequantize", "encode_fp8",
        "encode_pull", "encode_quant", "push", "quantize_delta",
        "wire_nbytes")
_REF = ("apply_delta_ref", "push_ref", "quantize_delta_ref",
        "quantize_fp8_ref")

__all__ = list(_OPS) + list(_REF)


def __getattr__(name):
    if name in _OPS:
        from repro.kernels.state_push import ops
        return getattr(ops, name)
    if name in _REF:
        from repro.kernels.state_push import ref
        return getattr(ref, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
