from repro.kernels.state_push.ops import (apply_delta, apply_pull, dequantize,
                                          encode_pull, push, quantize_delta,
                                          wire_nbytes)
from repro.kernels.state_push.ref import (apply_delta_ref, push_ref,
                                          quantize_delta_ref)

__all__ = ["apply_delta", "apply_pull", "dequantize", "encode_pull", "push",
           "quantize_delta", "wire_nbytes", "apply_delta_ref", "push_ref",
           "quantize_delta_ref"]
