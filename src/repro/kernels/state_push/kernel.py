"""Pallas TPU kernels for the fused two-tier state push.

Faasm's push writes a local-tier replica's changes to the global tier.  On a
TPU host the bandwidth-bound part is three HBM streams (local, base snapshot,
global) — a naive implementation does delta-compute and apply as two passes
(5 streams).  These kernels fuse each direction into a single pass:

  * ``quantize_delta``: delta = local − base, per-row (128-lane) absmax scale,
    int8 payload — one read of each input, one int8 + one f32 write.  The
    int8 payload is what crosses the pod interconnect (≈ 4× fewer ICI bytes).
  * ``apply_delta``: global += q·scale — one read each, one write.

Blocks are (block_rows, 128): the minor dim matches the VREG lane width so the
VPU runs at full occupancy; rows are the streaming dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _quantize_kernel(local_ref, base_ref, q_ref, scale_ref, *, qmax: float):
    delta = local_ref[...].astype(jnp.float32) - base_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(delta), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q_ref[...] = jnp.clip(jnp.round(delta / scale), -qmax, qmax).astype(jnp.int8)
    scale_ref[...] = scale


FP8_MAX = 448.0  # float8_e4m3fn max finite — clip before cast (no inf in e4m3)


def _quantize_fp8_kernel(local_ref, base_ref, q_ref, scale_ref):
    delta = local_ref[...].astype(jnp.float32) - base_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(delta), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / FP8_MAX, 1e-12)
    q_ref[...] = jnp.clip(delta / scale, -FP8_MAX,
                          FP8_MAX).astype(jnp.float8_e4m3fn)
    scale_ref[...] = scale


def _apply_kernel(global_ref, q_ref, scale_ref, out_ref):
    out_ref[...] = (global_ref[...].astype(jnp.float32)
                    + q_ref[...].astype(jnp.float32) * scale_ref[...]
                    ).astype(out_ref.dtype)


def _push_kernel(local_ref, base_ref, global_ref, out_ref):
    out_ref[...] = (global_ref[...].astype(jnp.float32)
                    + local_ref[...].astype(jnp.float32)
                    - base_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


def quantize_delta_pallas(local, base, *, block_rows: int = 256,
                          interpret: bool = False, qmax: float = 127.0):
    R, L = local.shape
    assert L == LANES and R % block_rows == 0, (local.shape, block_rows)
    grid = (R // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    sspec = pl.BlockSpec((block_rows, 1), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_quantize_kernel, qmax=qmax),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, sspec],
        out_shape=[jax.ShapeDtypeStruct((R, LANES), jnp.int8),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(local, base)


def quantize_fp8_pallas(local, base, *, block_rows: int = 256,
                        interpret: bool = False):
    R, L = local.shape
    assert L == LANES and R % block_rows == 0, (local.shape, block_rows)
    grid = (R // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    sspec = pl.BlockSpec((block_rows, 1), lambda i: (i, 0))
    return pl.pallas_call(
        _quantize_fp8_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, sspec],
        out_shape=[jax.ShapeDtypeStruct((R, LANES), jnp.float8_e4m3fn),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(local, base)


def apply_delta_pallas(global_val, q, scale, *, block_rows: int = 256,
                       interpret: bool = False):
    R, L = global_val.shape
    assert L == LANES and R % block_rows == 0
    grid = (R // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    sspec = pl.BlockSpec((block_rows, 1), lambda i: (i, 0))
    return pl.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[spec, spec, sspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(global_val.shape, global_val.dtype),
        interpret=interpret,
    )(global_val, q, scale)


def push_pallas(local, base, global_val, *, block_rows: int = 256,
                interpret: bool = False):
    R, L = local.shape
    assert L == LANES and R % block_rows == 0
    grid = (R // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _push_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(global_val.shape, global_val.dtype),
        interpret=interpret,
    )(local, base, global_val)
