"""Pure-jnp oracle for the fused two-tier state push (Faasm §4.2).

A push moves `delta = local - base` from the local tier to the global tier.
The compressed variant quantises the delta to int8 with one f32 scale per
128-lane row — what actually crosses the pod interconnect.
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize_delta_ref(local, base, qmax: float = 127.0):
    """local/base: (R, 128) f32.  Returns (q int8 (R,128), scales f32 (R, 1)).

    ``qmax`` selects the integer tier: 127 for the int8 wire, 7 for the int4
    wire (codes stay int8 here; nibble-packing is a host-side wire concern)."""
    delta = local.astype(jnp.float32) - base.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(delta), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(delta / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


FP8_MAX = 448.0  # float8_e4m3fn max finite; no inf — overflow casts to NaN


def quantize_fp8_ref(local, base):
    """fp8 (e4m3fn) twin of :func:`quantize_delta_ref`.

    Codes are clipped to ±``FP8_MAX`` before the cast: e4m3fn has no inf, so
    an unclipped |code| > 448 would become NaN on the wire."""
    delta = local.astype(jnp.float32) - base.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(delta), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / FP8_MAX, 1e-12)
    q = jnp.clip(delta / scale, -FP8_MAX, FP8_MAX).astype(jnp.float8_e4m3fn)
    return q, scale


def apply_delta_ref(global_val, q, scale):
    """global_val: (R, 128); q: (R,128) int8; scale: (R,1).  Returns new global."""
    return (global_val.astype(jnp.float32)
            + q.astype(jnp.float32) * scale).astype(global_val.dtype)


def push_ref(local, base, global_val):
    """Uncompressed fused push: global += (local - base)."""
    delta = local.astype(jnp.float32) - base.astype(jnp.float32)
    return (global_val.astype(jnp.float32) + delta).astype(global_val.dtype)
