"""Pure-jnp oracle for the fused two-tier state push (Faasm §4.2).

A push moves `delta = local - base` from the local tier to the global tier.
The compressed variant quantises the delta to int8 with one f32 scale per
128-lane row — what actually crosses the pod interconnect.
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize_delta_ref(local, base):
    """local/base: (R, 128) f32.  Returns (q int8 (R,128), scales f32 (R, 1))."""
    delta = local.astype(jnp.float32) - base.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(delta), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(delta / scale), -127, 127).astype(jnp.int8)
    return q, scale


def apply_delta_ref(global_val, q, scale):
    """global_val: (R, 128); q: (R,128) int8; scale: (R,1).  Returns new global."""
    return (global_val.astype(jnp.float32)
            + q.astype(jnp.float32) * scale).astype(global_val.dtype)


def push_ref(local, base, global_val):
    """Uncompressed fused push: global += (local - base)."""
    delta = local.astype(jnp.float32) - base.astype(jnp.float32)
    return (global_val.astype(jnp.float32) + delta).astype(global_val.dtype)
