"""Host-native fused wire codec — pure numpy, no JAX import.

``kernels/state_push/ops.py`` is the right home for device-resident values,
but for host-resident numpy replicas the JAX dispatch round-trip *is* the
cost: at 64 KB the eager ``_to_rows`` → jit → ``np.asarray`` chain has a
~1.7 ms floor that dwarfs the math.  This module is the fast path
``ops.quantize_delta`` takes when both operands are plain ``np.ndarray`` on
an ``xla`` (host) backend: one chunked pass that fuses delta, per-row absmax
scale, quantise, dequantise and error-feedback residual, writing straight
into preallocated wire buffers.

Chunking (``chunk_rows`` 128-lane rows at a time) keeps the working set in
cache and doubles as the pipelining unit: each chunk's quantised payload is
complete — and readable by a wire writer — while the next chunk is still
being encoded, because scales are per-row and chunk boundaries sit on row
boundaries (the output is bitwise identical for any chunk size).

Kept JAX-free on purpose: ``scripts/check_jax_pin.py`` exercises these entry
points *before* importing jax to prove the host wire path cannot be stalled
by device runtime initialisation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

LANES = 128
DEFAULT_CHUNK_ROWS = 1024  # 512 KB of f32 per chunk — L2-resident on host CPUs

# float8_e4m3fn: max finite 448, no inf — values beyond +-448 cast to NaN, so
# the encoder must clip codes before the cast.  ml_dtypes ships with jax but
# the import is gated so a numpy-only environment still gets int8/int4 tiers.
FP8_MAX = 448.0
try:
    from ml_dtypes import float8_e4m3fn as _fp8_dtype
except ImportError:  # pragma: no cover - ml_dtypes ships with the pinned jax
    _fp8_dtype = None


def fp8_available() -> bool:
    return _fp8_dtype is not None


def fp8_dtype():
    if _fp8_dtype is None:
        raise RuntimeError("ml_dtypes not available: fp8 wire tier disabled")
    return _fp8_dtype


def rows_for(numel: int) -> int:
    return max(1, -(-numel // LANES))


def _flat_f32(x: np.ndarray) -> np.ndarray:
    flat = x.reshape(-1)
    if flat.dtype != np.float32:
        flat = flat.astype(np.float32)
    return flat


def encode_quant(eff: np.ndarray, base: Optional[np.ndarray] = None, *,
                 qmax: int = 127, chunk_rows: int = DEFAULT_CHUNK_ROWS,
                 ) -> Tuple[np.ndarray, np.ndarray, int, np.ndarray]:
    """Fused quantise of ``eff - base`` to signed codes in ``[-qmax, qmax]``.

    ``base=None`` means a zero base (pull-direction encode of a ready-made
    delta) — no zeros array is materialised.  Returns
    ``(q int8 (R,128), scales f32 (R,1), numel, residual f32 (numel,))``
    where ``residual = delta - q*scales`` is the error-feedback carry.  The pad
    region (rows*128 − numel) encodes to zero-delta so applying it is a no-op.
    """
    eff_f = _flat_f32(eff)
    base_f = _flat_f32(base) if base is not None else None
    n = eff_f.size
    rows = rows_for(n)
    q = np.empty((rows, LANES), np.int8)
    scales = np.empty((rows, 1), np.float32)
    residual = np.empty(rows * LANES, np.float32)
    cr = max(1, min(chunk_rows, rows))
    scratch = np.empty((cr, LANES), np.float32)
    qmax_f = np.float32(qmax)
    eps = np.float32(1e-12)
    for r0 in range(0, rows, cr):
        r1 = min(r0 + cr, rows)
        i0, i1 = r0 * LANES, min(r1 * LANES, n)
        m = i1 - i0
        ch = scratch[: r1 - r0]
        flat = ch.reshape(-1)
        if base_f is None:
            np.copyto(flat[:m], eff_f[i0:i1])
        else:
            np.subtract(eff_f[i0:i1], base_f[i0:i1], out=flat[:m])
        if m < flat.size:
            flat[m:] = 0.0
        sc = scales[r0:r1]
        np.max(np.abs(ch), axis=1, keepdims=True, out=sc)
        np.divide(sc, qmax_f, out=sc)
        np.maximum(sc, eps, out=sc)
        rch = residual[r0 * LANES: r1 * LANES].reshape(r1 - r0, LANES)
        np.copyto(rch, ch)                      # stash delta
        np.divide(ch, sc, out=ch)
        np.rint(ch, out=ch)
        np.clip(ch, -qmax_f, qmax_f, out=ch)
        qc = q[r0:r1]
        qc[...] = ch                            # integral f32 -> int8
        np.multiply(qc, sc, out=ch)             # dequantised carry
        np.subtract(rch, ch, out=rch)           # residual = delta - deq
    return q, scales, n, residual[:n]


def encode_fp8(eff: np.ndarray, base: Optional[np.ndarray] = None, *,
               chunk_rows: int = DEFAULT_CHUNK_ROWS,
               ) -> Tuple[np.ndarray, np.ndarray, int, np.ndarray]:
    """Fused fp8 (e4m3fn) encode of ``eff - base`` (``base=None`` → zero base).

    Returns ``(q fp8 (R,128), scales f32 (R,1), numel, residual f32 (numel,))``.
    Codes are clipped to ±``FP8_MAX`` *before* the cast — e4m3fn has no inf,
    so an unclipped overflow would silently become NaN on the wire.
    """
    dt = fp8_dtype()
    eff_f = _flat_f32(eff)
    base_f = _flat_f32(base) if base is not None else None
    n = eff_f.size
    rows = rows_for(n)
    q = np.empty((rows, LANES), dt)
    scales = np.empty((rows, 1), np.float32)
    residual = np.empty(rows * LANES, np.float32)
    cr = max(1, min(chunk_rows, rows))
    scratch = np.empty((cr, LANES), np.float32)
    fmax = np.float32(FP8_MAX)
    eps = np.float32(1e-12)
    for r0 in range(0, rows, cr):
        r1 = min(r0 + cr, rows)
        i0, i1 = r0 * LANES, min(r1 * LANES, n)
        m = i1 - i0
        ch = scratch[: r1 - r0]
        flat = ch.reshape(-1)
        if base_f is None:
            np.copyto(flat[:m], eff_f[i0:i1])
        else:
            np.subtract(eff_f[i0:i1], base_f[i0:i1], out=flat[:m])
        if m < flat.size:
            flat[m:] = 0.0
        sc = scales[r0:r1]
        np.max(np.abs(ch), axis=1, keepdims=True, out=sc)
        np.divide(sc, fmax, out=sc)
        np.maximum(sc, eps, out=sc)
        rch = residual[r0 * LANES: r1 * LANES].reshape(r1 - r0, LANES)
        np.copyto(rch, ch)
        np.divide(ch, sc, out=ch)
        np.clip(ch, -fmax, fmax, out=ch)
        qc = q[r0:r1]
        qc[...] = ch                            # f32 -> fp8 (rounds to e4m3fn)
        np.multiply(qc.astype(np.float32), sc, out=ch)
        np.subtract(rch, ch, out=rch)
    return q, scales, n, residual[:n]


def decode_rows(payload: np.ndarray, scales: np.ndarray, numel: int
                ) -> np.ndarray:
    """Decode a (R,128) payload (int8 or fp8) back to the flat f32 delta."""
    return (payload.astype(np.float32) * scales).reshape(-1)[:numel]


def pack_int4(q: np.ndarray) -> np.ndarray:
    """Pack (R,128) int8 codes in [-7,7] into (R,64) uint8 nibble pairs.

    Lane 2k goes to the low nibble, lane 2k+1 to the high nibble."""
    lo = q[:, 0::2].astype(np.uint8) & 0x0F
    hi = (q[:, 1::2].astype(np.uint8) & 0x0F) << 4
    return lo | hi


def unpack_int4(packed: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pack_int4`: (R,64) uint8 → (R,128) int8 in [-8,7]."""
    rows = packed.shape[0]
    q = np.empty((rows, 2 * packed.shape[1]), np.int8)
    # shift-left-then-arithmetic-shift-right sign-extends the nibble
    q[:, 0::2] = (packed << 4).astype(np.int8) >> 4
    q[:, 1::2] = packed.astype(np.int8) >> 4
    return q


def encode_exact(eff: np.ndarray, base: np.ndarray, *,
                 chunk_rows: int = DEFAULT_CHUNK_ROWS) -> np.ndarray:
    """Chunked exact delta: flat f32 ``eff - base`` into a fresh buffer.

    The chunk loop exists for symmetry with the quantised encoders — each
    completed chunk of the output is final while later chunks encode."""
    eff_f = _flat_f32(eff)
    base_f = _flat_f32(base)
    n = eff_f.size
    out = np.empty(n, np.float32)
    step = max(LANES, chunk_rows * LANES)
    for i0 in range(0, n, step):
        i1 = min(i0 + step, n)
        np.subtract(eff_f[i0:i1], base_f[i0:i1], out=out[i0:i1])
    return out


def usable(eff, base) -> bool:
    """True when both operands can take the host-native path: plain numpy
    (or scalar-strided views) — never device arrays, which must stay on
    device end to end."""
    return (type(eff) is np.ndarray or isinstance(eff, np.ndarray)) and \
           (type(base) is np.ndarray or isinstance(base, np.ndarray))
