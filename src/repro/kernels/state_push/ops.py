"""Jitted wrappers for the fused state push, handling arbitrary shapes.

Arrays are flattened and padded to (rows, 128); the pad region quantises to
zero-delta so applying a padded push is a no-op on the pad.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import resolve_backend, round_up
from repro.kernels.state_push import ref as _ref
from repro.kernels.state_push.kernel import (LANES, apply_delta_pallas,
                                             push_pallas, quantize_delta_pallas)

# the xla path is the hot CPU-host wire codec (LocalTier.push_delta calls it
# per push): jit once, jax caches the executable per shape
_quantize_ref = jax.jit(_ref.quantize_delta_ref)
_apply_ref = jax.jit(_ref.apply_delta_ref)
_push_ref = jax.jit(_ref.push_ref)


def _to_rows(x):
    flat = jnp.ravel(x).astype(jnp.float32)
    n = flat.shape[0]
    rows = max(1, round_up(n, LANES) // LANES)
    padded = jnp.pad(flat, (0, rows * LANES - n))
    return padded.reshape(rows, LANES), n


def _block_rows(rows: int) -> int:
    for b in (256, 64, 8, 1):
        if rows % b == 0:
            return b
    return 1


def quantize_delta(local, base, *, backend: str | None = None):
    """Any-shape fused delta quantisation.  Returns (q (R,128) int8, scales (R,1),
    original_numel) — the wire format of a compressed push."""
    b = resolve_backend(backend)
    lr, n = _to_rows(local)
    br, _ = _to_rows(base)
    if b == "xla":
        q, s = _quantize_ref(lr, br)
    else:
        q, s = quantize_delta_pallas(lr, br, block_rows=_block_rows(lr.shape[0]),
                                     interpret=(b == "pallas_interpret"))
    return q, s, n


def dequantize(q, scales, numel: int):
    """Decode a wire tuple back to the flat f32 delta of length ``numel``.

    The pad region (rows*128 − numel) quantises to zero-delta, so the trim
    here drops only zeros."""
    return (q.astype(jnp.float32) * scales).reshape(-1)[:numel]


def wire_nbytes(q, scales) -> int:
    """Bytes the compressed push actually moves: int8 payload + f32 scales."""
    return int(q.size) + int(scales.size) * 4


def _apply_wire(value, q, scales, backend: str | None):
    """Shared decode/apply: ``value += q·scale`` (any shape), one fused pass.

    The single home of the wire-apply dispatch for both directions —
    :func:`apply_delta` (push: global buffer) and :func:`apply_pull`
    (pull/broadcast: replica or device value)."""
    b = resolve_backend(backend)
    shape, dtype = value.shape, value.dtype
    gr, n = _to_rows(value)
    if b == "xla":
        out = _apply_ref(gr, q, scales)
    else:
        out = apply_delta_pallas(gr, q, scales,
                                 block_rows=_block_rows(gr.shape[0]),
                                 interpret=(b == "pallas_interpret"))
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)


def apply_delta(global_val, q, scales, *, backend: str | None = None):
    """Apply a compressed push to a value of any shape."""
    return _apply_wire(global_val, q, scales, backend)


def encode_pull(new, base, *, backend: str | None = None):
    """Pull-direction encode: quantise ``new − base`` (the delta a warm
    replica at ``base`` needs to catch up to ``new``) with the same fused
    quantise kernel the push wire uses.  Returns the ``(q, scales, numel)``
    wire tuple — the symmetric twin of :func:`quantize_delta`."""
    return quantize_delta(new, base, backend=backend)


def apply_pull(value, q, scales, *, backend: str | None = None):
    """Pull-direction decode/apply: ``replica += q·scale`` (any shape).

    Applies a pulled (or peer-broadcast) wire tuple onto a replica value —
    host- or device-resident — in one fused pass; the pad region quantises
    to zero-delta so the trim is a no-op beyond ``numel``.  Same kernel as
    :func:`apply_delta`, dispatched from the opposite side of the tier
    boundary."""
    return _apply_wire(value, q, scales, backend)


def push(local, base, global_val, *, backend: str | None = None):
    """Uncompressed fused push: global += local - base (any shape)."""
    b = resolve_backend(backend)
    shape, dtype = global_val.shape, global_val.dtype
    lr, n = _to_rows(local)
    br, _ = _to_rows(base)
    gr, _ = _to_rows(global_val)
    if b == "xla":
        out = _push_ref(lr, br, gr)
    else:
        out = push_pallas(lr, br, gr, block_rows=_block_rows(lr.shape[0]),
                          interpret=(b == "pallas_interpret"))
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)
