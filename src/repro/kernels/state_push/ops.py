"""Jitted wrappers for the fused state push, handling arbitrary shapes.

Arrays are flattened and padded to (rows, 128); the pad region quantises to
zero-delta so applying a padded push is a no-op on the pad.

Two encode paths, chosen per call:

* **host-native** (``hostcodec``): both operands are plain numpy and the
  resolved backend is ``xla`` — the math is a handful of cache-resident
  numpy passes, so the JAX dispatch round-trip (a ~1.7 ms floor at 64 KB)
  is pure overhead and is skipped entirely.
* **device**: anything holding a device array goes through **one** fused
  jitted executable (flatten + pad + quantise + residual in a single
  dispatch, cached by jax per ``(shape, dtype, qmax)`` and per backend), and
  large values are encoded in row chunks whose copy-out is pipelined with
  the next chunk's dispatch — async dispatch means chunk N quantises on
  device while chunk N−1's payload is crossing to the host.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.common import resolve_backend, round_up
from repro.kernels.state_push import hostcodec
from repro.kernels.state_push import ref as _ref
from repro.kernels.state_push.kernel import (LANES, apply_delta_pallas,
                                             push_pallas,
                                             quantize_delta_pallas,
                                             quantize_fp8_pallas)

# the xla path is the hot CPU-host wire codec (LocalTier.push_delta calls it
# per push): jit once, jax caches the executable per shape
_quantize_ref = jax.jit(_ref.quantize_delta_ref, static_argnums=(2,))
_apply_ref = jax.jit(_ref.apply_delta_ref)
_push_ref = jax.jit(_ref.push_ref)

# rows a device-side encode processes per dispatch when chunking: 2 MB of f32
# keeps enough compute in flight to hide each chunk's host copy-out
DEVICE_CHUNK_ROWS = 4096


def _to_rows(x):
    flat = jnp.ravel(x).astype(jnp.float32)
    n = flat.shape[0]
    rows = max(1, round_up(n, LANES) // LANES)
    padded = jnp.pad(flat, (0, rows * LANES - n))
    return padded.reshape(rows, LANES), n


def _block_rows(rows: int) -> int:
    for b in (256, 64, 8, 1):
        if rows % b == 0:
            return b
    return 1


@functools.partial(jax.jit, static_argnames=("qmax", "with_residual"))
def _encode_fused(local, base, qmax, with_residual):
    """Single-dispatch device encode: flatten/pad/quantise (+ residual) in one
    executable.  jax caches the compiled program per (shape, dtype, qmax)."""
    lr, _ = _to_rows(local)
    br, _ = _to_rows(base)
    q, s = _ref.quantize_delta_ref(lr, br, float(qmax))
    if not with_residual:
        return q, s
    resid = (lr - br) - q.astype(jnp.float32) * s
    return q, s, resid


@functools.partial(jax.jit, static_argnames=("with_residual",))
def _encode_fp8_fused(local, base, with_residual):
    lr, _ = _to_rows(local)
    br, _ = _to_rows(base)
    q, s = _ref.quantize_fp8_ref(lr, br)
    if not with_residual:
        return q, s
    resid = (lr - br) - q.astype(jnp.float32) * s
    return q, s, resid


def _device_encode(eff, base, *, qmax, fp8, b, with_residual):
    """Device-path encode returning host numpy wire buffers.

    Values above ``DEVICE_CHUNK_ROWS`` rows are encoded chunk by chunk:
    every chunk's kernel is dispatched before any copy-out blocks, so the
    device quantises chunk N while chunk N−1 streams to the host.  Scales
    are per-row and chunks split on row boundaries, so the result is
    bitwise identical to a single-shot encode."""
    n = int(np.prod(np.shape(eff))) if np.shape(eff) else 1
    rows = hostcodec.rows_for(n)
    if b != "xla":
        lr, _ = _to_rows(eff)
        br, _ = _to_rows(base)
        interp = b == "pallas_interpret"
        blk = _block_rows(rows)
        if fp8:
            q, s = quantize_fp8_pallas(lr, br, block_rows=blk, interpret=interp)
        else:
            q, s = quantize_delta_pallas(lr, br, block_rows=blk,
                                         interpret=interp, qmax=float(qmax))
        qn, sn = np.asarray(q), np.asarray(s)
        if not with_residual:
            return qn, sn, n, None
        deltar = np.asarray(lr - br)
        resid = deltar - qn.astype(np.float32) * sn
        return qn, sn, n, resid.reshape(-1)[:n]
    if rows <= DEVICE_CHUNK_ROWS:
        out = (_encode_fp8_fused(eff, base, with_residual) if fp8
               else _encode_fused(eff, base, qmax, with_residual))
        if with_residual:
            q, s, resid = out
            return (np.asarray(q), np.asarray(s), n,
                    np.asarray(resid).reshape(-1)[:n])
        q, s = out
        return np.asarray(q), np.asarray(s), n, None
    # chunked: dispatch everything (async), then copy out in order
    lr, _ = _to_rows(eff)
    br, _ = _to_rows(base)
    parts = []
    for r0 in range(0, rows, DEVICE_CHUNK_ROWS):
        r1 = min(r0 + DEVICE_CHUNK_ROWS, rows)
        parts.append((r0, r1,
                      _encode_fp8_fused(lr[r0:r1], br[r0:r1], with_residual)
                      if fp8 else
                      _encode_fused(lr[r0:r1], br[r0:r1], qmax, with_residual)))
    qdt = hostcodec.fp8_dtype() if fp8 else np.int8
    qn = np.empty((rows, LANES), qdt)
    sn = np.empty((rows, 1), np.float32)
    resid = np.empty(rows * LANES, np.float32) if with_residual else None
    for r0, r1, out in parts:
        if with_residual:
            qc, sc, rc = out
            resid[r0 * LANES: r1 * LANES] = np.asarray(rc).reshape(-1)
        else:
            qc, sc = out
        qn[r0:r1] = np.asarray(qc)
        sn[r0:r1] = np.asarray(sc)
    return qn, sn, n, (resid[:n] if with_residual else None)


def encode_quant(eff, base, *, qmax: int = 127, backend: str | None = None,
                 with_residual: bool = True):
    """Fused wire encode for the integer tiers: quantise ``eff − base`` to
    signed codes in ``[-qmax, qmax]`` and (optionally) the error-feedback
    residual, in one pass.  Returns host numpy
    ``(q int8 (R,128), scales f32 (R,1), numel, residual f32 (numel,) | None)``.

    Host-resident numpy operands on the ``xla`` backend skip JAX entirely
    (:mod:`.hostcodec`); device operands take one fused cached executable
    with chunk-pipelined copy-out."""
    b = resolve_backend(backend)
    if b == "xla" and (base is None or hostcodec.usable(eff, base)) \
            and isinstance(eff, np.ndarray):
        q, s, n, resid = hostcodec.encode_quant(eff, base, qmax=qmax)
        return q, s, n, (resid if with_residual else None)
    if base is None:
        base = jnp.zeros_like(jnp.ravel(eff))
    return _device_encode(eff, base, qmax=qmax, fp8=False, b=b,
                          with_residual=with_residual)


def encode_fp8(eff, base, *, backend: str | None = None,
               with_residual: bool = True):
    """fp8 (e4m3fn) twin of :func:`encode_quant` — same path selection."""
    b = resolve_backend(backend)
    if b == "xla" and (base is None or hostcodec.usable(eff, base)) \
            and isinstance(eff, np.ndarray):
        q, s, n, resid = hostcodec.encode_fp8(eff, base)
        return q, s, n, (resid if with_residual else None)
    if base is None:
        base = jnp.zeros_like(jnp.ravel(eff))
    return _device_encode(eff, base, qmax=0, fp8=True, b=b,
                          with_residual=with_residual)


def quantize_delta(local, base, *, backend: str | None = None,
                   qmax: int = 127):
    """Any-shape fused delta quantisation.  Returns (q (R,128) int8, scales (R,1),
    original_numel) — the wire format of a compressed push."""
    b = resolve_backend(backend)
    if b == "xla" and hostcodec.usable(local, base):
        q, s, n, _ = hostcodec.encode_quant(local, base, qmax=qmax)
        return q, s, n
    lr, n = _to_rows(local)
    br, _ = _to_rows(base)
    if b == "xla":
        q, s = _quantize_ref(lr, br, float(qmax))
    else:
        q, s = quantize_delta_pallas(lr, br, block_rows=_block_rows(lr.shape[0]),
                                     interpret=(b == "pallas_interpret"),
                                     qmax=float(qmax))
    return q, s, n


def dequantize(q, scales, numel: int):
    """Decode a wire tuple back to the flat f32 delta of length ``numel``.

    The pad region (rows*128 − numel) quantises to zero-delta, so the trim
    here drops only zeros."""
    if isinstance(q, np.ndarray) and isinstance(scales, np.ndarray):
        return hostcodec.decode_rows(q, scales, numel)
    return (q.astype(jnp.float32) * scales).reshape(-1)[:numel]


def wire_nbytes(q, scales) -> int:
    """Bytes the compressed push actually moves: int8 payload + f32 scales."""
    return int(q.size) + int(scales.size) * 4


def _apply_wire(value, q, scales, backend: str | None):
    """Shared decode/apply: ``value += q·scale`` (any shape), one fused pass.

    The single home of the wire-apply dispatch for both directions —
    :func:`apply_delta` (push: global buffer) and :func:`apply_pull`
    (pull/broadcast: replica or device value)."""
    b = resolve_backend(backend)
    shape, dtype = value.shape, value.dtype
    gr, n = _to_rows(value)
    if b == "xla":
        out = _apply_ref(gr, q, scales)
    else:
        out = apply_delta_pallas(gr, q, scales,
                                 block_rows=_block_rows(gr.shape[0]),
                                 interpret=(b == "pallas_interpret"))
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)


def apply_delta(global_val, q, scales, *, backend: str | None = None):
    """Apply a compressed push to a value of any shape."""
    return _apply_wire(global_val, q, scales, backend)


def encode_pull(new, base, *, backend: str | None = None):
    """Pull-direction encode: quantise ``new − base`` (the delta a warm
    replica at ``base`` needs to catch up to ``new``) with the same fused
    quantise kernel the push wire uses.  Returns the ``(q, scales, numel)``
    wire tuple — the symmetric twin of :func:`quantize_delta`."""
    return quantize_delta(new, base, backend=backend)


def apply_pull(value, q, scales, *, backend: str | None = None):
    """Pull-direction decode/apply: ``replica += q·scale`` (any shape).

    Applies a pulled (or peer-broadcast) wire tuple onto a replica value —
    host- or device-resident — in one fused pass; the pad region quantises
    to zero-delta so the trim is a no-op beyond ``numel``.  Same kernel as
    :func:`apply_delta`, dispatched from the opposite side of the tier
    boundary."""
    return _apply_wire(value, q, scales, backend)


def push(local, base, global_val, *, backend: str | None = None):
    """Uncompressed fused push: global += local - base (any shape)."""
    b = resolve_backend(backend)
    shape, dtype = global_val.shape, global_val.dtype
    lr, n = _to_rows(local)
    br, _ = _to_rows(base)
    gr, _ = _to_rows(global_val)
    if b == "xla":
        out = _push_ref(lr, br, gr)
    else:
        out = push_pallas(lr, br, gr, block_rows=_block_rows(lr.shape[0]),
                          interpret=(b == "pallas_interpret"))
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)
