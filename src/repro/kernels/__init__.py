"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships: ``kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jitted wrapper with xla / pallas / pallas_interpret
dispatch) and ``ref.py`` (pure-jnp oracle used by the allclose test sweeps).
"""
from repro.kernels.common import BACKENDS, default_backend, resolve_backend

__all__ = ["BACKENDS", "default_backend", "resolve_backend"]
