"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships: ``kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jitted wrapper with xla / pallas / pallas_interpret
dispatch) and ``ref.py`` (pure-jnp oracle used by the allclose test sweeps).

Re-exports are lazy (PEP 562): importing this package must not import jax,
so the jax-free host wire codec (``state_push.hostcodec``) stays importable
before any device runtime initialisation (``scripts/check_jax_pin.py``
relies on this ordering).
"""

__all__ = ["BACKENDS", "default_backend", "resolve_backend"]


def __getattr__(name):
    if name in __all__:
        from repro.kernels import common
        return getattr(common, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
