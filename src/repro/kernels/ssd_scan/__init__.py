from repro.kernels.ssd_scan.ops import ssd, ssd_step
from repro.kernels.ssd_scan.ref import ssd_ref

__all__ = ["ssd", "ssd_step", "ssd_ref"]
