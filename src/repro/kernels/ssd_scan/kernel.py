"""Pallas TPU kernel for the chunked Mamba2 SSD scan.

TPU-native layout of the SSD algorithm (Dao & Gu, 2024, §6):

  * grid = (batch, heads, chunks); the chunk dimension is sequential
    (``arbitrary``) and the inter-chunk recurrent state (P, N) lives in VMEM
    scratch across chunk steps — HBM traffic is one read of x/dt/B/C and one
    write of y, with no state round-trips.
  * the intra-chunk quadratic term (C·Bᵀ ⊙ L) and the chunk-state update are
    (Q×N)·(N×Q) and (P×Q)·(Q×N) matmuls — MXU work, with Q (chunk length),
    N (state) and P (head dim) chosen as multiples of the 128 MXU tile where
    the model config allows.
  * all decays are exp of non-positive cumulative sums (A < 0, dt > 0), so the
    kernel is overflow-free in f32 scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import tpu_compiler_params


def _ssd_kernel(A_ref, D_ref, x_ref, dt_ref, B_ref, C_ref, init_ref,
                y_ref, final_ref, state_ref, *, chunk: int, n_chunks: int):
    h = pl.program_id(1)
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = init_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)               # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)                 # (Q,)
    Bm = B_ref[0, :, 0, :].astype(jnp.float32)               # (Q, N)
    Cm = C_ref[0, :, 0, :].astype(jnp.float32)               # (Q, N)
    A_h = A_ref[h]
    D_h = D_ref[h]

    dA = dt * A_h                                             # (Q,) <= 0
    cs = jnp.cumsum(dA)                                       # inclusive
    seg = cs[:, None] - cs[None, :]                           # (Q, Q)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # mask BEFORE exp: upper-triangular seg is positive and would overflow
    L = jnp.exp(jnp.where(tri, seg, -jnp.inf))

    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q, Q)
    dtx = x * dt[:, None]                                          # (Q, P)
    y = jax.lax.dot_general(CB * L, dtx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q, P)

    state = state_ref[...]                                         # (P, N)
    y = y + jnp.exp(cs)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                        # (Q,N)x(P,N)->(Q,P)
    y = y + D_h * x
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    decay_out = jnp.exp(cs[-1] - cs)                               # (Q,)
    new_state = jnp.exp(cs[-1]) * state + jax.lax.dot_general(
        dtx * decay_out[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                        # (P, N)
    state_ref[...] = new_state

    @pl.when(c == n_chunks - 1)
    def _final():
        final_ref[0, 0] = new_state.astype(final_ref.dtype)


def ssd_pallas(x, dt, A, B, C, D_skip, initial_state, *, chunk: int,
               interpret: bool = False):
    """Chunked SSD.  S must be a multiple of ``chunk`` (ops.py pads)."""
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    assert S % chunk == 0
    n_chunks = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Bt, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c, A, D: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c, A, D: (b, c, h)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c, A, D: (b, c, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c, A, D: (b, c, h // rep, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c, A, D: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c, A, D: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c, A, D: (b, h, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
    )
    compiler_params = tpu_compiler_params(("parallel", "parallel", "arbitrary"))
    y, final_state = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((Bt, H, P, N), jnp.float32),
        ],
        compiler_params=compiler_params,
        interpret=interpret,
    )(A.astype(jnp.float32), D_skip.astype(jnp.float32), x, dt, B, C,
      initial_state)
    return y, final_state
