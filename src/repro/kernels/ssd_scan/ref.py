"""Pure-jnp oracle for the Mamba2 SSD (state-space duality) recurrence.

Sequential per-step scan — O(S) steps, used only at test scale to validate the
chunked XLA path and the Pallas kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C, D_skip, *, initial_state=None):
    """Selective-state-space recurrence.

    state_s = exp(dt_s * A) * state_{s-1} + dt_s * (x_s ⊗ B_s)
    y_s     = C_s · state_s + D * x_s

    Args:
      x:  (Bt, S, H, P)   per-head inputs
      dt: (Bt, S, H)      positive step sizes (softplus already applied)
      A:  (H,)            negative per-head decay rates
      B:  (Bt, S, G, N)   input projections (G groups, H % G == 0)
      C:  (Bt, S, G, N)   output projections
      D_skip: (H,)        skip connection
      initial_state: (Bt, H, P, N) or None

    Returns: y (Bt, S, H, P) in x.dtype, final_state (Bt, H, P, N) f32.
    """
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)   # (Bt, S, H, N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)
    Af = A.astype(jnp.float32)

    state0 = (jnp.zeros((Bt, H, P, N), jnp.float32) if initial_state is None
              else initial_state.astype(jnp.float32))

    def step(state, inp):
        x_s, dt_s, B_s, C_s = inp                          # (Bt,H,P) (Bt,H) (Bt,H,N)
        decay = jnp.exp(dt_s * Af)[..., None, None]        # (Bt,H,1,1)
        state = decay * state + (dt_s[..., None] * x_s)[..., None] * B_s[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", state, C_s)
        return state, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    final_state, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1) + D_skip.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype), final_state
