"""Jitted SSD wrapper: chunked XLA path, Pallas dispatch, and the decode step.

The XLA path is the same chunked algorithm as the kernel, expressed as a
``lax.scan`` over chunks so peak memory stays O(chunk²·H) — this is what the
dry-run lowers for the SSM archs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_axis_to, resolve_backend, round_up
from repro.kernels.ssd_scan.kernel import ssd_pallas


def ssd(x, dt, A, B, C, D_skip, *, chunk: int = 256, initial_state=None,
        backend: str | None = None):
    """Chunked SSD scan.  Shapes as in ``ref.ssd_ref``; S is padded internally.

    Padding note: padded steps use dt=0 → decay exp(0·A)=1 and zero input, so
    the recurrent state is unchanged and padded outputs are discarded.
    """
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    if initial_state is None:
        initial_state = jnp.zeros((Bt, H, P, N), jnp.float32)

    b = resolve_backend(backend)
    chunk = min(chunk, max(16, 1 << (S - 1).bit_length()))   # don't over-chunk tiny S
    S_p = round_up(S, chunk)
    xp = pad_axis_to(x, 1, S_p)
    dtp = pad_axis_to(dt, 1, S_p)
    Bp = pad_axis_to(B, 1, S_p)
    Cp = pad_axis_to(C, 1, S_p)

    if b == "xla":
        y, final = _ssd_xla(xp, dtp, A, Bp, Cp, D_skip, initial_state, chunk)
    else:
        y, final = ssd_pallas(xp, dtp, A, Bp, Cp, D_skip, initial_state,
                              chunk=chunk, interpret=(b == "pallas_interpret"))
    return y[:, :S], final


@functools.partial(jax.jit, static_argnames=("chunk",))
def _ssd_xla(x, dt, A, B, C, D_skip, initial_state, chunk):
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    nc = S // chunk
    Q = chunk

    xf = x.astype(jnp.float32).reshape(Bt, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bt, nc, Q, H)
    Bf = B.astype(jnp.float32).reshape(Bt, nc, Q, G, N)
    Cf = C.astype(jnp.float32).reshape(Bt, nc, Q, G, N)
    Af = A.astype(jnp.float32)

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(state, inp):
        xc, dtc, Bc, Cc = inp              # (Bt,Q,H,P) (Bt,Q,H) (Bt,Q,G,N) x2
        dA = dtc * Af                       # (Bt,Q,H)
        cs = jnp.cumsum(dA, axis=1)         # inclusive
        seg = cs[:, :, None, :] - cs[:, None, :, :]            # (Bt,Q,Q,H)
        # mask BEFORE exp: upper-triangular seg is positive and would overflow
        L = jnp.exp(jnp.where(tri[None, :, :, None], seg, -jnp.inf))
        CB = jnp.einsum("bign,bjgn->bijg", Cc, Bc)               # (Bt,Q,Q,G)
        CBh = jnp.repeat(CB, rep, axis=3)                       # (Bt,Q,Q,H)
        scores = CBh * L
        dtx = xc * dtc[..., None]                                # (Bt,Q,H,P)
        y = jnp.einsum("bijh,bjhp->bihp", scores, dtx)
        # contribution of the incoming state
        Ch = jnp.repeat(Cc, rep, axis=2)                         # (Bt,Q,H,N)
        y = y + jnp.exp(cs)[..., None] * jnp.einsum("bihn,bhpn->bihp", Ch, state)
        # state update
        decay_out = jnp.exp(cs[:, -1:, :] - cs)                  # (Bt,Q,H)
        Bh = jnp.repeat(Bc, rep, axis=2)                         # (Bt,Q,H,N)
        new_state = jnp.exp(cs[:, -1, :])[..., None, None] * state + \
            jnp.einsum("bjhp,bjhn->bhpn", dtx * decay_out[..., None], Bh)
        return new_state, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    final, ys = jax.lax.scan(chunk_step, initial_state.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bt, S, H, P)
    y = y + D_skip.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), final


def ssd_step(state, x_t, dt_t, A, B_t, C_t, D_skip):
    """Single decode step of the SSD recurrence (pure jnp — O(H·P·N)).

    state: (Bt, H, P, N) f32; x_t: (Bt, H, P); dt_t: (Bt, H);
    B_t/C_t: (Bt, G, N).  Returns (y_t (Bt,H,P), new_state).
    """
    Bt, H, P, N = state.shape
    G = B_t.shape[1]
    rep = H // G
    xf = x_t.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    Bh = jnp.repeat(B_t.astype(jnp.float32), rep, axis=1)     # (Bt,H,N)
    Ch = jnp.repeat(C_t.astype(jnp.float32), rep, axis=1)
    decay = jnp.exp(dtf * A.astype(jnp.float32))[..., None, None]
    new_state = decay * state + (dtf[..., None] * xf)[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + D_skip.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x_t.dtype), new_state
