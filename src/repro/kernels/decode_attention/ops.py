"""Jitted decode-attention wrapper with backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import (NEG_INF, pad_axis_to, resolve_backend,
                                  round_up)
from repro.kernels.decode_attention.kernel import decode_attention_pallas


def decode_attention(q, k, v, lengths, *, scale: float | None = None,
                     backend: str | None = None, block_k: int = 512):
    """q: (B, H, D); k/v: (B, S, K, D); lengths: (B,) -> (B, H, D)."""
    b = resolve_backend(backend)
    if b == "xla":
        return _decode_xla(q, k, v, lengths, scale=scale)
    return _decode_pallas(q, k, v, lengths, scale=scale, block_k=block_k,
                          interpret=(b == "pallas_interpret"))


def _decode_xla(q, k, v, lengths, *, scale):
    """bf16 inputs stay bf16 (no materialised f32 KV copies); the score matmul
    accumulates in f32 via preferred_element_type — decode is HBM-bound, so
    the KV bytes read per token are the whole roofline."""
    B, H, D = q.shape
    _, S, K, _ = k.shape
    G = H // K
    if scale is None:
        scale = D ** -0.5
    qg = ((q.astype(jnp.float32) * scale).astype(q.dtype)).reshape(B, K, G, D)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(q.dtype),
                        preferred_element_type=jnp.float32)
    mask = jnp.arange(S)[None, :] >= lengths[:, None]
    logits = jnp.where(mask[:, None, None], NEG_INF, logits)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    out = out / denom
    return out.reshape(B, H, D).astype(q.dtype)


def _decode_pallas(q, k, v, lengths, *, scale, block_k, interpret):
    B, H, D = q.shape
    _, S, K, _ = k.shape
    G = H // K
    g_pad = max(8, round_up(G, 8))                       # sublane alignment
    qg = q.reshape(B, K, G, D)
    qg = pad_axis_to(qg, 2, g_pad)
    S_p = round_up(S, min(block_k, round_up(S, 8)))
    block_k = min(block_k, S_p)
    S_p = round_up(S_p, block_k)
    kp = pad_axis_to(k, 1, S_p)
    vp = pad_axis_to(v, 1, S_p)
    out = decode_attention_pallas(qg, kp, vp, lengths.astype(jnp.int32),
                                  scale=scale, block_k=block_k,
                                  interpret=interpret)
    return out[:, :, :G, :].reshape(B, H, D)
