"""Pallas TPU flash-decode kernel: one query token vs. a long KV cache.

Decode attention is purely memory-bound (arithmetic intensity ~1 FLOP/byte), so
the kernel is organised around streaming the KV cache through VMEM exactly once:

  * grid = (batch, kv_heads, kv_splits); the split dimension is sequential and
    carries online-softmax stats in VMEM scratch (flash-decode reduction).
  * all G = H/K query heads of one KV head are processed together as a (G, D)
    tile, so each streamed KV tile is reused G times from VMEM (the GQA
    arithmetic-intensity win: bytes/token divided by G).
  * per-sequence cache lengths arrive via scalar prefetch (SMEM) and mask the
    tail tile; whole splits past the length are elided with ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, cdiv, tpu_compiler_params

_MIN_LANES = 128


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                   scale: float, block_k: int, n_splits: int, g_pad: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)
    length = len_ref[b]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ik * block_k < length)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale            # (Gp, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)                     # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)   # (Gp, bk)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (g_pad, block_k), 1)
        s = jnp.where(k_pos >= length, NEG_INF, s)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(ik == n_splits - 1)
    def _finalize():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, lengths, *, scale: float | None = None,
                            block_k: int = 512, interpret: bool = False):
    """q: (B, K, Gp, D) grouped+padded queries; k/v: (B, S, K, D); lengths: (B,)."""
    B, K, Gp, D = q.shape
    _, S, _, _ = k.shape
    if scale is None:
        scale = D ** -0.5
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    n_splits = S // block_k

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               n_splits=n_splits, g_pad=Gp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, n_splits),
        in_specs=[
            pl.BlockSpec((1, 1, Gp, D), lambda b, h, ik, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik, lens: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik, lens: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Gp, D), lambda b, h, ik, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Gp, D), jnp.float32),
            pltpu.VMEM((Gp, _MIN_LANES), jnp.float32),
            pltpu.VMEM((Gp, _MIN_LANES), jnp.float32),
        ],
    )
    compiler_params = tpu_compiler_params(("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=compiler_params,
        interpret=interpret,
    )(lengths, q, k, v)
