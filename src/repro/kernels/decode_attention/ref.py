"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import NEG_INF


def decode_attention_ref(q, k, v, lengths, *, scale: float | None = None):
    """One new token per sequence attends to its KV cache.

    Args:
      q: (B, H, D) — current-token queries
      k, v: (B, S, K, D) — KV cache (positions >= lengths[b] are garbage)
      lengths: (B,) int32 — valid cache lengths (inclusive of current token)

    Returns: (B, H, D) in q.dtype.
    """
    B, H, D = q.shape
    _, S, K, _ = k.shape
    G = H // K
    if scale is None:
        scale = D ** -0.5
    qg = (q.astype(jnp.float32) * scale).reshape(B, K, G, D)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    mask = jnp.arange(S)[None, :] >= lengths[:, None]           # (B, S)
    logits = jnp.where(mask[:, None, None], NEG_INF, logits)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
