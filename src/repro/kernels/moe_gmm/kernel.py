"""Pallas TPU grouped-matmul kernel (dropless MoE expert compute).

A dense-dispatch MoE pays FLOPs for zero-padded capacity slots; a ragged
grouped matmul only multiplies real tokens.  TPU-native design:

  * tokens arrive sorted by expert with every group padded to a multiple of
    ``block_m`` (ops.py does this — waste is < block_m rows per expert instead
    of a whole capacity factor).
  * the (n_tiles,) tile→expert map is **scalar-prefetched into SMEM** and used
    by the weight BlockSpec index map, so each (block_m, d) token tile streams
    exactly its own expert's (d, block_n) weight tile into VMEM — the TPU
    analogue of megablocks' block-sparse matmul, expressed through Pallas
    index maps instead of CUDA block scheduling.
  * f32 MXU accumulation, bf16 in/out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import tpu_compiler_params


def _gmm_kernel(tile_expert_ref, x_ref, w_ref, o_ref):
    del tile_expert_ref  # consumed by the index maps
    x = x_ref[...]
    w = w_ref[0]
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def gmm_pallas(x, w, tile_expert, *, block_m: int, block_n: int,
               interpret: bool = False):
    """x: (T, d) with T % block_m == 0 and group-aligned rows;
    tile_expert: (T // block_m,) int32; w: (E, d, f)."""
    T, d = x.shape
    E, _, f = w.shape
    assert T % block_m == 0 and f % block_n == 0, (T, block_m, f, block_n)
    n_tiles = T // block_m
    nf = f // block_n

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles, nf),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j, te: (i, 0)),
            pl.BlockSpec((1, d, block_n), lambda i, j, te: (te[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, te: (i, j)),
    )
    compiler_params = tpu_compiler_params(("arbitrary", "arbitrary"))
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, f), x.dtype),
        compiler_params=compiler_params,
        interpret=interpret,
    )(tile_expert.astype(jnp.int32), x, w)
