"""Pure-jnp oracle for the grouped (per-expert) matmul."""
from __future__ import annotations

import jax.numpy as jnp


def gmm_ref(x, w, group_sizes):
    """Grouped matmul: rows of group e are multiplied by w[e].

    Args:
      x: (T, d) tokens sorted by expert
      w: (E, d, f) expert weights
      group_sizes: (E,) int32, sum(group_sizes) <= T (tail rows -> zeros)

    Returns: (T, f) f32-accumulated, cast to x.dtype.
    """
    T, d = x.shape
    E, _, f = w.shape
    offsets = jnp.cumsum(group_sizes)
    starts = offsets - group_sizes
    row = jnp.arange(T)
    y = jnp.zeros((T, f), jnp.float32)
    for e in range(E):
        in_group = (row >= starts[e]) & (row < offsets[e])
        ye = jnp.dot(x.astype(jnp.float32), w[e].astype(jnp.float32))
        y = jnp.where(in_group[:, None], ye, y)
    return y.astype(x.dtype)
