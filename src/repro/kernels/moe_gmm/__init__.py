from repro.kernels.moe_gmm.ops import gmm
from repro.kernels.moe_gmm.ref import gmm_ref

__all__ = ["gmm", "gmm_ref"]
