"""Jitted grouped-matmul wrapper: ragged padding + backend dispatch.

``backend="xla"`` uses ``jax.lax.ragged_dot`` (native HLO ragged matmul);
the Pallas path pads every group to ``block_m`` rows and runs the
scalar-prefetch kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import cdiv, resolve_backend, round_up
from repro.kernels.moe_gmm.kernel import gmm_pallas


def gmm(x, w, group_sizes, *, backend: str | None = None,
        block_m: int = 128, block_n: int = 128):
    """Grouped matmul (see ref.gmm_ref).  x rows must be sorted by expert."""
    b = resolve_backend(backend)
    if b == "xla":
        return jax.lax.ragged_dot(x, w, group_sizes.astype(jnp.int32))
    return _gmm_ragged_pallas(x, w, group_sizes, block_m=block_m,
                              block_n=block_n,
                              interpret=(b == "pallas_interpret"))


def _gmm_ragged_pallas(x, w, group_sizes, *, block_m, block_n, interpret):
    T, d = x.shape
    E, _, f = w.shape
    block_n = min(block_n, f)
    block_m = min(block_m, max(8, T))
    f_p = round_up(f, block_n)
    if f_p != f:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, f_p - f)))

    # Pad each group to a multiple of block_m: padded row p of group e maps to
    # source row (start_e + offset) when offset < size_e, else a zero row.
    sizes = group_sizes.astype(jnp.int32)
    starts = jnp.cumsum(sizes) - sizes
    padded_sizes = ((sizes + block_m - 1) // block_m) * block_m
    padded_starts = jnp.cumsum(padded_sizes) - padded_sizes
    T_pad = T + E * block_m                      # static upper bound
    T_pad = round_up(T_pad, block_m)

    prow = jnp.arange(T_pad, dtype=jnp.int32)
    # group of each padded row (rows past the last group land in E-1, masked off)
    g = jnp.searchsorted(jnp.cumsum(padded_sizes), prow, side="right")
    g = jnp.minimum(g, E - 1).astype(jnp.int32)
    offset = prow - padded_starts[g]
    valid = offset < sizes[g]
    src = jnp.where(valid, starts[g] + offset, 0)
    xp = jnp.where(valid[:, None], x[src], 0)

    tile_expert = g[::block_m]                   # (T_pad // block_m,)
    yp = gmm_pallas(xp, w, tile_expert, block_m=block_m, block_n=block_n,
                    interpret=interpret)
    # Scatter padded rows back to the original layout (padding rows add zeros).
    y = jnp.zeros((T, f_p), yp.dtype)
    y = y.at[src].add(jnp.where(valid[:, None], yp, 0))
    return y[:, :f]
