"""Jitted flash-attention wrapper with backend dispatch and a flash backward.

``backend="xla"`` is a blocked online-softmax implementation in pure jnp
(a ``lax.scan`` over KV tiles) with a **custom VJP**: the backward pass
recomputes each tile's probabilities from the saved softmax stats (m, l)
instead of letting JAX stack per-tile residuals — peak memory stays
O(Sq·block_k) in both directions (the FlashAttention-2 backward).  This is
what the dry-run lowers, so the roofline's memory term reflects it.

``backend="pallas"`` calls the TPU kernel (forward; training uses the xla
path's VJP); ``"pallas_interpret"`` runs the kernel body on CPU for tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF, resolve_backend, round_up, pad_axis_to
from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    q_offset: int = 0, backend: str | None = None,
                    block_q: int = 128, block_k: int = 512):
    """Memory-bounded attention.  Shapes as in ``ref.attention_ref``."""
    b = resolve_backend(backend)
    if b == "xla":
        if scale is None:
            scale = q.shape[-1] ** -0.5
        return _flash_xla(q, k, v, causal, float(scale), q_offset,
                          min(block_k, k.shape[1]))
    return _flash_pallas_padded(q, k, v, causal=causal, scale=scale,
                                q_offset=q_offset, block_q=block_q,
                                block_k=min(block_k, 128),
                                interpret=(b == "pallas_interpret"))


def _flash_pallas_padded(q, k, v, *, causal, scale, q_offset, block_q, block_k,
                         interpret):
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    Sq_p = round_up(Sq, min(block_q, round_up(Sq, 8)))
    block_q = min(block_q, Sq_p)
    Sq_p = round_up(Sq, block_q)
    Sk_p = round_up(Sk, block_k) if Sk >= block_k else round_up(Sk, 8)
    block_k = min(block_k, Sk_p)
    Sk_p = round_up(Sk_p, block_k)
    qp = pad_axis_to(q, 1, Sq_p)
    kp = pad_axis_to(k, 1, Sk_p)
    vp = pad_axis_to(v, 1, Sk_p)
    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, scale=scale, q_offset=q_offset,
        kv_len=Sk, block_q=block_q, block_k=block_k, interpret=interpret)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# XLA path with flash backward (custom VJP)
# ---------------------------------------------------------------------------

def _kv_tiles(k, block_k):
    """(B, Sk_p, K, D) -> (n, B, bk, K, D) f32 tiles."""
    B, Sk_p, K, D = k.shape
    n = Sk_p // block_k
    return jnp.moveaxis(k.reshape(B, n, block_k, K, D), 1, 0)


def _mask_for(block_start, block_k, Sk, q_pos, causal):
    k_pos = block_start + jnp.arange(block_k)
    mask = k_pos[None, :] >= Sk                              # padding
    if causal:
        mask = mask | (k_pos[None, :] > q_pos[:, None])      # (Sq, bk)
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_xla(q, k, v, causal, scale, q_offset, block_k):
    out, _, _ = _flash_xla_fwd_impl(q, k, v, causal, scale, q_offset, block_k)
    return out


def _flash_xla_fwd_impl(q, k, v, causal, scale, q_offset, block_k):
    # K/V tiles stay in the input dtype (no materialised f32 cache copies);
    # score/accumulator matmuls accumulate in f32 via preferred_element_type.
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    cdt = q.dtype
    Sk_p = round_up(Sk, block_k)
    kp = pad_axis_to(k, 1, Sk_p).astype(cdt)
    vp = pad_axis_to(v, 1, Sk_p).astype(cdt)
    qg = ((q.astype(jnp.float32) * scale).astype(cdt)).reshape(B, Sq, K, G, D)
    q_pos = q_offset + jnp.arange(Sq)
    kb, vb = _kv_tiles(kp, block_k), _kv_tiles(vp, block_k)
    starts = jnp.arange(Sk_p // block_k) * block_k

    def body(carry, xs):
        m, l, acc = carry
        kt, vt, start = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kt,
                       preferred_element_type=jnp.float32)
        mask = _mask_for(start, block_k, Sk, q_pos, causal)
        s = jnp.where(mask[None, None, None], NEG_INF, s)
        m_cur = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l_cur = l * corr + p.sum(axis=-1)
        acc_cur = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(cdt), vt,
            preferred_element_type=jnp.float32)
        return (m_cur, l_cur, acc_cur), None

    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, K, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kb, vb, starts))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    outg = acc / l_safe[..., None]                            # (B,K,G,Sq,D) f32
    out = jnp.moveaxis(outg, 3, 1).reshape(B, Sq, H, D).astype(q.dtype)
    return out, m, l_safe


def _flash_xla_fwd(q, k, v, causal, scale, q_offset, block_k):
    out, m, l = _flash_xla_fwd_impl(q, k, v, causal, scale, q_offset, block_k)
    return out, (q, k, v, out, m, l)


def _flash_xla_bwd(causal, scale, q_offset, block_k, res, dout):
    q, k, v, out, m, l = res
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    cdt = q.dtype
    Sk_p = round_up(Sk, block_k)
    kp = pad_axis_to(k, 1, Sk_p).astype(cdt)
    vp = pad_axis_to(v, 1, Sk_p).astype(cdt)
    qg = ((q.astype(jnp.float32) * scale).astype(cdt)).reshape(B, Sq, K, G, D)
    outg = jnp.moveaxis(out.reshape(B, Sq, K, G, D), 1, 3)
    dog = jnp.moveaxis(dout.astype(cdt).reshape(B, Sq, K, G, D), 1, 3)
    Di = jnp.einsum("bkgqd,bkgqd->bkgq", outg.astype(cdt), dog,
                    preferred_element_type=jnp.float32)       # (B,K,G,Sq)
    q_pos = q_offset + jnp.arange(Sq)
    kb, vb = _kv_tiles(kp, block_k), _kv_tiles(vp, block_k)
    starts = jnp.arange(Sk_p // block_k) * block_k

    def body(dq_acc, xs):
        kt, vt, start = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kt,
                       preferred_element_type=jnp.float32)
        mask = _mask_for(start, block_k, Sk, q_pos, causal)
        s = jnp.where(mask[None, None, None], NEG_INF, s)
        p = jnp.exp(s - m[..., None]) / l[..., None]          # exact softmax
        pc = p.astype(cdt)
        dv_t = jnp.einsum("bkgqs,bkgqd->bskd", pc, dog,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bkgqd,bskd->bkgqs", dog, vt,
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - Di[..., None])).astype(cdt)           # (B,K,G,Sq,bk)
        dq_acc = dq_acc + scale * jnp.einsum(
            "bkgqs,bskd->bqkgd", ds, kt, preferred_element_type=jnp.float32)
        # qg already carries `scale`, so dk = dsᵀ·(q·scale) = dsᵀ·qg
        dk_t = jnp.einsum("bkgqs,bqkgd->bskd", ds, qg,
                          preferred_element_type=jnp.float32)
        return dq_acc, (dk_t, dv_t)

    dq0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)
    dq, (dk_t, dv_t) = jax.lax.scan(body, dq0, (kb, vb, starts))
    dk = jnp.moveaxis(dk_t, 0, 1).reshape(B, Sk_p, K, D)[:, :Sk]
    dv = jnp.moveaxis(dv_t, 0, 1).reshape(B, Sk_p, K, D)[:, :Sk]
    dq = dq.reshape(B, Sq, H, D)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash_xla.defvjp(_flash_xla_fwd, _flash_xla_bwd)
