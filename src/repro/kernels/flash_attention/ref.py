"""Pure-jnp oracle for flash attention (GQA, causal, query offset).

Materialises the full (Sq, Sk) score matrix — only usable at test scale; the
Pallas kernel and the blocked XLA path in ``ops.py`` are validated against this.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import NEG_INF


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None,
                  q_offset: int = 0, kv_len=None):
    """Reference attention.

    Args:
      q: (B, Sq, H, D)
      k, v: (B, Sk, K, D) with H % K == 0 (GQA)
      causal: lower-triangular masking in absolute positions
      scale: logit scale (default 1/sqrt(D))
      q_offset: absolute position of q[0] (decode: cache length)
      kv_len: optional (B,) valid KV lengths (positions >= kv_len are masked)

    Returns: (B, Sq, H, D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    Bk, Sk, K, Dk = k.shape
    assert (B, D) == (Bk, Dk) and H % K == 0, (q.shape, k.shape)
    G = H // K
    if scale is None:
        scale = D ** -0.5

    qf = q.astype(jnp.float32) * scale
    qg = qf.reshape(B, Sq, K, G, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))

    q_pos = q_offset + jnp.arange(Sq)[:, None]          # (Sq, 1)
    k_pos = jnp.arange(Sk)[None, :]                      # (1, Sk)
    mask = jnp.zeros((Sq, Sk), dtype=bool)
    if causal:
        mask = mask | (k_pos > q_pos)
    if kv_len is not None:
        mask = mask[None] | (k_pos[None] >= kv_len[:, None, None])   # (B, Sq, Sk)
        logits = jnp.where(mask[:, None, None], NEG_INF, logits)
    else:
        logits = jnp.where(mask[None, None, None], NEG_INF, logits)

    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)
