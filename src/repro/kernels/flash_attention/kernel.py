"""Pallas TPU flash-attention kernel (causal, GQA) with VMEM tiling.

Design (TPU-native, not a CUDA port):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the kv dimension is
    ``arbitrary`` (sequential) so the online-softmax accumulators live in VMEM
    scratch across kv steps — HBM sees each q/k/v tile exactly once.
  * q tile (block_q, head_dim) stays resident; k/v tiles stream through VMEM.
    block sizes default to 128 to align with the 128×128 MXU and 8×128 VREG lanes.
  * causal blocks strictly above the diagonal are skipped via ``pl.when``
    (grid-level work elision, the TPU analogue of warp-level early exit).
  * GQA: the k/v index map folds the query head onto its kv group
    (h -> h // group), so no repeated-KV materialisation in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, cdiv, tpu_compiler_params

# TPU VREG minor dimension; accumulators are padded to this many lanes.
_MIN_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_kv_blocks: int, q_offset: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Last absolute query position covered by this q tile.
    q_last = q_offset + (iq + 1) * block_q - 1
    needed = (ik * block_k <= q_last) if causal else (ik >= 0)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)                  # (bk, D)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                    # (bq, bk)

        q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos >= kv_len                                      # tail padding
        if causal:
            mask = mask | (k_pos > q_pos)
        s = jnp.where(mask, NEG_INF, s)

        m_prev = m_ref[:, 0]                                        # (bq,)
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])                             # (bq, bk)
        l_cur = l_prev * corr + p.sum(axis=-1)

        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)                             # fully-masked rows
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           scale: float | None = None, q_offset: int = 0,
                           kv_len: int | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """(B, Sq, H, D) x (B, Sk, K, D)^2 -> (B, Sq, H, D).  Sq/Sk padded by ops.py."""
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    assert H % K == 0
    group = H // K
    if scale is None:
        scale = D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    n_q, n_k = Sq // block_q, Sk // block_k
    kv_len = Sk if kv_len is None else kv_len

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_kv_blocks=n_k, q_offset=q_offset, kv_len=kv_len)

    grid = (B, H, n_q, n_k)
    in_specs = [
        pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
        pl.BlockSpec((1, block_k, 1, D), lambda b, h, iq, ik: (b, ik, h // group, 0)),
        pl.BlockSpec((1, block_k, 1, D), lambda b, h, iq, ik: (b, ik, h // group, 0)),
    ]
    out_specs = pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0))

    compiler_params = tpu_compiler_params(
        ("parallel", "parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),          # acc
            pltpu.VMEM((block_q, _MIN_LANES), jnp.float32),  # running max
            pltpu.VMEM((block_q, _MIN_LANES), jnp.float32),  # running denom
        ],
        compiler_params=compiler_params,
        interpret=interpret,
    )(q, k, v)
