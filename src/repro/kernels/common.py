"""Shared helpers for the Pallas kernels.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are validated
on CPU in ``interpret=True`` mode, which executes the kernel body with the pure-JAX
interpreter.  ``default_backend()`` picks the dispatch used by the model code:

  * ``"xla"``              — pure-jnp blocked implementation (lowers everywhere;
                             used by the dry-run so cost_analysis sees real HLO)
  * ``"pallas"``           — compiled Pallas kernel (TPU)
  * ``"pallas_interpret"`` — Pallas interpreter (CPU correctness tests)
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro import cancellation

BACKENDS = ("xla", "pallas", "pallas_interpret")

NEG_INF = float(-1e30)   # large-negative instead of -inf: keeps bf16 softmax NaN-free


def default_backend() -> str:
    forced = os.environ.get("REPRO_KERNEL_BACKEND")
    if forced:
        if forced not in BACKENDS:
            raise ValueError(f"REPRO_KERNEL_BACKEND={forced!r} not in {BACKENDS}")
        return forced
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def resolve_backend(backend: str | None) -> str:
    # every kernel dispatch wrapper passes through here, making it the
    # time-sliced cancellation checkpoint for long pure-compute loops that
    # never touch a host-interface call (cost: one thread-local read)
    cancellation.checkpoint()
    b = backend or "auto"
    if b == "auto":
        return default_backend()
    if b not in BACKENDS:
        raise ValueError(f"backend {b!r} not in {BACKENDS}")
    return b


def interpret_mode(backend: str) -> bool:
    return backend == "pallas_interpret"


def tpu_compiler_params(dimension_semantics=None, **kwargs):
    """Build Pallas TPU compiler params across JAX versions.

    Newer JAX exposes ``pltpu.CompilerParams``; older releases call it
    ``TPUCompilerParams``.  Returns ``None`` when neither is constructible,
    which ``pl.pallas_call`` accepts (defaults apply).
    """
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:
        return None
    cls = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        return None
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    try:
        return cls(**kwargs)
    except TypeError:
        return None


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pad_axis_to(x: jnp.ndarray, axis: int, size: int, value=0.0) -> jnp.ndarray:
    """Pad ``axis`` of ``x`` up to ``size`` (no-op if already there)."""
    cur = x.shape[axis]
    if cur == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, size - cur)
    return jnp.pad(x, pads, constant_values=value)
