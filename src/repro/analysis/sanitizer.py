"""Runtime data-plane sanitizer for the two-tier state fabric.

Opt-in (``FAASM_SANITIZE=1`` or the ``sanitize`` pytest marker via the
conftest fixture); **zero overhead when disabled**: the lock factories
below return the *raw* ``threading.RLock``/``RWLock`` objects at
construction time, and every hook site in the fabric is guarded by a
module-global ``if _SAN is not None`` — one pointer compare per call in
the disabled steady state, no wrapper frames, no indirection on the lock
fast path.

What it checks (the invariants are documented in ``docs/invariants.md``):

* **Lock order** — instrumented locks maintain a per-thread held-lock set
  and a global lock-*kind* order graph.  Acquiring kind B while holding
  kind A adds edge A→B; if a path B→…→A already exists, the acquisition
  is a deadlock-potential and is reported with **both** acquisition
  stacks (this one and the one that recorded the reverse ordering).
  Nesting two instances of the *same* kind (stripe inside stripe …) is
  reported too: homogeneous instances have no defined order.
* **Stripe ownership** — every ``GlobalTier`` buffer/meta touch asserts
  the calling thread holds that stripe's lock.
* **Torn writes** — per-(tier, key) generation counters are bumped by
  every mutating primitive (``write_from``/``add_inplace``/``apply_wire``
  /``set``…); ``readinto`` snapshots the generation before its memcpy and
  re-checks it after — a concurrent mutation in between is a torn
  zero-copy read.
* **Wire protocol** — per-key version monotonicity on every ``bump``;
  ``prev_version``/``version`` chain contiguity of frames entering the
  retained delta window; residual conservation on every quantised encode
  (``carried + residual ≈ true delta`` within tolerance).
* **Cancellation** — :func:`checkpoint_guard` (installed into
  ``repro.cancellation``) reports any cancellation checkpoint reached
  while a stripe or key lock is held: a cancel raising there would leak
  the lock.

Instrumentation is decided at **lock construction**: call :func:`enable`
before building the tiers/runtime you want checked.  Reports never raise
at the fault site (the fabric keeps running, so one report doesn't
cascade); tests drain them with :func:`take_reports` and fail on any.

Import-light on purpose (stdlib + numpy): ``repro.state``/``repro.core``
import the factories from here at module import time, so this module must
never import them back at top level (``enable`` does, lazily).
"""
from __future__ import annotations

import threading
import traceback
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

__all__ = [
    "Report", "SanLock", "SanRWLock", "disable", "enable", "enabled",
    "make_mutex", "reports", "reset", "take_reports", "wrap_rwlock",
]

_REPORT_CAP = 200                # dedup'd reports kept before dropping
# residual conservation: |carried + residual - delta| <= ATOL + RTOL*max|delta|
RESIDUAL_RTOL = 1e-4
RESIDUAL_ATOL = 1e-5
# lock kinds the cancellation checkpoint must never observe held: a cancel
# exception under one would unwind past its release
_NO_CANCEL_KINDS = ("stripe", "key")


def _stack() -> str:
    """The current acquisition stack, minus the sanitizer's own frames."""
    frames = traceback.format_stack(limit=24)
    return "".join(f for f in frames if "/analysis/sanitizer" not in f)


@dataclass
class Report:
    """One invariant violation (kept, not raised — see module docstring)."""

    check: str                   # lock-order | stripe-ownership | torn-read |
    #                              wire-version | wire-window | wire-residual |
    #                              cancel-under-lock | telemetry-under-lock |
    #                              lock-misuse | attempt-fence
    message: str
    stack: str                   # where the violation was observed
    other_stack: Optional[str] = None   # lock-order: the reverse acquisition
    thread: str = ""

    def __str__(self) -> str:
        out = (f"[{self.check}] {self.message} (thread {self.thread})\n"
               f"--- acquisition stack ---\n{self.stack}")
        if self.other_stack:
            out += f"--- conflicting acquisition stack ---\n{self.other_stack}"
        return out


class _Held:
    """One lock held by a thread (entry in the per-thread held list)."""

    __slots__ = ("lock", "kind", "name", "mode", "count")

    def __init__(self, lock: Any, kind: str, name: str, mode: str):
        self.lock = lock
        self.kind = kind
        self.name = name
        self.mode = mode          # "mutex" | "read" | "write"
        self.count = 1


class _State:
    """All sanitizer bookkeeping; one instance per :func:`enable`."""

    def __init__(self):
        self._mu = threading.RLock()
        self._tls = threading.local()
        self.reports: List[Report] = []
        self._seen: Set[Tuple[str, str]] = set()
        # lock-kind order graph: src kind -> dst kind -> stack that added it
        self._edges: Dict[str, Dict[str, str]] = {}
        self._gens: Dict[Tuple[int, str], int] = {}       # torn-write counters
        self._versions: Dict[Tuple[int, str], int] = {}   # last version seen
        # attempt-fence shadow state: admitted (call, key, seq) effects and
        # the highest superseded epoch per logical call
        self._fence_applied: Set[Tuple[str, str, int]] = set()
        self._fence_dead: Dict[str, int] = {}

    # -- reporting ---------------------------------------------------------

    def report(self, check: str, message: str, *,
               other_stack: Optional[str] = None) -> None:
        key = (check, message)
        with self._mu:
            if key in self._seen or len(self.reports) >= _REPORT_CAP:
                return
            self._seen.add(key)
            self.reports.append(Report(
                check, message, _stack(), other_stack,
                threading.current_thread().name))

    def take_reports(self) -> List[Report]:
        with self._mu:
            out = self.reports
            self.reports = []
            self._seen.clear()
            return out

    def reset(self) -> None:
        """Forget everything (reports, order graph, counters) but stay
        enabled — per-test isolation for the conftest fixture."""
        with self._mu:
            self.reports = []
            self._seen.clear()
            self._edges.clear()
            self._gens.clear()
            self._versions.clear()
            self._fence_applied.clear()
            self._fence_dead.clear()

    # -- held-lock tracking / lock-order graph -----------------------------

    def _held(self) -> List[_Held]:
        h = getattr(self._tls, "held", None)
        if h is None:
            h = self._tls.held = []
        return h

    def pre_acquire(self, lock: Any, kind: str, name: str, mode: str) -> None:
        """Record the acquisition *before* blocking on the raw lock, so a
        deadlock-potential is reported even on the run that would hang."""
        held = self._held()
        for e in reversed(held):
            if e.lock is lock and e.mode == mode:
                e.count += 1         # re-entrant re-acquire: no new edges
                return
        if held:
            self._add_edges(held, lock, kind)
        held.append(_Held(lock, kind, name, mode))

    def cancel_acquire(self, lock: Any, mode: str) -> None:
        """Undo pre_acquire after a failed non-blocking acquire."""
        self.on_release(lock, mode, "?")

    def on_release(self, lock: Any, mode: str, name: str) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            e = held[i]
            if e.lock is lock and e.mode == mode:
                e.count -= 1
                if e.count == 0:
                    del held[i]
                return
        self.report("lock-misuse",
                    f"release of {name!r} ({mode}) not held by this thread")

    def _add_edges(self, held: List[_Held], lock: Any, kind: str) -> None:
        stack = _stack()
        with self._mu:
            for src in {e.kind for e in held}:
                if src == kind:
                    inst = next(e for e in held if e.kind == kind)
                    self.report(
                        "lock-order",
                        f"nested acquisition of two {kind!r} locks "
                        f"({inst.name!r} then {getattr(lock, 'name', kind)!r})"
                        " — homogeneous lock instances have no defined order")
                    continue
                dst = self._edges.setdefault(src, {})
                if kind in dst:
                    continue
                reverse = self._find_path(kind, src)
                dst[kind] = stack
                if reverse is not None:
                    self.report(
                        "lock-order",
                        f"lock-order cycle: acquiring {kind!r} while holding "
                        f"{src!r}, but {src!r} is already acquired after "
                        f"{kind!r} elsewhere (deadlock potential)",
                        other_stack=reverse)

    def _find_path(self, src: str, dst: str) -> Optional[str]:
        """Stack of the first edge on an existing src→…→dst path, else
        None.  Caller holds ``_mu``."""
        seen = {src}
        frontier = [(src, None)]
        while frontier:
            node, first = frontier.pop()
            for nxt, stk in self._edges.get(node, {}).items():
                if nxt in seen:
                    continue
                f = first if first is not None else stk
                if nxt == dst:
                    return f
                seen.add(nxt)
                frontier.append((nxt, f))
        return None

    def holds(self, lock: Any, mode: Optional[str] = None) -> bool:
        return any(e.lock is lock and (mode is None or e.mode == mode)
                   for e in self._held())

    # -- stripe ownership --------------------------------------------------

    def stripe_touch(self, lock: Any, key: str) -> None:
        """Assert the calling thread holds ``lock`` (the stripe mutex) for
        this buffer/meta touch.  Uninstrumented stripes (tier built before
        :func:`enable`) are skipped."""
        if not isinstance(lock, SanLock):
            return
        if not self.holds(lock):
            self.report(
                "stripe-ownership",
                f"GlobalTier buffer/meta touch on {key!r} without the "
                f"stripe lock held")

    def assert_write_held(self, lock: Any, what: str) -> None:
        """Assert the calling thread write-holds ``lock`` (a replica
        RW lock) — for ``*_locked`` helpers whose contract is 'caller
        holds the write lock'."""
        if not isinstance(lock, SanRWLock):
            return
        if not self.holds(lock, "write"):
            self.report("lock-misuse",
                        f"{what} entered without the replica write lock held")

    # -- torn-write detection (generation counters) ------------------------

    def gen_bump(self, owner: Any, key: str) -> None:
        k = (id(owner), key)
        with self._mu:
            self._gens[k] = self._gens.get(k, 0) + 1

    def read_begin(self, owner: Any, key: str) -> int:
        with self._mu:
            return self._gens.get((id(owner), key), 0)

    def read_end(self, owner: Any, key: str, token: int) -> None:
        with self._mu:
            now = self._gens.get((id(owner), key), 0)
        if now != token:
            self.report(
                "torn-read",
                f"zero-copy read of {key!r} overlapped {now - token} "
                f"concurrent mutation(s) — torn view")

    # -- wire-protocol checks ----------------------------------------------

    def version_bumped(self, owner: Any, key: str, old: int, new: int) -> None:
        if new <= old:
            self.report("wire-version",
                        f"non-monotonic write version on {key!r}: "
                        f"{old} -> {new}")
        with self._mu:
            self._versions[(id(owner), key)] = new

    def frame_applied(self, owner: Any, key: str, frame: Any) -> None:
        if frame.version <= frame.prev_version:
            self.report(
                "wire-version",
                f"frame on {key!r} stamps a non-advancing transition "
                f"{frame.prev_version} -> {frame.version}")

    def frame_recorded(self, owner: Any, key: str, frame: Any,
                       tail_version: Optional[int], floor: int) -> None:
        """A frame entering the retained delta window must chain onto the
        window tail (or, for an empty window, start at the floor)."""
        if tail_version is not None:
            if frame.prev_version != tail_version:
                self.report(
                    "wire-window",
                    f"retained window gap on {key!r}: frame "
                    f"{frame.prev_version}->{frame.version} appended after "
                    f"tail version {tail_version}")
        elif frame.prev_version < floor:
            self.report(
                "wire-window",
                f"retained window on {key!r} starts below its floor: frame "
                f"{frame.prev_version}->{frame.version}, floor {floor}")

    def check_residual(self, delta, carried, residual) -> None:
        """Residual conservation: what the wire carried plus the
        error-feedback residual must reconstruct the true delta."""
        delta = np.asarray(delta, np.float32).reshape(-1)
        carried = np.asarray(carried, np.float32).reshape(-1)[:delta.size]
        if residual is None:
            res = np.zeros(delta.size, np.float32)
        else:
            res = np.asarray(residual, np.float32).reshape(-1)[:delta.size]
        if not delta.size:
            return
        err = float(np.max(np.abs(carried + res - delta)))
        tol = RESIDUAL_ATOL + RESIDUAL_RTOL * float(np.max(np.abs(delta)))
        if err > tol:
            self.report(
                "wire-residual",
                f"residual conservation violated: max|carried + residual "
                f"- delta| = {err:.3g} > {tol:.3g}")

    # -- attempt fences ----------------------------------------------------

    def fence_superseded(self, call_id: str, epoch: int) -> None:
        """The runtime declared every epoch of ``call_id`` up to ``epoch``
        dead (requeue past a lost host, retry past a failed dispatch)."""
        with self._mu:
            if epoch > self._fence_dead.get(call_id, 0):
                self._fence_dead[call_id] = epoch

    def fence_write(self, call_id: str, epoch: int, key: str, seq: int,
                    admitted: bool) -> None:
        """Exactly-once shadow check on every fenced delta-push decision:
        the tier must never admit the same ``(call, key, seq)`` effect twice
        (a re-executed attempt double-applying its delta) nor any write
        from an epoch the runtime already superseded (a zombie attempt
        mutating state after its requeue)."""
        if not admitted:
            return
        with self._mu:
            dup = (call_id, key, seq) in self._fence_applied
            dead = epoch <= self._fence_dead.get(call_id, 0)
            self._fence_applied.add((call_id, key, seq))
        if dup:
            self.report(
                "attempt-fence",
                f"delta push #{seq} on {key!r} by call {call_id} admitted "
                f"twice (epoch {epoch}) — re-execution double-applied state")
        if dead:
            self.report(
                "attempt-fence",
                f"delta push on {key!r} admitted from superseded epoch "
                f"{epoch} of call {call_id} — zombie attempt wrote state")

    # -- cancellation ------------------------------------------------------

    def checkpoint_guard(self) -> None:
        held = [e for e in self._held() if e.kind in _NO_CANCEL_KINDS]
        if held:
            names = ", ".join(f"{e.kind}:{e.name}" for e in held)
            self.report(
                "cancel-under-lock",
                f"cancellation checkpoint reached while holding {names} — "
                f"a cancel raising here would leak the lock")

    # -- telemetry ---------------------------------------------------------

    def telemetry_drain_guard(self) -> None:
        """Span ring-buffer *writes* are lock-free and legal anywhere, but
        the collector drain walks every thread's ring and the shared
        collected list — stalling it under a stripe/key lock couples the
        observability plane into the fabric's hot locks (and an export
        callback touching state would deadlock).  Installed into
        ``repro.telemetry.spans._SAN_GUARD``; ``Tracer.drain`` calls it."""
        held = [e for e in self._held() if e.kind in _NO_CANCEL_KINDS]
        if held:
            names = ", ".join(f"{e.kind}:{e.name}" for e in held)
            self.report(
                "telemetry-under-lock",
                f"telemetry collector drain reached while holding {names} — "
                f"drain/export must run outside fabric locks")


class SanLock:
    """Instrumented re-entrant mutex (drop-in for ``threading.RLock``)."""

    __slots__ = ("_raw", "kind", "name", "_san")

    def __init__(self, kind: str, name: Optional[str], san: _State):
        self._raw = threading.RLock()
        self.kind = kind
        self.name = name or kind
        self._san = san

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._san.pre_acquire(self, self.kind, self.name, "mutex")
        ok = self._raw.acquire(blocking, timeout)
        if not ok:
            self._san.cancel_acquire(self, "mutex")
        return ok

    def release(self) -> None:
        self._san.on_release(self, "mutex", self.name)
        self._raw.release()

    def __enter__(self) -> "SanLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False


class SanRWLock:
    """Instrumented wrapper around a ``repro.state.kv.RWLock``."""

    __slots__ = ("_raw", "kind", "name", "_san")

    def __init__(self, raw: Any, kind: str, name: Optional[str], san: _State):
        self._raw = raw
        self.kind = kind
        self.name = name or kind
        self._san = san

    def acquire_read(self) -> None:
        self._san.pre_acquire(self, self.kind, self.name, "read")
        self._raw.acquire_read()

    def release_read(self) -> None:
        self._san.on_release(self, "read", self.name)
        self._raw.release_read()

    def acquire_write(self) -> None:
        self._san.pre_acquire(self, self.kind, self.name, "write")
        self._raw.acquire_write()

    def release_write(self) -> None:
        self._san.on_release(self, "write", self.name)
        self._raw.release_write()


# -- module API ------------------------------------------------------------

_active: Optional[_State] = None


def enabled() -> bool:
    return _active is not None


def make_mutex(kind: str, name: Optional[str] = None):
    """A mutex of the given order ``kind``.  Disabled: the raw
    ``threading.RLock`` — the sanitizer compiles out of the lock path."""
    if _active is None:
        return threading.RLock()
    return SanLock(kind, name, _active)


def wrap_rwlock(lock, kind: str, name: Optional[str] = None):
    """Wrap an ``RWLock`` for order/ownership tracking.  Disabled: returns
    ``lock`` unchanged."""
    if _active is None:
        return lock
    return SanRWLock(lock, kind, name, _active)


def _install(st: Optional[_State]) -> None:
    """(Un)install the hook state into the fabric modules.  Imports live
    here, not at module top level, to keep the factory import acyclic."""
    from repro import cancellation
    from repro.state import kv, local, wire
    from repro.telemetry import spans
    kv._SAN = st
    local._SAN = st
    wire._SAN = st
    cancellation._SAN_GUARD = st.checkpoint_guard if st is not None else None
    spans._SAN_GUARD = (st.telemetry_drain_guard
                        if st is not None else None)


def enable() -> _State:
    """Turn the sanitizer on (idempotent).  Only locks constructed *after*
    this call are instrumented — enable before building tiers/runtimes."""
    global _active
    if _active is None:
        _active = _State()
        _install(_active)
    return _active


def disable() -> None:
    global _active
    if _active is None:
        return
    _active = None
    _install(None)


def reset() -> None:
    if _active is not None:
        _active.reset()


def reports() -> List[Report]:
    return list(_active.reports) if _active is not None else []


def take_reports() -> List[Report]:
    return _active.take_reports() if _active is not None else []
