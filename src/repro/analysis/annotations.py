"""Static-analysis annotations (zero runtime cost).

These markers carry locking contracts that the AST lint
(:mod:`repro.analysis.lint`) enforces mechanically.  They are identity
decorators at runtime — no wrapper frame, no call overhead.
"""
from __future__ import annotations

from typing import Callable, TypeVar

F = TypeVar("F", bound=Callable)


def holds_stripe(fn: F) -> F:
    """Declare that every caller of ``fn`` already holds the stripe lock.

    The ``stripe-access`` lint rule exempts the decorated function from the
    ``with s.lock:`` requirement; in exchange the *callers* are expected to
    invoke it only under the lock (the decorated body is still checked for
    blocking calls).  Use for ``_Stripe`` bookkeeping helpers like
    ``bump``/``record``/``invalidate``.
    """
    fn.__faasm_holds_stripe__ = True
    return fn
