"""SFI-style invariant checking for the shared-memory state fabric.

Faasm's bet is that software-fault isolation makes shared memory safe; this
package is the correctness-tooling analogue for our reproduction's
hand-rolled concurrency: it makes the locking and wire-protocol discipline
*machine-verified* instead of re-audited by eyeball on every PR.

Two layers (see ``docs/invariants.md`` for the discipline itself):

  * :mod:`repro.analysis.lint` — a static AST pass over ``src/`` enforcing
    the repo-specific rules (stripe accesses under the stripe lock, no
    blocking calls under stripe/key locks, ``WireFrame`` built only by the
    codec layer, no unaccounted copies of tier buffers).  Driven by
    ``scripts/faasmlint.py``; runs as a pre-test stage in
    ``scripts/tier1.sh``.
  * :mod:`repro.analysis.sanitizer` — an opt-in runtime sanitizer
    (``FAASM_SANITIZE=1`` or the ``sanitize`` pytest marker): instrumented
    locks maintain a per-thread held-lock set and a global lock-order graph
    with cycle detection, buffer touches assert stripe ownership,
    generation counters catch torn zero-copy reads, and the wire fabric's
    version/window/residual invariants are checked on every frame.  When
    disabled the wrappers compile out to the raw locks at construction time
    — the steady-state cost is a module-global ``is None`` test.

This module stays import-light: only the annotation markers live here, so
``repro.state`` can depend on it without dragging the linter (ast) or the
sanitizer bookkeeping into every import.
"""
from repro.analysis.annotations import holds_stripe

__all__ = ["holds_stripe"]
