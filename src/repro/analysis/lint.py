"""faasmlint: repo-specific static rules for the state-fabric discipline.

An AST pass (no imports of the checked code) enforcing the lexical side of
the invariants in ``docs/invariants.md``:

``stripe-access``
    ``_Stripe`` sub-map/counter attributes (``store``/``meta``/``locks``/
    ``subs``/``vc``/``pulled``/``pushed``/``copied``/``bcast``) may only be
    touched inside a ``with <stripe>.lock:`` block, or inside a function
    annotated ``@holds_stripe`` (whose callers then carry the obligation),
    or in ``__init__`` (construction precedes sharing).  Stripe variables
    are inferred from ``self._stripe(...)`` / ``self._stripes`` data flow;
    ``self`` inside ``class _Stripe`` is a stripe.

``lock-blocking``
    No blocking or full-value call — ``Event.wait``, tier ``pull``/``push``
    fan-ins, ``broadcast`` fan-out, codec ``encode``/``decode`` and the
    quantise kernels — lexically inside a stripe-lock ``with`` block or a
    key-lock region (``lock = gt.lock(k); lock.acquire_*(); try: ...
    finally: lock.release_*()``, or the ``lock_state_global_*`` /
    ``unlock_state_global_*`` try/finally idiom).  Replica RW locks are
    deliberately out of scope: encoding under the replica lock is the
    documented push pipeline.

``wire-construct``
    ``WireFrame(...)`` is constructed only by the codec layer
    (``repro/state/wire.py``).  Everyone else goes through a
    ``WireCodec``/``frame_from_quantized`` so frames can't skip residual
    and version stamping.

``tier-copy``
    In the tier files (``state/kv.py``, ``state/local.py``,
    ``core/host_interface.py``), no naked ``.copy()``/``.tobytes()``/
    ``np.copy`` unless the enclosing function accounts the copy
    (``s.copied += ...`` or a ``charge_net(...)`` call) — the copy
    accounting (``bytes_copied``) is a measured experiment output and
    silent copies corrupt it.

``fault-point``
    Fault-injection sites go through the public ``repro.faults`` surface —
    ``faults.point(...)`` at the site, ``arm``/``disarm``/``armed`` around
    it.  Importing or touching the module's internals (``_PLAN``,
    ``_fire``, any underscore name) outside ``repro/faults.py`` builds an
    ad-hoc ``if FAULTS:`` branch that the disarmed one-compare fast path
    can't keep free, and that schedules can't see or count.

``metric-naming``
    Metrics are registered through a ``telemetry.metrics`` registry with
    names matching ``faasm_<subsystem>_<name>_<unit>`` (string-literal
    names on ``.counter``/``.gauge``/``.histogram`` calls are checked
    against the convention), and the data-plane modules take timestamps
    from ``repro.telemetry.clock`` — a direct ``time.perf_counter()``
    there is a second clock the spans can't be correlated with.

``bounded-queue``
    In the data-plane packages (``core/``, ``state/``) every queue is
    bounded: a raw ``queue.Queue()`` (or ``Queue()``) construction is a
    violation — an unbounded queue is an invisible buffer that converts
    overload into unbounded latency and memory instead of backpressure.
    Use ``repro.overload.bounded_queue(...)`` (the blessed factory, with
    the admission-control depth default) or ``overload.CoalescingQueue``.

``suppress-justify``
    Every ``# faasmlint: disable=<rule>`` must carry a justification
    string (and name a real rule).

Suppression: ``# faasmlint: disable=<rule>[,<rule>...] -- <why>`` as a
trailing comment silences those rules on its own line; as a standalone
comment line it silences them on the next code line.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set

__all__ = ["RULES", "Violation", "lint_file", "lint_paths", "lint_source"]

RULES: Dict[str, str] = {
    "stripe-access": ("_Stripe buffer/meta/counter access outside a "
                      "'with stripe.lock:' block (or @holds_stripe)"),
    "lock-blocking": ("blocking call (wait/pull/push/broadcast/codec "
                      "encode/decode) while a stripe or key lock is held"),
    "wire-construct": ("WireFrame constructed outside the codec layer "
                       "(repro/state/wire.py)"),
    "tier-copy": ("unaccounted .copy()/.tobytes()/np.copy on a tier "
                  "buffer outside the accounted primitives"),
    "fault-point": ("fault-injection site bypassing the public "
                    "repro.faults surface (faults.point/arm/disarm) — "
                    "internals like _PLAN are off-limits outside "
                    "repro/faults.py"),
    "metric-naming": ("metric name violating faasm_<subsystem>_<name>_"
                      "<unit>, or a direct time.perf_counter() in a "
                      "data-plane module (use repro.telemetry.clock)"),
    "bounded-queue": ("raw queue.Queue() in a data-plane package (core/, "
                      "state/) — use repro.overload.bounded_queue() or "
                      "CoalescingQueue so overload becomes backpressure, "
                      "not an unbounded buffer"),
    "suppress-justify": ("faasmlint suppression without a justification "
                         "(or naming an unknown rule)"),
}

# _Stripe attributes guarded by the stripe lock ('lock' itself is exempt:
# acquiring it is the point)
STRIPE_ATTRS = frozenset({
    "store", "meta", "locks", "subs", "vc", "pulled", "pushed", "copied",
    "bcast",
})

# call names that block or do full-value work: forbidden under stripe/key
# locks (lexically)
BLOCKING_CALLS = frozenset({
    "wait",                                # Event.wait / Condition.wait
    "pull", "pull_chunk", "pull_range", "pull_wire",
    "push", "push_dirty", "push_delta",
    "broadcast",
    "encode_delta", "decode",
    "quantize_delta", "encode_pull", "apply_pull", "dequantize",
})
# 'encode' is too common a name (str.encode); flag it only on codec-like
# receivers (source text mentions codec/frame/wire)
_CODEC_ENCODE = "encode"

TIER_COPY_CALLS = frozenset({"copy", "tobytes"})
# path suffixes the tier-copy rule applies to
TIER_COPY_FILES = ("state/kv.py", "state/local.py", "core/host_interface.py")
WIRE_HOME = "state/wire.py"          # the one module allowed to build frames
FAULTS_HOME = "repro/faults.py"      # the one module allowed its internals
# the public fault-injection surface; anything else from repro.faults is an
# internal and the fault-point rule flags its use elsewhere
FAULTS_PUBLIC = frozenset({
    "point", "arm", "disarm", "armed", "active",
    "FaultPlan", "FaultRule", "FaultInjected", "HostCrash", "FAULT_POINTS",
    "_TEL",      # telemetry hook slot: written by repro.telemetry.spans
})

# data-plane modules: every timestamp comes from repro.telemetry.clock so
# spans, Call timing and benchmark rows share one monotonic timebase
DATA_PLANE_FILES = (
    "core/runtime.py", "core/faaslet.py", "core/proto.py",
    "core/host_interface.py", "state/kv.py", "state/local.py",
    "state/wire.py", "launch/serve.py", "launch/train.py",
)
# packages where the bounded-queue rule applies: the data plane, where an
# unbounded queue defeats admission control
BOUNDED_QUEUE_DIRS = ("core/", "state/")
_RAW_QUEUE_CALLS = frozenset({"Queue", "SimpleQueue", "LifoQueue"})
CLOCK_HOME = "telemetry/clock.py"    # the one module allowed perf_counter
_RAW_CLOCK_CALLS = frozenset({"perf_counter", "perf_counter_ns"})
# mirror of repro.telemetry.metrics._NAME_RE (this linter is AST-only and
# must not import the checked code); keep the unit list in sync
_METRIC_UNITS = ("seconds", "ms", "us", "ns", "bytes", "pages", "total",
                 "count", "ratio", "rps")
_METRIC_NAME_RE = re.compile(
    r"^faasm(_[a-z0-9]+)+_(" + "|".join(_METRIC_UNITS) + r")$")
_REGISTRY_METHODS = frozenset({"counter", "gauge", "histogram"})

_DISABLE_RE = re.compile(
    r"#\s*faasmlint:\s*disable=([A-Za-z0-9_,-]+)[ \t]*(.*)")


@dataclass
class Violation:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _parse_suppressions(source: str, path: str,
                        out: List[Violation]) -> Dict[int, Set[str]]:
    """Map line number -> rules suppressed there; justification-less or
    unknown-rule suppressions become ``suppress-justify`` violations."""
    lines = source.splitlines()
    sup: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, 1):
        m = _DISABLE_RE.search(line)
        if m is None:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        just = m.group(2).strip().lstrip("-—:").strip()
        for r in sorted(rules - set(RULES)):
            out.append(Violation("suppress-justify", path, i,
                                 f"suppression names unknown rule {r!r}"))
        rules &= set(RULES)
        if not just:
            out.append(Violation(
                "suppress-justify", path, i,
                "suppression without a justification (write "
                "'# faasmlint: disable=<rule> -- <why>')"))
            continue
        target = i
        if line.strip().startswith("#"):
            # standalone comment: applies to the next code line
            j = i + 1
            while j <= len(lines) and (not lines[j - 1].strip()
                                       or lines[j - 1].strip().startswith("#")):
                j += 1
            target = j
        sup.setdefault(target, set()).update(rules)
        sup.setdefault(i, set()).update(rules)
    return sup


def _yields_stripes(node: ast.AST, stripe_vars: Set[str]) -> bool:
    """True when evaluating ``node`` can produce stripe objects: mentions
    ``._stripe(...)``, ``._stripes`` or a known stripe variable."""
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in ("_stripe", "_stripes"):
            return True
        if isinstance(n, ast.Name) and n.id in stripe_vars:
            return True
    return False


def _target_names(target: ast.AST) -> List[str]:
    out = []
    for n in ast.walk(target):
        if isinstance(n, ast.Name):
            out.append(n.id)
    return out


def _call_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _is_accounted(fn: ast.AST) -> bool:
    """The 'accounted copy' heuristic for tier-copy: the function body
    charges the tier copy counter or the Faaslet net budget."""
    for n in ast.walk(fn):
        if isinstance(n, ast.AugAssign) and \
                isinstance(n.target, ast.Attribute) and \
                n.target.attr == "copied":
            return True
        if isinstance(n, ast.Call) and _call_name(n.func) == "charge_net":
            return True
    return False


def _has_holds_stripe(fn) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        name = _call_name(dec) if isinstance(dec, ast.Call) else None
        if isinstance(dec, (ast.Name, ast.Attribute)):
            name = dec.attr if isinstance(dec, ast.Attribute) else dec.id
        if name == "holds_stripe":
            return True
    return False


class _FunctionLinter:
    """Lints one function body, tracking lexical lock regions."""

    def __init__(self, checker: "_FileLinter", class_name: Optional[str],
                 fn: ast.AST):
        self.checker = checker
        self.fn = fn
        self.stripe_vars: Set[str] = set()
        if class_name == "_Stripe":
            self.stripe_vars.add("self")
        self.keylock_vars: Set[str] = set()
        self.locked_stripes: List[str] = []   # stripe vars whose lock is held
        self.lock_depth = 0                   # stripe/key lock regions active
        name = getattr(fn, "name", "<lambda>")
        self.access_exempt = (name == "__init__" or _has_holds_stripe(fn))
        # @holds_stripe: the body runs under the stripe lock by contract —
        # blocking calls inside it are violations even with no lexical region
        self.contract_lock = _has_holds_stripe(fn)
        self.accounted = _is_accounted(fn)

    # -- statement walk ----------------------------------------------------

    def run(self) -> None:
        if self.contract_lock:
            self.lock_depth += 1
        self.visit_body(getattr(self.fn, "body", []))

    def visit_body(self, stmts: Sequence[ast.stmt]) -> None:
        for st in stmts:
            self.visit_stmt(st)

    def visit_stmt(self, st: ast.stmt) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs run later, outside this lexical lock region
            self.checker.lint_function(st, None)
            return
        if isinstance(st, ast.ClassDef):
            self.checker.lint_class(st)
            return
        if isinstance(st, ast.Assign):
            self.scan_expr(st.value)
            for t in st.targets:
                self.scan_expr(t)
            if _yields_stripes(st.value, self.stripe_vars):
                for t in st.targets:
                    self.stripe_vars.update(_target_names(t))
            if isinstance(st.value, ast.Call) and \
                    _call_name(st.value.func) == "lock":
                for t in st.targets:
                    self.keylock_vars.update(_target_names(t))
            return
        if isinstance(st, (ast.For, ast.AsyncFor)):
            self.scan_expr(st.iter)
            if _yields_stripes(st.iter, self.stripe_vars):
                self.stripe_vars.update(_target_names(st.target))
            self.visit_body(st.body)
            self.visit_body(st.orelse)
            return
        if isinstance(st, (ast.With, ast.AsyncWith)):
            opened: List[str] = []
            for item in st.items:
                self.scan_expr(item.context_expr)
                ctx = item.context_expr
                if isinstance(ctx, ast.Attribute) and ctx.attr == "lock" and \
                        isinstance(ctx.value, ast.Name) and \
                        ctx.value.id in self.stripe_vars:
                    opened.append(ctx.value.id)
            self.locked_stripes.extend(opened)
            self.lock_depth += len(opened)
            self.visit_body(st.body)
            self.lock_depth -= len(opened)
            del self.locked_stripes[len(self.locked_stripes) - len(opened):]
            return
        if isinstance(st, ast.Try):
            locked = self._finally_releases_keylock(st.finalbody)
            if locked:
                self.lock_depth += 1
            self.visit_body(st.body)
            if locked:
                self.lock_depth -= 1
            for h in st.handlers:
                self.visit_body(h.body)
            self.visit_body(st.orelse)
            self.visit_body(st.finalbody)
            return
        if isinstance(st, (ast.If, ast.While)):
            self.scan_expr(st.test)
            self.visit_body(st.body)
            self.visit_body(st.orelse)
            return
        # leaf statements: scan every contained expression
        for child in ast.iter_child_nodes(st):
            if isinstance(child, ast.expr):
                self.scan_expr(child)
            elif isinstance(child, ast.stmt):
                self.visit_stmt(child)

    def _finally_releases_keylock(self, finalbody: Sequence[ast.stmt]) -> bool:
        """A try/finally whose finaliser releases a key lock marks its try
        body as a key-lock region."""
        for st in finalbody:
            for n in ast.walk(st):
                if not isinstance(n, ast.Call):
                    continue
                name = _call_name(n.func)
                if name is None:
                    continue
                if name.startswith("unlock_state_global"):
                    return True
                if name in ("release_read", "release_write", "release") and \
                        isinstance(n.func, ast.Attribute) and \
                        isinstance(n.func.value, ast.Name) and \
                        n.func.value.id in self.keylock_vars:
                    return True
        return False

    # -- expression scan ---------------------------------------------------

    def scan_expr(self, node: ast.AST) -> None:
        for n in ast.walk(node):
            if isinstance(n, ast.Attribute):
                self._check_stripe_access(n)
            elif isinstance(n, ast.Call):
                self._check_call(n)

    def _check_stripe_access(self, n: ast.Attribute) -> None:
        if n.attr not in STRIPE_ATTRS:
            return
        if not (isinstance(n.value, ast.Name)
                and n.value.id in self.stripe_vars):
            return
        if self.access_exempt or n.value.id in self.locked_stripes:
            return
        self.checker.add("stripe-access", n.lineno,
                         f"access to stripe attribute "
                         f"'{n.value.id}.{n.attr}' outside "
                         f"'with {n.value.id}.lock:'")

    @staticmethod
    def _is_codec_encode(n: ast.Call, name: Optional[str]) -> bool:
        """``encode()`` on a receiver that looks like a wire codec/frame —
        plain ``str.encode()`` must not trip the rule."""
        if name != _CODEC_ENCODE or not isinstance(n.func, ast.Attribute):
            return False
        try:
            recv = ast.unparse(n.func.value).lower()
        except Exception:                      # pragma: no cover
            return True                        # can't tell: err on reporting
        return any(hint in recv for hint in ("codec", "frame", "wire"))

    def _check_call(self, n: ast.Call) -> None:
        name = _call_name(n.func)
        if name is None:
            return
        if self.lock_depth > 0 and (name in BLOCKING_CALLS
                                    or self._is_codec_encode(n, name)):
            self.checker.add(
                "lock-blocking", n.lineno,
                f"call to {name}() inside a stripe/key lock region")
        if name == "WireFrame" and \
                not self.checker.path_str.endswith(WIRE_HOME):
            self.checker.add(
                "wire-construct", n.lineno,
                "WireFrame constructed outside repro/state/wire.py — go "
                "through a WireCodec (or wire.frame_from_quantized)")
        if name in _RAW_QUEUE_CALLS and self.checker.bounded_queue_scope:
            self.checker.add(
                "bounded-queue", n.lineno,
                f"raw {name}() in a data-plane package — use "
                f"repro.overload.bounded_queue() (or CoalescingQueue) so "
                f"overload turns into backpressure, not an unbounded buffer")
        if name in _RAW_CLOCK_CALLS and self.checker.data_plane_scope:
            self.checker.add(
                "metric-naming", n.lineno,
                f"direct time.{name}() in a data-plane module — take "
                f"timestamps from repro.telemetry.clock so spans and "
                f"Call timing share one timebase")
        if name in _REGISTRY_METHODS and isinstance(n.func, ast.Attribute) \
                and n.args and isinstance(n.args[0], ast.Constant) \
                and isinstance(n.args[0].value, str):
            try:
                recv = ast.unparse(n.func.value).lower()
            except Exception:                  # pragma: no cover
                recv = "registry"              # can't tell: err on checking
            if any(h in recv for h in ("metric", "reg")) and \
                    not _METRIC_NAME_RE.match(n.args[0].value):
                self.checker.add(
                    "metric-naming", n.lineno,
                    f"metric name {n.args[0].value!r} violates "
                    f"faasm_<subsystem>_<name>_<unit> "
                    f"(unit one of {', '.join(_METRIC_UNITS)})")
        if self.checker.tier_copy_scope and not self.accounted:
            is_np_copy = (name == "copy" and isinstance(n.func, ast.Attribute)
                          and isinstance(n.func.value, ast.Name)
                          and n.func.value.id == "np")
            if name in TIER_COPY_CALLS and isinstance(n.func, ast.Attribute) \
                    or is_np_copy:
                self.checker.add(
                    "tier-copy", n.lineno,
                    f"{name}() in a tier file outside an accounted "
                    f"primitive (no '.copied +=' / charge_net in scope)")


class _FileLinter:
    def __init__(self, source: str, path: str):
        self.path_str = path.replace("\\", "/")
        self.source = source
        self.violations: List[Violation] = []
        self.suppressions = _parse_suppressions(source, path, self.violations)
        self.tier_copy_scope = any(self.path_str.endswith(p)
                                   for p in TIER_COPY_FILES)
        self.bounded_queue_scope = any(d in self.path_str
                                       for d in BOUNDED_QUEUE_DIRS)
        self.data_plane_scope = (
            any(self.path_str.endswith(p) for p in DATA_PLANE_FILES)
            and not self.path_str.endswith(CLOCK_HOME))

    def add(self, rule: str, line: int, message: str) -> None:
        if rule in self.suppressions.get(line, ()):
            return
        self.violations.append(Violation(rule, self.path_str, line, message))

    def run(self) -> List[Violation]:
        tree = ast.parse(self.source, filename=self.path_str)
        self.lint_body(tree.body, None)
        self._lint_fault_points(tree)
        self.violations.sort(key=lambda v: (v.line, v.rule))
        return self.violations

    def _lint_fault_points(self, tree: ast.AST) -> None:
        """fault-point: outside repro/faults.py, only the public surface of
        the fault layer may be named — no ``from repro.faults import _PLAN``
        and no ``faults._anything`` attribute reach-through (that's an
        ad-hoc injection branch the armed/disarmed discipline can't see)."""
        if self.path_str.endswith(FAULTS_HOME):
            return
        aliases: Set[str] = set()
        for n in ast.walk(tree):
            if isinstance(n, ast.Import):
                for a in n.names:
                    if a.name == "repro.faults" and a.asname:
                        aliases.add(a.asname)
            elif isinstance(n, ast.ImportFrom):
                mod = n.module or ""
                if mod == "repro" or mod.endswith("repro"):
                    for a in n.names:
                        if a.name == "faults":
                            aliases.add(a.asname or "faults")
                if mod == "repro.faults" or mod.endswith(".faults"):
                    for a in n.names:
                        if a.name not in FAULTS_PUBLIC:
                            self.add(
                                "fault-point", n.lineno,
                                f"import of repro.faults internal "
                                f"{a.name!r} — sites use faults.point() "
                                f"and plans use arm()/disarm()/armed()")
        if not aliases:
            return
        for n in ast.walk(tree):
            if isinstance(n, ast.Attribute) and \
                    isinstance(n.value, ast.Name) and \
                    n.value.id in aliases and \
                    n.attr not in FAULTS_PUBLIC:
                self.add(
                    "fault-point", n.lineno,
                    f"reach into fault-layer internals "
                    f"'{n.value.id}.{n.attr}' — fault sites go through "
                    f"faults.point(...); plans through arm()/disarm()")

    def lint_body(self, stmts, class_name: Optional[str]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.lint_function(st, class_name)
            elif isinstance(st, ast.ClassDef):
                self.lint_class(st)
            else:
                # module/class-level statements: frame/copy rules still apply
                fl = _FunctionLinter(self, class_name, ast.Module(body=[],
                                                                  type_ignores=[]))
                fl.visit_stmt(st)

    def lint_class(self, cls: ast.ClassDef) -> None:
        self.lint_body(cls.body, cls.name)

    def lint_function(self, fn, class_name: Optional[str]) -> None:
        _FunctionLinter(self, class_name, fn).run()


def lint_source(source: str, path: str = "<string>") -> List[Violation]:
    """Lint python source text; ``path`` scopes the path-sensitive rules."""
    return _FileLinter(source, path).run()


def lint_file(path) -> List[Violation]:
    p = Path(path)
    return lint_source(p.read_text(encoding="utf-8"), str(p))


def lint_paths(paths: Sequence) -> List[Violation]:
    """Lint files and/or directory trees (``*.py``, recursively)."""
    out: List[Violation] = []
    for path in paths:
        p = Path(path)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            out.extend(lint_file(f))
    return out
