"""Elastic rescaling: move a training state between meshes of different size.

The checkpoint stores host-layout arrays; ``reshard`` places them on a new
mesh under freshly derived ShardingRules — scale from N to M hosts (or
recover from a lost pod) without converting the checkpoint.  Combined with
the deterministic data pipeline (batch = f(seed, step, shard)), a restart on
a different cluster shape replays identical training.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules


def reshard_params(params_host, cfg: ModelConfig, mesh: Mesh,
                   fsdp: bool = True):
    """Host pytree -> device pytree sharded for ``mesh``."""
    rules = ShardingRules(mesh, cfg, fsdp=fsdp)
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.asarray(x).shape,
                                       np.asarray(x).dtype), params_host)
    shardings = rules.params_shardings(shapes)
    return jax.tree.map(lambda x, s: jax.device_put(np.asarray(x), s),
                        params_host, shardings)


def reshard_train_state(params_host, opt_state_host, cfg: ModelConfig,
                        mesh: Mesh, fsdp: bool = True):
    """Reshard (params, optimizer state) for a new mesh (ZeRO state follows
    the parameter specs)."""
    rules = ShardingRules(mesh, cfg, fsdp=fsdp)
    pshapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.asarray(x).shape,
                                       np.asarray(x).dtype), params_host)
    oshapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.asarray(x).shape,
                                       np.asarray(x).dtype), opt_state_host)
    psh = rules.params_shardings(pshapes)
    osp = rules.opt_specs(oshapes, pshapes)
    osh = jax.tree.map(lambda s: NamedSharding(mesh, s), osp)
    put = lambda x, s: jax.device_put(np.asarray(x), s)
    return (jax.tree.map(put, params_host, psh),
            jax.tree.map(put, opt_state_host, osh))


def to_host(tree) -> Any:
    """Gather a (possibly sharded) pytree to host numpy (for checkpointing)."""
    return jax.tree.map(lambda x: np.asarray(x), tree)
