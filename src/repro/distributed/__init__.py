from repro.distributed.sharding import ShardingRules
from repro.distributed.hlo_analysis import HloAnalyzer, analyze

__all__ = ["ShardingRules", "HloAnalyzer", "analyze"]
