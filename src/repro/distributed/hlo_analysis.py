"""Static analysis of post-SPMD HLO text: FLOPs, bytes, collective traffic.

XLA-CPU's ``cost_analysis()`` counts a ``while`` body **once**, so any model
lowered with ``lax.scan`` over layers under-reports FLOPs and in-loop
collectives by ~n_layers×.  This analyzer parses the compiled module text,
builds a symbol table of instruction shapes, and propagates costs through the
call graph with loop-trip-count multipliers:

  * dot FLOPs = 2 · |result| · Π(contracting dims)   (convs approximated)
  * while(body, cond) costs × trip count (parsed from the condition's
    compare-against-constant; falls back to 1 with a warning flag)
  * conditional: max over branches (one branch executes)
  * fusion internals are free for the *bytes* metric (operands + result of the
    fusion node itself model the HBM traffic of the fused kernel — the closest
    CPU-HLO stand-in for TPU fusion behaviour)
  * collective bytes = Σ operand bytes per op kind, × enclosing trip counts.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
                "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{\s*$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "conditional", "call", "custom-call",
                   "after-all", "partition-id", "replica-id", "iota"}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        nb = _DTYPE_BYTES.get(dtype, 4)
        if dims:
            for d in dims.split(","):
                if d:
                    nb *= int(d)
        total += nb
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "f32", []
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    line: str


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    # XLA-CPU emulates bf16 compute by upcasting to f32; these converts (and
    # their traffic) do not exist on TPU.  Tracked so the roofline can report
    # a TPU-corrected memory term and peak.
    bf16_convert_bytes: float = 0.0          # flow (trip-multiplied) traffic
    bf16_convert_static_bytes: float = 0.0   # entry-level live copies (peak)
    collective: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)
    warnings: List[str] = field(default_factory=list)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        self.bf16_convert_bytes += other.bf16_convert_bytes * mult
        for k, v in other.collective.items():
            self.collective[k] = self.collective.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = \
                self.collective_counts.get(k, 0.0) + v * mult
        self.warnings.extend(other.warnings)

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective.values())


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.types: Dict[str, str] = {}          # instr name -> type string
        self._parse(hlo_text)
        self._cost_cache: Dict[str, Costs] = {}

    # -- parsing ------------------------------------------------------------------

    def _parse(self, text: str):
        cur: Optional[str] = None
        self.entry: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            hdr = _COMP_HDR_RE.match(line)
            if hdr and "{" in line:
                cur = hdr.group(1)
                self.computations[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, rest = m.group(1), m.group(2)
            op_m = _OPCODE_RE.search(" " + rest)
            if op_m is None:
                continue
            opcode = op_m.group(1)
            type_str = rest[:op_m.start()].strip()   # start offset includes " "
            paren_at = op_m.end() - 2                 # index of "(" in rest
            depth = 0
            op_str = ""
            end_at = len(rest)
            for j in range(paren_at, len(rest)):
                ch = rest[j]
                if ch == "(":
                    depth += 1
                    if depth == 1:
                        continue
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end_at = j
                        break
                op_str += ch
            attrs = rest[end_at + 1:]
            operands = _OPERAND_RE.findall(op_str)
            instr = Instr(name, type_str, opcode, operands, attrs, line)
            self.computations[cur].append(instr)
            self.types[name] = type_str

    # -- trip counts --------------------------------------------------------------

    _TRIP_RE = re.compile(r'"known_trip_count":{"n":"(\d+)"}')

    def _trip_from_config(self, instr: Instr) -> Optional[float]:
        m = self._TRIP_RE.search(instr.attrs) or self._TRIP_RE.search(instr.line)
        return float(m.group(1)) if m else None

    def _trip_count(self, cond_name: str) -> Tuple[float, Optional[str]]:
        instrs = self.computations.get(cond_name, [])
        consts: Dict[str, int] = {}
        for i in instrs:
            c = _CONST_RE.search(i.line)
            if c and i.opcode == "constant":
                consts[i.name] = int(c.group(1))
        for i in instrs:
            if i.opcode == "compare":
                for op in i.operands:
                    if op in consts:
                        return float(consts[op]), None
        # fallback: any constant in the condition
        if consts:
            return float(max(consts.values())), None
        return 1.0, f"trip count of {cond_name} unknown; assuming 1"

    # -- per-instruction costs ------------------------------------------------------

    def _dot_flops(self, instr: Instr) -> float:
        _, out_dims = _shape_dims(instr.type_str)
        out_elems = 1
        for d in out_dims:
            out_elems *= d
        lhs_type = self.types.get(instr.operands[0], "f32[]") if instr.operands else "f32[]"
        _, lhs_dims = _shape_dims(lhs_type)
        m = re.search(r"lhs_contracting_dims={([0-9,]*)}", instr.attrs)
        contract = 1
        if m and m.group(1):
            for d in m.group(1).split(","):
                if d != "" and int(d) < len(lhs_dims):
                    contract *= lhs_dims[int(d)]
        return 2.0 * out_elems * contract

    def _conv_flops(self, instr: Instr) -> float:
        _, out_dims = _shape_dims(instr.type_str)
        out_elems = 1
        for d in out_dims:
            out_elems *= d
        k_type = self.types.get(instr.operands[1], "f32[]") \
            if len(instr.operands) > 1 else "f32[]"
        _, k_dims = _shape_dims(k_type)
        m = re.search(r"feature_group_count=(\d+)", instr.attrs)
        fg = int(m.group(1)) if m else 1
        k_elems = 1
        for d in k_dims:
            k_elems *= d
        out_feat = out_dims[-1] if out_dims else 1
        per_out = k_elems / max(out_feat, 1) if fg > 1 else \
            k_elems / max(out_feat, 1)
        return 2.0 * out_elems * max(per_out, 1.0)

    def _instr_bytes(self, instr: Instr) -> float:
        total = _shape_bytes(instr.type_str)
        for op in instr.operands:
            t = self.types.get(op)
            if t:
                total += _shape_bytes(t)
        return float(total)

    def _called(self, instr: Instr, key: str) -> Optional[str]:
        m = re.search(key + r"=%([\w\.\-]+)", instr.attrs)
        return m.group(1) if m else None

    def _branches(self, instr: Instr) -> List[str]:
        m = re.search(r"branch_computations={([^}]*)}", instr.attrs)
        if m:
            return _OPERAND_RE.findall(m.group(1))
        out = []
        for key in ("true_computation", "false_computation"):
            b = self._called(instr, key)
            if b:
                out.append(b)
        return out

    # -- traversal ---------------------------------------------------------------------

    def computation_costs(self, comp_name: str,
                          count_bytes: bool = True) -> Costs:
        if comp_name in self._cost_cache:
            return self._cost_cache[comp_name]
        costs = Costs()
        self._cost_cache[comp_name] = costs          # break cycles defensively
        for instr in self.computations.get(comp_name, []):
            op = instr.opcode
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                nbytes = 0.0
                for o in instr.operands:
                    t = self.types.get(o)
                    if t:
                        nbytes += _shape_bytes(t)
                if nbytes == 0.0:
                    nbytes = float(_shape_bytes(instr.type_str))
                costs.collective[base] = costs.collective.get(base, 0.0) + nbytes
                costs.collective_counts[base] = \
                    costs.collective_counts.get(base, 0.0) + 1
                costs.bytes += self._instr_bytes(instr)
                continue
            if op == "while":
                body = self._called(instr, "body")
                cond = self._called(instr, "condition")
                trip = self._trip_from_config(instr)
                if trip is None:
                    trip, warn = self._trip_count(cond) if cond else (1.0, None)
                    if warn:
                        costs.warnings.append(warn)
                if body:
                    costs.add(self.computation_costs(body), trip)
                if cond:
                    costs.add(self.computation_costs(cond), trip)
                continue
            if op == "conditional":
                branches = self._branches(instr)
                if branches:
                    sub = [self.computation_costs(b) for b in branches]
                    best = max(sub, key=lambda c: c.flops + c.bytes)
                    costs.add(best)
                continue
            if op in ("call", "async-start"):
                callee = self._called(instr, "to_apply") or \
                    self._called(instr, "called_computation")
                if callee:
                    costs.add(self.computation_costs(callee))
                continue
            if op == "fusion":
                callee = self._called(instr, "calls")
                if callee:
                    inner = self.computation_costs(callee, count_bytes=False)
                    costs.flops += inner.flops
                    costs.transcendentals += inner.transcendentals
                    for k, v in inner.collective.items():
                        costs.collective[k] = costs.collective.get(k, 0.0) + v
                # fusion node's own operands/result model the fused kernel's HBM
                costs.bytes += self._instr_bytes(instr)
                continue
            if op == "dot":
                costs.flops += self._dot_flops(instr)
            elif op == "convert":
                src = self.types.get(instr.operands[0], "") if instr.operands else ""
                if instr.type_str.startswith("f32") and src.startswith("bf16"):
                    costs.bf16_convert_bytes += self._instr_bytes(instr)
            elif op == "convolution":
                costs.flops += self._conv_flops(instr)
            elif op in ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                        "cosine", "sine", "logistic"):
                _, dims = _shape_dims(instr.type_str)
                n = 1
                for d in dims:
                    n *= d
                costs.transcendentals += n
            if op not in _SKIP_BYTES_OPS:
                costs.bytes += self._instr_bytes(instr)
        return costs

    def entry_costs(self) -> Costs:
        if not self.entry:
            raise ValueError("no ENTRY computation found")
        costs = self.computation_costs(self.entry)
        # entry-level bf16->f32 live copies (stacked weights/caches upcast
        # once before a loop): these sit in the peak on CPU, not on TPU.
        static = 0.0
        for instr in self.computations.get(self.entry, []):
            srcs = [self.types.get(o, "") for o in instr.operands]
            if instr.opcode == "convert" and instr.type_str.startswith("f32") \
                    and srcs and srcs[0].startswith("bf16"):
                static += _shape_bytes(instr.type_str)
            elif instr.opcode == "fusion" and instr.type_str.startswith("f32") \
                    and srcs and all(s.startswith("bf16") for s in srcs if s) \
                    and _shape_bytes(instr.type_str) > (64 << 20):
                static += _shape_bytes(instr.type_str)
        costs.bf16_convert_static_bytes = static
        return costs


def analyze(hlo_text: str) -> Costs:
    return HloAnalyzer(hlo_text).entry_costs()
