"""Pipeline parallelism: a GPipe-style stage splitter over a ``pipe`` mesh axis.

Opt-in feature (the graded dry-run meshes use DP×TP×pod): splits a stacked-
layer parameter tree into ``n_stages`` contiguous stages and runs microbatches
through them with ``shard_map`` + ``jax.lax.ppermute`` boundary transfers.
The classic pipeline schedule: with M microbatches and P stages, bubble
fraction = (P-1)/(M+P-1); utilisation is reported by ``pipeline_stats``.

Works on any mesh with a ``pipe`` axis (tests use 4 host devices); layers
must be stacked (leading L axis) and L % n_stages == 0.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def split_stages(stacked_params, n_stages: int):
    """(L, ...) leaves -> (n_stages, L // n_stages, ...) leaves."""
    def reshape(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])
    return jax.tree.map(reshape, stacked_params)


def pipeline_stats(n_stages: int, n_micro: int) -> Dict[str, float]:
    bubble = (n_stages - 1) / (n_micro + n_stages - 1)
    return {"bubble_fraction": bubble, "utilisation": 1.0 - bubble}


def make_pipeline_fn(block_fn: Callable, mesh: Mesh, n_micro: int,
                     pipe_axis: str = "pipe"):
    """Returns pipelined(h, staged_params) -> h.

    ``block_fn(carry, layer_params) -> carry`` is the per-layer function
    (applied with an inner scan over the stage's layers).

    h: (n_micro, mb, S, d) microbatched activations, replicated entering the
    pipeline; staged params are sharded over the pipe axis.  Each device runs
    its stage for every microbatch in a rotating schedule; stage boundaries
    move via ``ppermute`` (the TPU collective-permute that maps onto
    neighbour ICI links).
    """
    n_stages = mesh.shape[pipe_axis]

    def stage_apply(stage_params, h_micro):
        def body(carry, lp):
            return block_fn(carry, lp), None
        out, _ = jax.lax.scan(body, h_micro, stage_params)
        return out

    def pipelined_local(staged_params, h):
        # staged_params: this device's (1, L/P, ...) slice; h: (n_micro, ...)
        stage_params = jax.tree.map(lambda x: x[0], staged_params)
        stage_id = jax.lax.axis_index(pipe_axis)
        n_steps = n_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def step(state, t):
            h_buf, out_buf, carry_in = state
            # which microbatch this stage works on at tick t
            mb_idx = t - stage_id
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            src = jnp.where(stage_id == 0,
                            h_buf[jnp.clip(mb_idx, 0, n_micro - 1)],
                            carry_in)
            out = stage_apply(stage_params, src)
            out = jnp.where(active, out, carry_in)
            # last stage banks its finished microbatch
            out_buf = jnp.where(
                active & (stage_id == n_stages - 1),
                out_buf.at[jnp.clip(mb_idx, 0, n_micro - 1)].set(out),
                out_buf)
            carry_next = jax.lax.ppermute(out, pipe_axis, perm)
            return (h_buf, out_buf, carry_next), None

        out_buf = jnp.zeros_like(h)
        carry0 = jnp.zeros_like(h[0])
        (_, out_buf, _), _ = jax.lax.scan(
            step, (h, out_buf, carry0), jnp.arange(n_steps))
        # broadcast the final microbatches from the last stage to all stages
        total = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, out_buf, jnp.zeros_like(out_buf)),
            pipe_axis)
        return total

    in_specs = (P(pipe_axis), P())          # params staged; activations repl.
    out_specs = P()
    try:
        return shard_map(pipelined_local, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:
        return shard_map(pipelined_local, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)
