"""Collective helpers for shard_map regions + cost models for napkin math."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def ring_allreduce_bytes(nbytes: int, n: int) -> float:
    """Bytes moved per device by a ring all-reduce of an n-way group."""
    return 2.0 * nbytes * (n - 1) / n


def allgather_bytes(shard_bytes: int, n: int) -> float:
    """Bytes received per device by an all-gather of n shards."""
    return shard_bytes * (n - 1)


def collective_seconds(nbytes_per_device: float, link_bw: float = 50e9) -> float:
    return nbytes_per_device / link_bw


def psum_scatter(x, axis_name: str):
    """Reduce-scatter across a mesh axis (ZeRO gradient sync primitive)."""
    return jax.lax.psum_scatter(x, axis_name, scatter_dimension=0, tiled=True)


def all_gather(x, axis_name: str):
    return jax.lax.all_gather(x, axis_name, tiled=True)
