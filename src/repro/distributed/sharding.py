"""Per-architecture sharding rules: DP / TP (Megatron) / EP / SP / FSDP.

``ShardingRules`` maps every parameter, optimizer-state, batch and cache leaf
to a ``PartitionSpec`` on the production mesh:

  * **TP** over the ``model`` axis: QKV / MLP-up column-parallel, O / MLP-down
    row-parallel, vocab-parallel embeddings, experts expert-parallel.
  * **FSDP/ZeRO** over the ``data`` axis: the *other* matrix dimension of each
    weight is sharded over data and all-gathered per layer by GSPMD; optimizer
    state inherits the same spec (fully sharded).
  * **DP** over ``("pod", "data")``: batch dims.  The pod axis is pure data
    parallelism — weights are pod-replicated, gradients all-reduce across pods
    (the compressed global-tier push attacks exactly these bytes).
  * **SP for caches**: KV caches shard heads over ``model`` when the head
    count divides it, otherwise the cache *sequence* dim shards over ``model``
    (sequence-parallel decode attention); the 500k-token batch-1 cell shards
    sequence over every axis.
  * SSM archs (no head dim divisible by model): batch shards over
    ``(data, model)`` jointly where divisible — all axes do data parallelism,
    weights FSDP over ``data``.

Every assignment is divisibility-guarded: a dim that does not divide the axis
size stays unsharded rather than failing to lower.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def _dim(leaf, i):
    return leaf.shape[i]


def _axis_entry(axes):
    """Collapse an axis collection into a canonical PartitionSpec entry:
    ``[] -> None``, ``['model'] -> 'model'`` (scalar, not a 1-tuple),
    ``['pod', 'data'] -> ('pod', 'data')``."""
    axes = tuple(axes)
    if not axes:
        return None
    if len(axes) == 1:
        return axes[0]
    return axes


def _has_axis(entry, name: str) -> bool:
    """Membership test on a spec entry that may be None, a scalar or a tuple."""
    if entry is None:
        return False
    if isinstance(entry, str):
        return entry == name
    return name in entry


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    cfg: ModelConfig
    fsdp: bool = True

    def __post_init__(self):
        names = self.mesh.axis_names
        self.model_ax = "model" if "model" in names else None
        self.data_axs = tuple(a for a in names if a != "model")
        self.model_size = self.mesh.shape.get("model", 1)
        self.data_size = int(np.prod([self.mesh.shape[a] for a in self.data_axs])) \
            if self.data_axs else 1
        # trillion-scale params: extend FSDP across the pod axis too (ZeRO-3
        # over DCI) — weights must not be pod-replicated.
        fsdp_pod = (self.cfg.param_count() > 4e11 and "pod" in names)
        if not self.fsdp or "data" not in names:
            self.fsdp_ax = None
            self.fsdp_size = 1
        elif fsdp_pod:
            self.fsdp_ax = ("pod", "data")
            self.fsdp_size = self.mesh.shape["pod"] * self.mesh.shape["data"]
        else:
            self.fsdp_ax = "data"
            self.fsdp_size = self.mesh.shape.get("data", 1)

    # -- helpers ------------------------------------------------------------------

    def _maybe(self, ax: Optional[str], size: int, dim: int):
        """Assign axis only if the dim divides its size."""
        if ax is None or dim % max(size, 1) != 0 or size == 1:
            return None
        return ax

    def _model(self, dim: int):
        return self._maybe(self.model_ax, self.model_size, dim)

    def _fsdp(self, dim: int):
        return self._maybe(self.fsdp_ax, self.fsdp_size, dim)

    def _batch_axes(self, b: int, wide: bool = False):
        """Axes for a batch dim; ``wide`` also folds in the model axis (SSM DP)."""
        axs = []
        rem = b
        for a in self.data_axs + ((("model",) if wide and self.model_ax else ())):
            sz = self.mesh.shape[a]
            if rem % sz == 0:
                axs.append(a)
                rem //= sz
        return _axis_entry(axs)

    # -- parameter rules ----------------------------------------------------------

    def _param_rule(self, path: str, leaf) -> P:
        nd = leaf.ndim
        cfg = self.cfg
        name = path.split("'")[-2] if "'" in path else path

        def tail(*axes):
            """Spec for the trailing len(axes) dims; leading dims unsharded."""
            axes = list(axes)
            lead = nd - len(axes)
            if lead < 0:
                axes = axes[-nd:] if nd else []
                lead = 0
            return P(*([None] * lead + axes))

        ssm_weight = ".mamba" in path or "'mamba'" in path

        if name == "embed":
            return tail(self._model(_dim(leaf, 0)), self._fsdp(_dim(leaf, 1)))
        if name == "unembed":
            return tail(self._fsdp(_dim(leaf, 0)), self._model(_dim(leaf, 1)))

        if "moe" in path and name in ("w_gate", "w_up") and nd >= 3:
            return tail(self._model(_dim(leaf, nd - 3)),       # experts
                        self._fsdp(_dim(leaf, nd - 2)), None)
        if "moe" in path and name == "w_down" and nd >= 3:
            return tail(self._model(_dim(leaf, nd - 3)), None,
                        self._fsdp(_dim(leaf, nd - 1)))
        if name == "router":
            return tail(self._fsdp(_dim(leaf, nd - 2)), None)

        if ssm_weight:
            # SSM weights: FSDP only (head counts rarely divide the model axis)
            if name == "w_in":
                return tail(self._fsdp(_dim(leaf, nd - 2)), None)
            if name == "w_out":
                return tail(None, self._fsdp(_dim(leaf, nd - 1)))
            if name == "conv_w":
                return tail(None, None)
            return tail(*([None] * min(nd, 1)))

        if name in ("wq", "wk", "wv"):
            return tail(self._fsdp(_dim(leaf, nd - 2)), self._model(_dim(leaf, nd - 1)))
        if name == "wo":
            return tail(self._model(_dim(leaf, nd - 2)), self._fsdp(_dim(leaf, nd - 1)))
        if name in ("bq", "bk", "bv", "b_up"):
            return tail(self._model(_dim(leaf, nd - 1)))
        if name in ("w_gate", "w_up"):                         # dense / shared MLP
            return tail(self._fsdp(_dim(leaf, nd - 2)), self._model(_dim(leaf, nd - 1)))
        if name == "w_down":
            return tail(self._model(_dim(leaf, nd - 2)), self._fsdp(_dim(leaf, nd - 1)))

        # norms, small vectors, biases on d_model: replicated
        return P(*([None] * nd))

    def params_specs(self, params_shapes) -> Any:
        def rule(path, leaf):
            return self._param_rule(jax.tree_util.keystr(path), leaf)
        return jax.tree_util.tree_map_with_path(rule, params_shapes)

    def params_shardings(self, params_shapes) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.params_specs(params_shapes))

    # -- optimizer state: inherit the param spec where shapes match -----------------

    def opt_specs(self, opt_shapes, params_shapes) -> Any:
        pspecs = self.params_specs(params_shapes)
        pshapes = {tuple(l.shape) for l in jax.tree.leaves(params_shapes)}
        by_shape: Dict[tuple, P] = {}
        for l, s in zip(jax.tree.leaves(params_shapes),
                        jax.tree.leaves(pspecs)):
            by_shape.setdefault(tuple(l.shape), s)

        def rule(leaf):
            return by_shape.get(tuple(leaf.shape), P(*([None] * leaf.ndim)))
        return jax.tree.map(rule, opt_shapes)

    # -- batch / activation rules ------------------------------------------------------

    def _wide_batch(self) -> bool:
        """SSM/hybrid archs do pure DP across every axis (incl. model)."""
        return self.cfg.family in ("ssm", "hybrid")

    def batch_specs(self, input_specs: Dict[str, Any], shape: ShapeConfig) -> Any:
        wide = self._wide_batch()

        def spec_for_input(leaf):
            b_axes = self._batch_axes(leaf.shape[0], wide=wide)
            return P(*([b_axes] + [None] * (leaf.ndim - 1)))

        out = {}
        for k, v in input_specs.items():
            if k == "cache":
                out[k] = self.cache_specs(v)
            else:
                out[k] = jax.tree.map(spec_for_input, v)
        return out

    def cache_specs(self, cache_shapes) -> Any:
        """Cache leaves: (L, B, S, K, D) attn / (L, B, W, C) conv / (L, B, H, P, N) ssm."""
        wide = self._wide_batch()

        def rule(path, leaf):
            name = jax.tree_util.keystr(path)
            nd = leaf.ndim
            batch_dim = 1                      # all caches are (L, B, ...)
            b_axes = self._batch_axes(leaf.shape[batch_dim], wide=wide)
            spec = [None] * nd
            spec[batch_dim] = b_axes
            if ("'k'" in name or "'v'" in name or "'ck'" in name
                    or "'cv'" in name or "first_" in name) and nd == 5:
                L, B, S, K, D = leaf.shape
                model_used = _has_axis(b_axes, "model")
                if self._model(K) is not None and not model_used:
                    spec[3] = self._model(K)
                    model_used = True
                # sequence-parallel cache: any axis not already used shards S
                # (few KV heads -> model; batch-1 long-context -> data too).
                seq_axes = []
                rem = S
                if b_axes is None:
                    for a in self.data_axs:
                        if rem % self.mesh.shape[a] == 0:
                            seq_axes.append(a)
                            rem //= self.mesh.shape[a]
                if (self.model_ax and not model_used
                        and rem % self.model_size == 0):
                    seq_axes.append(self.model_ax)
                spec[2] = _axis_entry(seq_axes)
            elif "'ssm'" in name and nd == 5:
                L, B, H, Pd, N = leaf.shape
                if not _has_axis(b_axes, "model"):
                    if self._model(N) is not None and \
                            not _has_axis(b_axes, self.model_ax or ""):
                        spec[4] = self._model(N)
            elif "'conv'" in name and nd == 4:
                L, B, W, C = leaf.shape
                if not _has_axis(b_axes, "model"):
                    if self._model(C) is not None and \
                            not _has_axis(b_axes, self.model_ax or ""):
                        spec[3] = self._model(C)
            return P(*spec)

        return jax.tree_util.tree_map_with_path(rule, cache_shapes)

    # -- logits / outputs --------------------------------------------------------------

    def logits_spec(self, batch: int) -> P:
        b_axes = self._batch_axes(batch, wide=self._wide_batch())
        return P(b_axes, self._model(self.cfg.vocab_size))

    def scalar_spec(self) -> P:
        return P()
