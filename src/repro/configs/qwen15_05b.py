"""qwen1.5-0.5b — dense, 24L d1024 16H (MHA kv=16) d_ff=2816 vocab=151936.

QKV bias, tied embeddings.  [hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    norm_eps=1e-6,
    mlp_act="silu",
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)
