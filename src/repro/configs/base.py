"""Base configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`.  The config is a
frozen dataclass so it can be used as a cache key for Proto-Faaslet executable
snapshots (see ``core/proto.py``) and hashed into dry-run artifact names.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    ``family`` selects the block structure:
      * ``dense``  — decoder-only transformer (GQA attention + gated MLP)
      * ``moe``    — decoder-only with mixture-of-experts MLPs
      * ``ssm``    — attention-free Mamba2 (SSD) stack
      * ``hybrid`` — Mamba2 backbone with a *shared* attention block applied
                     every ``attn_every`` layers (Zamba2 style)
      * ``encdec`` — encoder/decoder transformer (Whisper style); the audio conv
                     frontend is a stub: ``input_specs`` supplies frame embeddings
      * ``vlm``    — decoder-only LM consuming stubbed vision patch embeddings
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads

    # --- attention options ---------------------------------------------------
    qkv_bias: bool = False
    o_bias: bool = False
    qk_norm: bool = False              # Qwen3-style per-head RMSNorm on q/k
    rope_theta: float = 10_000.0
    use_rope: bool = True
    causal: bool = True

    # --- norms / MLP ----------------------------------------------------------
    norm_type: str = "rmsnorm"         # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-5
    mlp_act: str = "silu"              # "silu" (gated) | "gelu" (plain 2-matrix)
    mlp_bias: bool = False
    tie_embeddings: bool = False

    # --- MoE ------------------------------------------------------------------
    n_experts: int = 0                 # routed experts (0 = dense MLP)
    experts_per_token: int = 0         # top-k
    n_shared_experts: int = 0          # always-on experts (DeepSeek style)
    moe_d_ff: int = 0                  # per-expert hidden size (fine-grained MoE)
    first_k_dense: int = 0             # leading layers with a dense MLP
    dense_d_ff: int = 0                # hidden size of those dense layers
    router_aux_coef: float = 0.001     # load-balance aux loss coefficient
    capacity_factor: float = 1.25      # EP dispatch capacity factor

    # --- SSM (Mamba2 / SSD) -----------------------------------------------------
    ssm_state: int = 0                 # N: state dimension per head
    ssm_headdim: int = 64              # P: channels per SSD head
    ssm_expand: int = 2                # d_inner = expand * d_model
    ssm_conv: int = 4                  # depthwise causal conv width
    ssm_ngroups: int = 1               # B/C groups
    ssm_chunk: int = 256               # SSD chunk length

    # --- hybrid (Zamba2) --------------------------------------------------------
    attn_every: int = 0                # shared attn block applied every k layers

    # --- encoder/decoder (Whisper) ----------------------------------------------
    n_enc_layers: int = 0
    n_frames: int = 0                  # encoder sequence length (post-conv stub)

    # --- VLM ----------------------------------------------------------------------
    n_image_tokens: int = 0            # stubbed ViT patch embeddings prepended

    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    max_seq_len: int = 1 << 19

    # --- provenance -------------------------------------------------------------
    source: str = ""

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- derived quantities ----------------------------------------------------

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch supports 500k-token decode (SSM or hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    # -- parameter counting (used for 6·N·D roofline MODEL_FLOPS) ---------------

    def _attn_params(self) -> int:
        p = self.d_model * (self.q_dim + 2 * self.kv_dim)       # QKV
        p += self.q_dim * self.d_model                           # O
        if self.qkv_bias:
            p += self.q_dim + 2 * self.kv_dim
        if self.qk_norm:
            p += 2 * self.head_dim
        return p

    def _dense_mlp_params(self, d_ff: int) -> int:
        if self.mlp_act == "silu":                               # gated: 3 matrices
            return 3 * self.d_model * d_ff
        return 2 * self.d_model * d_ff                           # plain: 2 matrices

    def _expert_params(self) -> int:
        return 3 * self.d_model * self.moe_d_ff                  # gated expert

    def _ssm_params(self) -> int:
        d_in, N, H = self.d_inner, self.ssm_state, self.ssm_nheads
        G = self.ssm_ngroups
        zxbcdt = self.d_model * (2 * d_in + 2 * G * N + H)       # fused in-proj
        conv = self.ssm_conv * (d_in + 2 * G * N)
        extra = 2 * H + d_in                                      # A_log, D, gate norm
        out = d_in * self.d_model
        return zxbcdt + conv + extra + out

    def _norm_params(self) -> int:
        mult = 2 if self.norm_type == "layernorm" else 1
        return mult * self.d_model

    def layer_params(self, layer_idx: int) -> int:
        """Parameter count of one block (routed + shared experts included)."""
        if self.family in ("ssm",):
            return self._ssm_params() + self._norm_params()
        if self.family == "hybrid":
            return self._ssm_params() + self._norm_params()
        p = self._attn_params() + 2 * self._norm_params()
        if self.n_experts and layer_idx >= self.first_k_dense:
            p += self.n_experts * self._expert_params()
            p += self.n_shared_experts * self._expert_params()
            p += self.d_model * self.n_experts                   # router
        elif self.n_experts:
            p += self._dense_mlp_params(self.dense_d_ff or self.d_ff)
        else:
            p += self._dense_mlp_params(self.d_ff)
        return p

    def param_count(self) -> int:
        """Total parameters N."""
        p = self.vocab_size * self.d_model                        # embed
        if not self.tie_embeddings:
            p += self.vocab_size * self.d_model                   # unembed
        p += self._norm_params()                                  # final norm
        p += sum(self.layer_params(i) for i in range(self.n_layers))
        if self.family == "hybrid" and self.attn_every:
            # one *shared* attention+MLP block (counted once: weights are tied)
            p += self._attn_params() + self._dense_mlp_params(self.d_ff)
            p += 2 * self._norm_params()
        if self.family == "encdec":
            enc_layer = self._attn_params() + self._dense_mlp_params(self.d_ff) \
                + 2 * self._norm_params()
            p += self.n_enc_layers * enc_layer
            # decoder cross-attention
            p += self.n_layers * (self._attn_params() + self._norm_params())
            p += self.n_frames * self.d_model                     # enc positions
            p += self.max_decoder_positions() * self.d_model      # dec positions
        return p

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top-k routed only)."""
        if not self.n_experts:
            n = self.param_count()
            if self.family == "hybrid":
                return n
            return n
        dense = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            dense += self.vocab_size * self.d_model
        dense += self._norm_params()
        for i in range(self.n_layers):
            dense += self._attn_params() + 2 * self._norm_params()
            if i < self.first_k_dense:
                dense += self._dense_mlp_params(self.dense_d_ff or self.d_ff)
            else:
                k = self.experts_per_token + self.n_shared_experts
                dense += k * self._expert_params()
                dense += self.d_model * self.n_experts
        return dense

    def max_decoder_positions(self) -> int:
        return 448 if self.family == "encdec" else self.max_seq_len

    def with_overrides(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Input-shape configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (seq_len, global_batch) workload cell."""

    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int         # train/prefill: tokens processed; decode: KV cache length
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.global_batch * self.seq_len


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; reason when skipped.

    ``long_500k`` needs sub-quadratic sequence mixing — skipped for pure
    full-attention archs per the assignment (documented in DESIGN.md).
    """
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "long_500k skipped: pure full-attention arch (no sub-quadratic path)"
    return True, ""
