"""zamba2-1.2b — hybrid, 38 Mamba2 layers + one *shared* attention block.

d_model=2048, shared block: 32H (MHA kv=32) d_ff=8192; ssm_state=64,
vocab=32000.  The shared transformer block's weights are tied across all of its
applications (every ``attn_every`` Mamba2 layers) — the Zamba2 parameter-sharing
trick.  [arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32_000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_ngroups=1,
    attn_every=6,            # shared attention block applied every 6 mamba layers
    rope_theta=10_000.0,
    norm_type="rmsnorm",
    norm_eps=1e-5,
    mlp_act="gelu",
    tie_embeddings=True,
    source="arXiv:2411.15242",
)
