"""qwen3-4b — dense, 36L d2560 32H (GQA kv=8) d_ff=9728 vocab=151936.

QK-norm (per-head RMSNorm on q and k), head_dim=128 as published (explicit, not
d_model/n_heads).  [hf:Qwen/Qwen3-4B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    norm_eps=1e-6,
    mlp_act="silu",
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-4B",
)
