"""deepseek-moe-16b — fine-grained MoE, 28L d2048 16H (MHA kv=16).

Per-expert d_ff=1408; 64 routed experts top-6 + 2 shared experts; first layer
dense (d_ff=10944); vocab=102400.  [arXiv:2401.06066; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,               # per-expert hidden (assigned table value)
    moe_d_ff=1408,
    vocab_size=102_400,
    n_experts=64,
    experts_per_token=6,
    n_shared_experts=2,
    first_k_dense=1,
    dense_d_ff=10_944,
    rope_theta=10_000.0,
    norm_type="rmsnorm",
    norm_eps=1e-6,
    mlp_act="silu",
    source="arXiv:2401.06066",
)
