"""mamba2-130m — attention-free SSD (state-space duality) stack.

24L d768, ssm_state=128, expand=2 (d_inner=1536), headdim=64 (24 SSD heads),
vocab=50280.  [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                  # attention-free, no MLP (Mamba2 block is the mixer)
    vocab_size=50_280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_ngroups=1,
    use_rope=False,
    norm_type="rmsnorm",
    norm_eps=1e-5,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
