from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    shape_applicable,
)
from repro.configs.registry import (
    ARCHS,
    arch_ids,
    cells,
    get_config,
    get_shape,
    smoke_config,
    smoke_shape,
)

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "shape_applicable", "ARCHS", "arch_ids", "cells",
    "get_config", "get_shape", "smoke_config", "smoke_shape",
]
