"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config).

61L d7168 64H (GQA kv=8, per the assigned table — the released model uses MLA;
we follow the assignment), per-expert d_ff=2048, 384 routed experts top-8 +
1 shared, first layer dense, vocab=163840.  Total ≈ 1.03 T params, ≈ 32 B active.
[arXiv:2501.kimi2; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,               # per-expert hidden (assigned table value)
    moe_d_ff=2048,
    vocab_size=163_840,
    n_experts=384,
    experts_per_token=8,
    n_shared_experts=1,
    first_k_dense=1,
    dense_d_ff=18_432,
    rope_theta=50_000.0,
    norm_type="rmsnorm",
    norm_eps=1e-6,
    mlp_act="silu",
    source="arXiv:2501.kimi2 (paper-table)",
)
