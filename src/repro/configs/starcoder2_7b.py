"""starcoder2-7b — dense, 32L d4608 36H (GQA kv=4) d_ff=18432 vocab=49152.

GQA + RoPE; LayerNorm + biased plain-GELU MLP per the published model.
[arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18_432,
    vocab_size=49_152,
    qkv_bias=True,
    o_bias=True,
    rope_theta=100_000.0,
    norm_type="layernorm",
    norm_eps=1e-5,
    mlp_act="gelu",
    mlp_bias=True,
    source="arXiv:2402.19173",
)
