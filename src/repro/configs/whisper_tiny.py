"""whisper-tiny — encoder/decoder audio transformer backbone.

4 enc + 4 dec layers, d_model=384, 6H (MHA kv=6), d_ff=1536, vocab=51865.
The conv audio frontend is a STUB: ``input_specs()`` supplies precomputed frame
embeddings of shape (batch, 1500, 384) — per the assignment, the backbone only.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,              # decoder layers
    n_enc_layers=4,
    n_frames=1500,           # encoder positions after the (stubbed) conv frontend
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    use_rope=False,          # whisper uses absolute positions
    qkv_bias=True,
    o_bias=True,
    norm_type="layernorm",
    norm_eps=1e-5,
    mlp_act="gelu",
    mlp_bias=True,
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
