"""Architecture registry: ``--arch <id>`` lookup, smoke-config reduction.

``get_config(arch_id)`` returns the full published config; ``smoke_config(arch_id)``
returns a reduced config of the same family (small widths, few experts, tiny vocab)
used by the CPU smoke tests.  Full configs are only ever *lowered* (ShapeDtypeStruct,
no allocation) via the dry-run.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable

from repro.configs.qwen15_05b import CONFIG as _QWEN15
from repro.configs.starcoder2_7b import CONFIG as _STARCODER2
from repro.configs.granite3_8b import CONFIG as _GRANITE3
from repro.configs.qwen3_4b import CONFIG as _QWEN3
from repro.configs.zamba2_12b import CONFIG as _ZAMBA2
from repro.configs.whisper_tiny import CONFIG as _WHISPER
from repro.configs.deepseek_moe_16b import CONFIG as _DSMOE
from repro.configs.kimi_k2 import CONFIG as _KIMI
from repro.configs.mamba2_130m import CONFIG as _MAMBA2
from repro.configs.internvl2_2b import CONFIG as _INTERNVL

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _QWEN15, _STARCODER2, _GRANITE3, _QWEN3, _ZAMBA2,
        _WHISPER, _DSMOE, _KIMI, _MAMBA2, _INTERNVL,
    )
}


def arch_ids() -> List[str]:
    return list(ARCHS.keys())


def get_config(arch_id: str) -> ModelConfig:
    try:
        return ARCHS[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(ARCHS)}") from None


def get_shape(shape_id: str) -> ShapeConfig:
    try:
        return SHAPES[shape_id]
    except KeyError:
        raise KeyError(
            f"unknown shape {shape_id!r}; available: {', '.join(SHAPES)}") from None


def cells(include_skipped: bool = False):
    """Yield every assigned (arch, shape) cell, with applicability."""
    for arch_id, cfg in ARCHS.items():
        for shape_id, shape in SHAPES.items():
            ok, reason = shape_applicable(cfg, shape)
            if ok or include_skipped:
                yield arch_id, shape_id, ok, reason


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (one fwd/train step)."""
    cfg = get_config(arch_id)
    kw = dict(
        name=f"{cfg.name}-smoke",
        n_layers=min(cfg.n_layers, 3),
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=257,
        max_seq_len=1 << 12,
    )
    if cfg.n_heads:
        kw.update(
            n_heads=4,
            n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
            head_dim=16,
        )
    if cfg.n_experts:
        kw.update(n_experts=8, experts_per_token=2,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  moe_d_ff=32, d_ff=32, dense_d_ff=96,
                  first_k_dense=min(cfg.first_k_dense, 1),
                  capacity_factor=8.0)   # effectively dropless at smoke scale
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        kw.update(attn_every=2, n_layers=4)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, n_frames=8, n_layers=2)
    if cfg.family == "vlm":
        kw.update(n_image_tokens=4)
    return cfg.with_overrides(**kw)


def smoke_shape(kind: str = "train") -> ShapeConfig:
    """Tiny shape for smoke tests."""
    if kind == "train":
        return ShapeConfig("smoke_train", "train", 32, 2)
    if kind == "prefill":
        return ShapeConfig("smoke_prefill", "prefill", 32, 2)
    return ShapeConfig("smoke_decode", "decode", 32, 2)
