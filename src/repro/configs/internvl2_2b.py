"""internvl2-2b — VLM: InternLM2-1.8B language backbone + stubbed InternViT.

LM backbone: 24L d2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The vision
frontend is a STUB per the assignment: ``input_specs()`` supplies precomputed
patch embeddings (batch, 256, d_model) already projected into LM space.
[arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_553,
    n_image_tokens=256,
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    norm_eps=1e-5,
    mlp_act="silu",
    source="arXiv:2404.16821",
)
