"""Deterministic synthetic data pipeline, sharded per data-parallel rank.

Every batch is a pure function of (seed, step, shard) — restarts and elastic
rescaling replay identical data without coordination state (the pipeline
itself needs no checkpoint beyond the step counter).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class PipelineConfig:
    seed: int = 0
    n_shards: int = 1
    shard: int = 0


def _rng(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


def make_batch(cfg: ModelConfig, shape: ShapeConfig, pc: PipelineConfig,
               step: int) -> Dict[str, np.ndarray]:
    """One train batch for this shard (global_batch // n_shards rows)."""
    rng = _rng(pc.seed, step, pc.shard)
    B = shape.global_batch // pc.n_shards
    S = shape.seq_len
    St = S - cfg.n_image_tokens if cfg.family == "vlm" else S
    # Markov-ish token stream so the LM has learnable structure.
    toks = rng.integers(0, cfg.vocab_size, size=(B, St + 1), dtype=np.int64)
    repeat = rng.random((B, St + 1)) < 0.5
    for t in range(1, St + 1):
        toks[:, t] = np.where(repeat[:, t], toks[:, t - 1], toks[:, t])
    batch = {
        "tokens": toks[:, :-1].astype(np.int32),
        "targets": toks[:, 1:].astype(np.int32),
        "mask": np.ones((B, St), np.float32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = rng.standard_normal(
            (B, cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (B, cfg.n_frames, cfg.d_model)).astype(np.float32)
    return batch


def batch_iterator(cfg: ModelConfig, shape: ShapeConfig,
                   pc: Optional[PipelineConfig] = None,
                   start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    pc = pc or PipelineConfig()
    step = start_step
    while True:
        yield make_batch(cfg, shape, pc, step)
        step += 1
