from repro.data.pipeline import PipelineConfig, batch_iterator, make_batch
from repro.data.sparse import accuracy, hinge_loss, make_sparse_dataset

__all__ = ["PipelineConfig", "batch_iterator", "make_batch",
           "accuracy", "hinge_loss", "make_sparse_dataset"]
