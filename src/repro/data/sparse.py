"""Synthetic RCV1-like sparse text-classification data (paper §6.2).

Generates a sparse feature matrix (features × examples, CSC-friendly) and
labels with a planted linear model, so HOGWILD! SGD measurably converges and
the training benchmark has a correctness signal, not just throughput.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def make_sparse_dataset(n_features: int = 512, n_examples: int = 4096,
                        density: float = 0.05, seed: int = 0
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (dense X (features, examples), labels (examples,), w_true)."""
    rng = np.random.default_rng(seed)
    X = np.zeros((n_features, n_examples), np.float32)
    nnz = int(density * n_features)
    for c in range(n_examples):
        idx = rng.choice(n_features, size=nnz, replace=False)
        X[idx, c] = rng.standard_normal(nnz).astype(np.float32)
    w_true = rng.standard_normal(n_features).astype(np.float32)
    margin = w_true @ X
    y = (margin > 0).astype(np.float32) * 2 - 1        # ±1 labels
    return X, y, w_true


def hinge_loss(w: np.ndarray, X: np.ndarray, y: np.ndarray) -> float:
    margins = y * (w @ X)
    return float(np.maximum(0.0, 1.0 - margins).mean())


def accuracy(w: np.ndarray, X: np.ndarray, y: np.ndarray) -> float:
    return float((np.sign(w @ X) == y).mean())
