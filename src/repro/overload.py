"""Overload control plane: deadlines, admission control, retry budgets,
circuit breakers, and the backpressured broadcast primitives.

The runtime's defence against overload is assembled from five small,
independently testable mechanisms, all defined here and threaded through
``repro.core`` / ``repro.state``:

* :class:`Deadline` — an absolute expiry on the telemetry clock, stamped on
  a :class:`~repro.core.runtime.Call` at ``invoke(deadline=...)`` and
  inherited by chained children (same absolute expiry ⇒ children get exactly
  the remaining budget).  Enforced at admission (already-expired work settles
  :data:`DEADLINE_RC` without dispatching), at dequeue (remaining budget
  below the function's floor ⇒ shed before wasting an executor slot), and
  mid-execution through the ``cancellation.checkpoint`` plane (behaves like
  a cooperative cancel; the PR 7 attempt fence keeps the interrupted
  attempt's state effects exactly-once).
* bounded host queues + :class:`AdmissionPolicy` — ``Host.submit`` refuses
  work beyond ``capacity + max_queue_depth`` by raising :class:`QueueFull`;
  the dispatcher then spills down the rendezvous ranking to a peer with
  room, or settles the call fast with :data:`SHED_RC`.
* :class:`RetryBudget` — a token bucket refilled as a *fraction of
  successes*, so re-execution after host loss can never amplify a fault
  storm into a retry storm: once the bucket is dry, lost calls settle failed
  immediately instead of backoff-looping.
* :class:`CircuitBreaker` — per-host closed→open→half-open breaker fed by
  call outcomes; the scheduler consults it alongside ``has_capacity()`` so
  a persistently failing host stops receiving traffic until a half-open
  probe succeeds.
* :class:`CoalescingQueue` — the bounded per-subscriber frame queue behind
  ``GlobalTier.broadcast``'s pump threads.  Same-key frames collapse to the
  newest (the skipped predecessor becomes a version gap the subscriber's
  ``prev_version`` check already tolerates — the next delta pull repairs
  it); overflow drops the subscriber back to pull-repair entirely.  Either
  way the *pusher* never blocks on a slow subscriber.

Disarmed cost discipline (same contract as ``faults``/``telemetry``, asserted
by ``scripts/check_jax_pin.py``): a runtime built without an
:class:`OverloadPolicy` carries ``overload is None`` / ``_retry_budget is
None`` / ``_breakers is None``, and a call without a deadline carries
``deadline is None`` — every hook site in the hot path reduces to one
pointer compare.  There is no process-global state in this module.

faasmlint's ``bounded-queue`` rule enforces that data-plane modules
(``core/``, ``state/``) never construct a raw unbounded ``queue.Queue``;
:func:`bounded_queue` is the blessed factory (depth explicit, shedding
semantics documented at the construction site).
"""
from __future__ import annotations

import queue
import threading
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

from repro.telemetry import clock as tclock

# Return codes surfaced to waiters.  SHED_RC predates this module (the
# degraded-serving path in launch/serve.py); it is canonical here now and
# re-exported there.  Both are negative so they can never collide with a
# function's own nonzero failure codes.
SHED_RC = -2          # refused at admission: bounded queue full, no peer had room
DEADLINE_RC = -3      # end-to-end deadline expired (admission, dequeue or mid-exec)

DEFAULT_NET_QUEUE_DEPTH = 1024   # virtual-socket mailboxes (runtime._net)
DEFAULT_BCAST_DEPTH = 8          # per-subscriber broadcast frames in flight


class QueueFull(RuntimeError):
    """A host's bounded admission queue refused a call.  The dispatcher
    catches this and spills to a peer or sheds with :data:`SHED_RC` —
    user code never sees it."""


def bounded_queue(maxsize: int = DEFAULT_NET_QUEUE_DEPTH) -> "queue.Queue":
    """The lint-blessed queue constructor for data-plane modules.

    Raw ``queue.Queue()`` (unbounded) in ``core/`` or ``state/`` is a
    faasmlint ``bounded-queue`` violation: an unbounded queue converts
    overload into unbounded memory growth and unbounded latency, invisibly.
    Constructing through this factory makes the depth an explicit, reviewed
    decision."""
    if maxsize <= 0:
        raise ValueError("bounded_queue needs a positive depth; use "
                         "queue.Queue() with a lint suppression if you "
                         "really mean unbounded")
    return queue.Queue(maxsize=maxsize)


# --------------------------------------------------------------------- deadlines

@dataclass(frozen=True)
class Deadline:
    """An absolute end-to-end expiry on the telemetry clock.

    Children of a deadlined call inherit the *same* object: the expiry is
    absolute, so an inherited deadline is exactly the parent's remaining
    budget — no per-hop re-derivation, no budget inflation across a chain.
    """

    expires_at: float          # absolute, repro.telemetry.clock base
    budget_s: float            # original budget (introspection only)

    @classmethod
    def after(cls, budget_s: float) -> "Deadline":
        """Deadline ``budget_s`` seconds from now."""
        budget_s = float(budget_s)
        if budget_s <= 0.0:
            raise ValueError("deadline budget must be positive")
        return cls(expires_at=tclock.now() + budget_s, budget_s=budget_s)

    def remaining(self) -> float:
        """Seconds of budget left (negative once expired)."""
        return self.expires_at - tclock.now()

    def expired(self) -> bool:
        return tclock.now() >= self.expires_at


# --------------------------------------------------------------- admission policy

@dataclass
class AdmissionPolicy:
    """What to do with a call that hits a full host queue.

    ``spill=True`` (default): try peers down the rendezvous ranking first,
    shed only when nobody has room.  ``spill=False``: shed immediately —
    the latency-strict policy (a spilled call pays another placement and
    possibly a cold start).  Subclass and override :meth:`on_full` for
    anything richer (e.g. priority classes)."""

    spill: bool = True

    def on_full(self, call) -> str:
        """Return ``"spill"`` or ``"shed"`` for a call refused by its
        target host's bounded queue."""
        return "spill" if self.spill else "shed"


# ------------------------------------------------------------------ retry budget

class RetryBudget:
    """Token-bucket retry budget: retries can never exceed ~``ratio`` of
    successful traffic.

    Every successful call refills ``ratio`` tokens (capped at ``burst``);
    every re-execution spends one whole token.  A fault storm that kills
    more work than the cluster completes drains the bucket, after which
    lost calls settle failed immediately instead of amplifying the storm
    with backoff-retry loops.  All methods are thread-safe."""

    def __init__(self, ratio: float = 0.1, burst: float = 20.0,
                 initial: Optional[float] = None):
        if ratio < 0.0 or burst <= 0.0:
            raise ValueError("ratio must be >= 0 and burst > 0")
        self.ratio = float(ratio)
        self.burst = float(burst)
        self._tokens = float(burst if initial is None else initial)
        self._mu = threading.Lock()
        self.spent_total = 0
        self.denied_total = 0

    def try_spend(self) -> bool:
        """Take one token if available.  False ⇒ budget exhausted: the
        caller must settle the call failed, not retry."""
        with self._mu:
            if self._tokens >= 1.0:
                self._tokens -= 1.0
                self.spent_total += 1
                return True
            self.denied_total += 1
            return False

    def on_success(self) -> None:
        """Refill from a completed call (``ratio`` tokens, capped)."""
        with self._mu:
            self._tokens = min(self.burst, self._tokens + self.ratio)

    def fill_ratio(self) -> float:
        """Bucket fullness in [0, 1] (for the metrics gauge)."""
        with self._mu:
            return self._tokens / self.burst


# --------------------------------------------------------------- circuit breaker

class CircuitBreaker:
    """Per-host breaker: closed → open on failure-rate-over-window,
    half-open probes before readmitting.

    ``record(ok)`` feeds call outcomes into a sliding window of the last
    ``window`` calls; once at least ``min_volume`` outcomes are in and the
    failure fraction reaches ``failure_ratio``, the breaker opens for
    ``reset_timeout_s``.  While open, :meth:`allow` refuses placement.
    After the timeout it goes half-open and admits up to ``probes``
    in-flight probe calls: one probe success closes it (window reset), one
    probe failure re-opens it for another full timeout."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"

    def __init__(self, window: int = 16, failure_ratio: float = 0.5,
                 min_volume: int = 4, reset_timeout_s: float = 0.25,
                 probes: int = 1):
        assert window > 0 and 0.0 < failure_ratio <= 1.0
        assert min_volume >= 1 and reset_timeout_s > 0.0 and probes >= 1
        self.window = window
        self.failure_ratio = failure_ratio
        self.min_volume = min_volume
        self.reset_timeout_s = reset_timeout_s
        self.probes = probes
        self._mu = threading.Lock()
        self._outcomes: deque = deque(maxlen=window)
        self._state = self.CLOSED
        self._opened_at = 0.0
        self._probes_inflight = 0
        self.opened_total = 0

    @property
    def state(self) -> str:
        with self._mu:
            return self._state

    def _trip_locked(self) -> None:
        self._state = self.OPEN
        self._opened_at = tclock.now()
        self._probes_inflight = 0
        self._outcomes.clear()
        self.opened_total += 1

    def trip(self) -> None:
        """Force open (e.g. the host was declared dead outright)."""
        with self._mu:
            self._trip_locked()

    def allow(self) -> bool:
        """May the scheduler place a call on this host right now?
        A True answer in half-open state claims one probe slot; report the
        probe's outcome through :meth:`record`."""
        with self._mu:
            if self._state == self.CLOSED:
                return True
            if self._state == self.OPEN:
                if tclock.now() - self._opened_at < self.reset_timeout_s:
                    return False
                self._state = self.HALF_OPEN
                self._probes_inflight = 0
            # half-open: admit up to `probes` concurrent probe calls
            if self._probes_inflight < self.probes:
                self._probes_inflight += 1
                return True
            return False

    def record(self, ok: bool) -> None:
        """Feed one call outcome (True = success)."""
        with self._mu:
            if self._state == self.HALF_OPEN:
                self._probes_inflight = max(0, self._probes_inflight - 1)
                if ok:
                    self._state = self.CLOSED
                    self._outcomes.clear()
                else:
                    self._trip_locked()
                return
            if self._state == self.OPEN:
                return                    # zombie outcome from before the trip
            self._outcomes.append(ok)
            n = len(self._outcomes)
            if n >= self.min_volume:
                failures = sum(1 for o in self._outcomes if not o)
                if failures / n >= self.failure_ratio:
                    self._trip_locked()


# ------------------------------------------------------ backpressured broadcast

class CoalescingQueue:
    """Bounded per-subscriber frame queue with same-key coalescing.

    The broadcast pump drains this on its own thread, so the *pusher* only
    ever pays a dict insert under a short lock.  Three outcomes per put:

    * ``"queued"``    — new key, depth available.
    * ``"coalesced"`` — a frame for this key was already waiting and is
      replaced by the newer one (in place, preserving arrival order).  The
      replaced frame becomes a version gap at the subscriber, which its
      ``prev_version`` check skips and the next delta pull repairs.
    * ``"overflow"``  — at depth with all-distinct keys: the caller should
      drop this subscriber back to pull-repair entirely.

    ``drain()`` hands the pump everything queued, oldest first."""

    def __init__(self, depth: int = DEFAULT_BCAST_DEPTH):
        assert depth >= 1
        self.depth = depth
        self._mu = threading.Lock()
        self._items: "OrderedDict[str, object]" = OrderedDict()

    def put(self, key: str, item) -> str:
        with self._mu:
            if key in self._items:
                self._items[key] = item          # collapse to newest
                return "coalesced"
            if len(self._items) >= self.depth:
                return "overflow"
            self._items[key] = item
            return "queued"

    def drain(self) -> List[Tuple[str, object]]:
        with self._mu:
            items = list(self._items.items())
            self._items.clear()
        return items

    def __len__(self) -> int:
        with self._mu:
            return len(self._items)


# ----------------------------------------------------------------- policy bundle

@dataclass
class OverloadPolicy:
    """Everything the runtime needs to defend itself, in one bundle.

    ``FaasmRuntime(overload=OverloadPolicy(...))`` arms the plane; the
    default (no policy) leaves every hook disarmed at one pointer compare.

    * ``max_queue_depth`` — per-host bound on calls queued beyond running
      capacity; ``None`` keeps today's unbounded behaviour.
    * ``default_deadline_s`` — stamped on any invoke that doesn't carry its
      own deadline (chained children still inherit their parent's).
    * ``deadline_floor_s`` — dequeue shed floor when the function doesn't
      declare its own ``FunctionDef.deadline_floor_s``.
    * ``retry_budget`` / ``breaker`` — see :class:`RetryBudget` /
      :class:`CircuitBreaker`; ``breaker`` is a zero-arg factory called
      once per host.
    * ``admission`` — full-queue decision, see :class:`AdmissionPolicy`.
    """

    max_queue_depth: Optional[int] = None
    default_deadline_s: Optional[float] = None
    deadline_floor_s: float = 0.0
    retry_budget: Optional[RetryBudget] = None
    breaker: Optional[Callable[[], CircuitBreaker]] = None
    admission: AdmissionPolicy = field(default_factory=AdmissionPolicy)
