"""Global state tier: a chunked, thread-safe distributed key-value store.

The authoritative copy of every state value (Faasm §4.2).  Values are byte
arrays (the paper's language-agnostic representation); large values are split
into fixed-size **state chunks** that can be pulled/pushed independently, so a
Faaslet replicates only the subsets it touches (Fig. 4, value C).

The store tracks per-host transfer bytes — the experiments' "network
transfer" metric (Fig. 6b) reads from here.  Global read/write locks per key
implement ``lock_state_global_read/write``.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

DEFAULT_CHUNK = 1 << 20          # 1 MiB state chunks


class RWLock:
    """Writer-preferring readers/writer lock."""

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self):
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self):
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_write(self):
        with self._cond:
            self._writers_waiting += 1
            while self._writer or self._readers:
                self._cond.wait()
            self._writers_waiting -= 1
            self._writer = True

    def release_write(self):
        with self._cond:
            self._writer = False
            self._cond.notify_all()


class GlobalTier:
    """In-memory stand-in for the distributed KVS backing the global tier.

    On a real deployment this is Redis/Anna sharded across hosts; here one
    process hosts the authoritative map, with the same chunk/locking/byte
    semantics, so every state-protocol decision (what is pulled, when, how
    many bytes) is real and measurable.
    """

    def __init__(self, chunk_size: int = DEFAULT_CHUNK):
        self.chunk_size = chunk_size
        self._store: Dict[str, bytearray] = {}
        self._locks: Dict[str, RWLock] = defaultdict(RWLock)
        self._mutex = threading.RLock()
        self.bytes_pulled: Dict[str, int] = defaultdict(int)    # per host
        self.bytes_pushed: Dict[str, int] = defaultdict(int)

    # -- basic KV -----------------------------------------------------------

    def exists(self, key: str) -> bool:
        with self._mutex:
            return key in self._store

    def keys(self) -> List[str]:
        with self._mutex:
            return list(self._store.keys())

    def size(self, key: str) -> int:
        with self._mutex:
            return len(self._store.get(key, b""))

    def delete(self, key: str) -> None:
        with self._mutex:
            self._store.pop(key, None)

    def get(self, key: str, *, host: str = "?") -> bytes:
        with self._mutex:
            val = bytes(self._store[key])
        self.bytes_pulled[host] += len(val)
        return val

    def set(self, key: str, value: bytes, *, host: str = "?") -> None:
        with self._mutex:
            self._store[key] = bytearray(value)
        self.bytes_pushed[host] += len(value)

    def append(self, key: str, value: bytes, *, host: str = "?") -> None:
        with self._mutex:
            self._store.setdefault(key, bytearray()).extend(value)
        self.bytes_pushed[host] += len(value)

    # -- chunked access ------------------------------------------------------

    def get_range(self, key: str, offset: int, length: int, *,
                  host: str = "?") -> bytes:
        with self._mutex:
            buf = self._store[key]
            if offset < 0 or offset + length > len(buf):
                raise IndexError(
                    f"state range [{offset}, {offset + length}) out of bounds "
                    f"for {key!r} of size {len(buf)}")
            val = bytes(buf[offset:offset + length])
        self.bytes_pulled[host] += length
        return val

    def set_range(self, key: str, offset: int, value: bytes, *,
                  host: str = "?") -> None:
        with self._mutex:
            buf = self._store.setdefault(key, bytearray())
            end = offset + len(value)
            if offset < 0:
                raise IndexError("negative state offset")
            if end > len(buf):
                buf.extend(b"\x00" * (end - len(buf)))
            buf[offset:end] = value
        self.bytes_pushed[host] += len(value)

    def n_chunks(self, key: str) -> int:
        sz = self.size(key)
        return max(1, -(-sz // self.chunk_size))

    def chunk_bounds(self, key: str, idx: int) -> Tuple[int, int]:
        sz = self.size(key)
        start = idx * self.chunk_size
        return start, min(self.chunk_size, sz - start)

    # -- global locks -------------------------------------------------------

    def lock(self, key: str) -> RWLock:
        with self._mutex:
            return self._locks[key]

    # -- metrics --------------------------------------------------------------

    def total_transfer(self) -> int:
        return sum(self.bytes_pulled.values()) + sum(self.bytes_pushed.values())

    def reset_metrics(self) -> None:
        self.bytes_pulled.clear()
        self.bytes_pushed.clear()
