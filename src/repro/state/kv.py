"""Global state tier: a chunked, thread-safe distributed key-value store.

The authoritative copy of every state value (Faasm §4.2).  Values are byte
arrays (the paper's language-agnostic representation); large values are split
into fixed-size **state chunks** that can be pulled/pushed independently, so a
Faaslet replicates only the subsets it touches (Fig. 4, value C).

Concurrency: the store is **lock-striped** — keys hash onto a fixed array of
stripes, each stripe owning its own mutex, sub-map and transfer counters, so
chunk transfers (``get_range``/``set_range``, the primitives behind
``LocalTier.pull_chunk``/``push_dirty``) on *different* keys never contend.
Per-key metadata (the global read/write lock implementing
``lock_state_global_read/write``, plus a write version) lives next to the
value in its stripe.

The store tracks per-host transfer bytes — the experiments' "network
transfer" metric (Fig. 6b) reads from here.
"""
from __future__ import annotations

import threading
import zlib
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

DEFAULT_CHUNK = 1 << 20          # 1 MiB state chunks
DEFAULT_STRIPES = 64


class RWLock:
    """Writer-preferring readers/writer lock."""

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self):
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self):
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_write(self):
        with self._cond:
            self._writers_waiting += 1
            while self._writer or self._readers:
                self._cond.wait()
            self._writers_waiting -= 1
            self._writer = True

    def release_write(self):
        with self._cond:
            self._writer = False
            self._cond.notify_all()


@dataclass
class KeyMeta:
    """Per-key metadata co-located with the value in its stripe."""

    version: int = 0                 # stripe-monotonic; stamped on every write


class _Stripe:
    """One lock stripe: a mutex guarding a sub-map of keys + its counters."""

    __slots__ = ("lock", "store", "meta", "locks", "vc", "pulled", "pushed")

    def __init__(self):
        self.lock = threading.RLock()
        self.store: Dict[str, bytearray] = {}
        self.meta: Dict[str, KeyMeta] = {}
        # RW locks live outside the meta map: a delete must not orphan a lock
        # some thread is holding, and version numbers draw from a monotonic
        # per-stripe counter so delete+recreate never aliases a cached version
        self.locks: Dict[str, RWLock] = {}
        self.vc = 0
        self.pulled: Dict[str, int] = {}     # per-host transfer bytes
        self.pushed: Dict[str, int] = {}

    def bump(self, key: str) -> None:
        self.vc += 1
        self.meta.setdefault(key, KeyMeta()).version = self.vc


class GlobalTier:
    """In-memory stand-in for the distributed KVS backing the global tier.

    On a real deployment this is Redis/Anna sharded across hosts; here one
    process hosts the authoritative map, with the same chunk/locking/byte
    semantics, so every state-protocol decision (what is pulled, when, how
    many bytes) is real and measurable.
    """

    def __init__(self, chunk_size: int = DEFAULT_CHUNK,
                 n_stripes: int = DEFAULT_STRIPES):
        self.chunk_size = chunk_size
        self.n_stripes = max(1, n_stripes)
        self._stripes = [_Stripe() for _ in range(self.n_stripes)]

    def _stripe(self, key: str) -> _Stripe:
        return self._stripes[zlib.crc32(key.encode()) % self.n_stripes]

    # -- basic KV -----------------------------------------------------------

    def exists(self, key: str) -> bool:
        s = self._stripe(key)
        with s.lock:
            return key in s.store

    def keys(self) -> List[str]:
        out: List[str] = []
        for s in self._stripes:
            with s.lock:
                out.extend(s.store.keys())
        return out

    def size(self, key: str) -> int:
        s = self._stripe(key)
        with s.lock:
            return len(s.store.get(key, b""))

    def delete(self, key: str) -> None:
        s = self._stripe(key)
        with s.lock:
            s.store.pop(key, None)
            s.meta.pop(key, None)

    def get(self, key: str, *, host: str = "?") -> bytes:
        s = self._stripe(key)
        with s.lock:
            val = bytes(s.store[key])
            s.pulled[host] = s.pulled.get(host, 0) + len(val)
        return val

    def set(self, key: str, value: bytes, *, host: str = "?") -> None:
        s = self._stripe(key)
        with s.lock:
            s.store[key] = bytearray(value)
            s.bump(key)
            s.pushed[host] = s.pushed.get(host, 0) + len(value)

    def append(self, key: str, value: bytes, *, host: str = "?") -> None:
        s = self._stripe(key)
        with s.lock:
            s.store.setdefault(key, bytearray()).extend(value)
            s.bump(key)
            s.pushed[host] = s.pushed.get(host, 0) + len(value)

    # -- chunked access ------------------------------------------------------
    #
    # get_range / set_range are the transfer primitives: LocalTier.pull_chunk
    # and push_dirty move every chunk through them, one stripe lock per key.

    def get_range(self, key: str, offset: int, length: int, *,
                  host: str = "?") -> bytes:
        s = self._stripe(key)
        with s.lock:
            buf = s.store[key]
            if offset < 0 or offset + length > len(buf):
                raise IndexError(
                    f"state range [{offset}, {offset + length}) out of bounds "
                    f"for {key!r} of size {len(buf)}")
            val = bytes(buf[offset:offset + length])
            s.pulled[host] = s.pulled.get(host, 0) + length
        return val

    def set_range(self, key: str, offset: int, value: bytes, *,
                  host: str = "?") -> None:
        s = self._stripe(key)
        with s.lock:
            buf = s.store.setdefault(key, bytearray())
            end = offset + len(value)
            if offset < 0:
                raise IndexError("negative state offset")
            if end > len(buf):
                buf.extend(b"\x00" * (end - len(buf)))
            buf[offset:end] = value
            s.bump(key)
            s.pushed[host] = s.pushed.get(host, 0) + len(value)

    def n_chunks(self, key: str) -> int:
        sz = self.size(key)
        return max(1, -(-sz // self.chunk_size))

    def chunk_bounds(self, key: str, idx: int) -> Tuple[int, int]:
        sz = self.size(key)
        start = idx * self.chunk_size
        return start, min(self.chunk_size, sz - start)

    # -- global locks / metadata ----------------------------------------------

    def lock(self, key: str) -> RWLock:
        s = self._stripe(key)
        with s.lock:
            return s.locks.setdefault(key, RWLock())

    def version(self, key: str) -> int:
        """Write version of ``key`` (0 if never written)."""
        s = self._stripe(key)
        with s.lock:
            m = s.meta.get(key)
            return m.version if m is not None else 0

    # -- metrics --------------------------------------------------------------

    @property
    def bytes_pulled(self) -> Dict[str, int]:
        """Per-host pulled bytes, aggregated across stripes (read-only view)."""
        out: Dict[str, int] = defaultdict(int)
        for s in self._stripes:
            with s.lock:
                for h, n in s.pulled.items():
                    out[h] += n
        return out

    @property
    def bytes_pushed(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for s in self._stripes:
            with s.lock:
                for h, n in s.pushed.items():
                    out[h] += n
        return out

    def total_transfer(self) -> int:
        total = 0
        for s in self._stripes:
            with s.lock:
                total += sum(s.pulled.values()) + sum(s.pushed.values())
        return total

    def reset_metrics(self) -> None:
        for s in self._stripes:
            with s.lock:
                s.pulled.clear()
                s.pushed.clear()
