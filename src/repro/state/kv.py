"""Global state tier: a chunked, thread-safe distributed key-value store.

The authoritative copy of every state value (Faasm §4.2).  Values are byte
arrays (the paper's language-agnostic representation); large values are split
into fixed-size **state chunks** that can be pulled/pushed independently, so a
Faaslet replicates only the subsets it touches (Fig. 4, value C).

Concurrency: the store is **lock-striped** — keys hash onto a fixed array of
stripes, each stripe owning its own mutex, sub-map and transfer counters, so
chunk transfers (``get_range``/``set_range``, the primitives behind
``LocalTier.pull_chunk``/``push_dirty``) on *different* keys never contend.
Per-key metadata (the global read/write lock implementing
``lock_state_global_read/write``, plus a write version) lives next to the
value in its stripe.

Data plane: values are **mutable numpy buffers**, and the zero-copy range
primitives ``readinto``/``write_from`` memcpy directly between global
storage and replica buffers under the stripe lock — no intermediate
``bytes`` materialisation.  ``add_inplace`` applies a HOGWILD delta
(``global += local − base``) arithmetically in the global buffer without
copying the value at all, and ``apply_quantized`` applies the int8
``kernels/state_push`` wire format — the delta arrives as ``(q, scales)``
and only those wire bytes (≈ value/4 for f32) are accounted as moved.  The
tier counts every byte it actually memcpys
(``bytes_copied``/``total_copied``) next to the per-host transfer counters —
the experiments' "network transfer" metric (Fig. 6b) reads the latter, the
copy-accounting benchmark reads the former.
"""
from __future__ import annotations

import threading
import zlib
from collections import defaultdict
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

DEFAULT_CHUNK = 1 << 20          # 1 MiB state chunks
DEFAULT_STRIPES = 64


class RWLock:
    """Writer-preferring readers/writer lock."""

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self):
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self):
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_write(self):
        with self._cond:
            self._writers_waiting += 1
            while self._writer or self._readers:
                self._cond.wait()
            self._writers_waiting -= 1
            self._writer = True

    def release_write(self):
        with self._cond:
            self._writer = False
            self._cond.notify_all()


@dataclass
class KeyMeta:
    """Per-key metadata co-located with the value in its stripe."""

    version: int = 0                 # stripe-monotonic; stamped on every write


class _Value:
    """A mutable value buffer: numpy storage with amortised append growth."""

    __slots__ = ("buf", "length")

    def __init__(self, length: int = 0, capacity: int = 0):
        self.buf = np.zeros(max(length, capacity), np.uint8)
        self.length = length

    def ensure(self, end: int) -> None:
        """Grow logical length to ``end`` (capacity doubles, gap zero-filled)."""
        if end > self.buf.size:
            grown = np.zeros(max(end, 2 * self.buf.size), np.uint8)
            grown[:self.length] = self.buf[:self.length]
            self.buf = grown
        if end > self.length:
            self.buf[self.length:end] = 0       # stale capacity must read as 0
            self.length = end


class _Stripe:
    """One lock stripe: a mutex guarding a sub-map of keys + its counters."""

    __slots__ = ("lock", "store", "meta", "locks", "vc", "pulled", "pushed",
                 "copied")

    def __init__(self):
        self.lock = threading.RLock()
        self.store: Dict[str, _Value] = {}
        self.meta: Dict[str, KeyMeta] = {}
        # RW locks live outside the meta map: a delete must not orphan a lock
        # some thread is holding, and version numbers draw from a monotonic
        # per-stripe counter so delete+recreate never aliases a cached version
        self.locks: Dict[str, RWLock] = {}
        self.vc = 0
        self.pulled: Dict[str, int] = {}     # per-host transfer bytes
        self.pushed: Dict[str, int] = {}
        self.copied = 0                      # bytes actually memcpy'd by the tier

    def bump(self, key: str) -> None:
        self.vc += 1
        self.meta.setdefault(key, KeyMeta()).version = self.vc


def _as_u8(a: np.ndarray) -> np.ndarray:
    """Flat uint8 view of a contiguous array (no copy)."""
    return a.reshape(-1).view(np.uint8)


class GlobalTier:
    """In-memory stand-in for the distributed KVS backing the global tier.

    On a real deployment this is Redis/Anna sharded across hosts; here one
    process hosts the authoritative map, with the same chunk/locking/byte
    semantics, so every state-protocol decision (what is pulled, when, how
    many bytes, how many copies) is real and measurable.
    """

    def __init__(self, chunk_size: int = DEFAULT_CHUNK,
                 n_stripes: int = DEFAULT_STRIPES):
        self.chunk_size = chunk_size
        self.n_stripes = max(1, n_stripes)
        self._stripes = [_Stripe() for _ in range(self.n_stripes)]

    def _stripe(self, key: str) -> _Stripe:
        return self._stripes[zlib.crc32(key.encode()) % self.n_stripes]

    # -- basic KV -----------------------------------------------------------

    def exists(self, key: str) -> bool:
        s = self._stripe(key)
        with s.lock:
            return key in s.store

    def keys(self) -> List[str]:
        out: List[str] = []
        for s in self._stripes:
            with s.lock:
                out.extend(s.store.keys())
        return out

    def size(self, key: str) -> int:
        s = self._stripe(key)
        with s.lock:
            v = s.store.get(key)
            return v.length if v is not None else 0

    def delete(self, key: str) -> None:
        s = self._stripe(key)
        with s.lock:
            s.store.pop(key, None)
            s.meta.pop(key, None)

    def get(self, key: str, *, host: str = "?") -> bytes:
        s = self._stripe(key)
        with s.lock:
            v = s.store[key]
            val = v.buf[:v.length].tobytes()
            s.pulled[host] = s.pulled.get(host, 0) + v.length
            s.copied += v.length
        return val

    def set(self, key: str, value: bytes, *, host: str = "?") -> None:
        s = self._stripe(key)
        n = len(value)
        with s.lock:
            v = s.store.get(key)
            if v is None or v.buf.size < n:
                v = _Value(capacity=n)
                s.store[key] = v
            v.length = n
            if n:
                v.buf[:n] = np.frombuffer(value, np.uint8)
            s.bump(key)
            s.pushed[host] = s.pushed.get(host, 0) + n
            s.copied += n

    def append(self, key: str, value: bytes, *, host: str = "?") -> None:
        """Append ``value`` to the key (amortised O(len(value)): capacity
        doubles, so delta-record logs don't rewrite the whole value)."""
        s = self._stripe(key)
        n = len(value)
        with s.lock:
            v = s.store.setdefault(key, _Value())
            off = v.length
            v.ensure(off + n)
            if n:
                v.buf[off:off + n] = np.frombuffer(value, np.uint8)
            s.bump(key)
            s.pushed[host] = s.pushed.get(host, 0) + n
            s.copied += n

    def rewrite(self, key: str, transform: Callable[[bytes], bytes], *,
                host: str = "?") -> Tuple[bytes, int]:
        """Atomically replace the value with ``transform(current)`` under the
        stripe lock (e.g. compacting a delta-record log).  ``transform`` must
        be pure — it runs with the stripe lock held.  Returns the new value
        and its write version (captured atomically, so callers can cache
        against exactly the state they produced)."""
        s = self._stripe(key)
        with s.lock:
            v = s.store.get(key)
            cur = v.buf[:v.length].tobytes() if v is not None else b""
            new = transform(cur)
            n = len(new)
            if v is None or v.buf.size < n:
                v = _Value(capacity=n)
                s.store[key] = v
            v.length = n
            if n:
                v.buf[:n] = np.frombuffer(new, np.uint8)
            s.bump(key)
            s.copied += len(cur) + n
            return new, s.meta[key].version

    # -- chunked access ------------------------------------------------------
    #
    # get_range / set_range are the bytes-typed transfer primitives; the
    # zero-copy data plane below (readinto / write_from / add_inplace) is
    # what LocalTier.pull/pull_chunk/push/push_dirty/push_delta use.

    def get_range(self, key: str, offset: int, length: int, *,
                  host: str = "?") -> bytes:
        s = self._stripe(key)
        with s.lock:
            v = s.store[key]
            if offset < 0 or offset + length > v.length:
                raise IndexError(
                    f"state range [{offset}, {offset + length}) out of bounds "
                    f"for {key!r} of size {v.length}")
            val = v.buf[offset:offset + length].tobytes()
            s.pulled[host] = s.pulled.get(host, 0) + length
            s.copied += length
        return val

    def set_range(self, key: str, offset: int, value: bytes, *,
                  host: str = "?") -> None:
        s = self._stripe(key)
        n = len(value)
        with s.lock:
            if offset < 0:
                raise IndexError("negative state offset")
            v = s.store.setdefault(key, _Value())
            v.ensure(max(v.length, offset + n))
            if n:
                v.buf[offset:offset + n] = np.frombuffer(value, np.uint8)
            s.bump(key)
            s.pushed[host] = s.pushed.get(host, 0) + n
            s.copied += n

    # -- zero-copy data plane (replica buffer <-> global buffer) --------------

    def readinto(self, key: str, offset: int, dest: np.ndarray, *,
                 host: str = "?", clamp: bool = False) -> int:
        """memcpy ``value[offset : offset+len(dest)]`` straight into ``dest``
        (a replica buffer view) under the stripe lock — one copy, no
        intermediate ``bytes``.  With ``clamp``, a read past the current
        value end copies what exists (a concurrent truncating push may have
        shrunk the value since the caller sized its buffer).  Returns bytes
        moved."""
        dest = _as_u8(dest)
        n = dest.size
        s = self._stripe(key)
        with s.lock:
            v = s.store[key]
            if offset < 0 or (not clamp and offset + n > v.length):
                raise IndexError(
                    f"state range [{offset}, {offset + n}) out of bounds "
                    f"for {key!r} of size {v.length}")
            n = min(n, max(v.length - offset, 0))
            if n:
                dest[:n] = v.buf[offset:offset + n]
            s.pulled[host] = s.pulled.get(host, 0) + n
            s.copied += n
        return n

    def write_from(self, key: str, offset: int, src: np.ndarray, *,
                   host: str = "?", truncate: bool = False) -> int:
        """memcpy ``src`` (a replica buffer view) straight into the global
        buffer at ``offset`` under the stripe lock — one copy.  With
        ``truncate`` the value's length becomes exactly ``offset + len(src)``
        (full-value push semantics).  Returns bytes moved."""
        src = _as_u8(src)
        n = src.size
        s = self._stripe(key)
        with s.lock:
            if offset < 0:
                raise IndexError("negative state offset")
            v = s.store.setdefault(key, _Value())
            v.ensure(max(v.length, offset + n))
            if n:
                v.buf[offset:offset + n] = src
            if truncate:
                v.length = offset + n
            s.bump(key)
            s.pushed[host] = s.pushed.get(host, 0) + n
            s.copied += n
        return n

    def add_inplace(self, key: str, local: np.ndarray,
                    base: Optional[np.ndarray] = None, *,
                    host: str = "?") -> int:
        """HOGWILD delta push computed in place in the global buffer:
        ``global += local`` then ``global -= base`` — no value-sized copy at
        all (``bytes_copied`` does not move).  ``local``/``base`` are typed
        replica views; the overlap with the stored value is updated.
        Returns delta bytes accounted as pushed."""
        dtype = local.dtype
        itemsize = dtype.itemsize
        s = self._stripe(key)
        with s.lock:
            v = s.store[key]
            g = v.buf[:v.length - v.length % itemsize].view(dtype)
            n = min(g.size, local.size)
            if n:
                g[:n] += local[:n]
                if base is not None:
                    g[:n] -= base[:n]
            s.bump(key)
            moved = n * itemsize
            s.pushed[host] = s.pushed.get(host, 0) + moved
        return moved

    def apply_quantized(self, key: str, q: np.ndarray, scales: np.ndarray,
                        numel: int, *, dtype=np.float32,
                        host: str = "?") -> int:
        """Apply an int8-quantised delta push (the ``kernels/state_push``
        wire format) in place in the global buffer.

        ``q`` is the (rows, 128) int8 payload, ``scales`` the per-row f32
        absmax scales, ``numel`` the original element count — the delta
        decodes as ``q * scales`` trimmed to ``numel``.  Accounting counts
        the **wire** bytes (int8 payload + scales), not the value bytes: a
        4 MB f32 push moves ~1 MB + scales across the tier boundary.
        Callers serialise under the key's global write lock, same as the
        exact :meth:`add_inplace` path."""
        q = np.asarray(q)
        scales = np.asarray(scales, np.float32)
        dt = np.dtype(dtype)
        s = self._stripe(key)
        with s.lock:
            v = s.store[key]
            g = v.buf[:v.length - v.length % dt.itemsize].view(dt)
            n = min(g.size, int(numel))
            if n:
                delta = (q.astype(np.float32) * scales).reshape(-1)[:n]
                g[:n] += delta.astype(dt, copy=False)
            s.bump(key)
            wire = q.nbytes + scales.nbytes
            s.pushed[host] = s.pushed.get(host, 0) + wire
            s.copied += wire
        return wire

    def n_chunks(self, key: str) -> int:
        sz = self.size(key)
        return max(1, -(-sz // self.chunk_size))

    def chunk_bounds(self, key: str, idx: int) -> Tuple[int, int]:
        sz = self.size(key)
        start = idx * self.chunk_size
        return start, min(self.chunk_size, sz - start)

    # -- global locks / metadata ----------------------------------------------

    def lock(self, key: str) -> RWLock:
        s = self._stripe(key)
        with s.lock:
            return s.locks.setdefault(key, RWLock())

    def version(self, key: str) -> int:
        """Write version of ``key`` (0 if never written)."""
        s = self._stripe(key)
        with s.lock:
            m = s.meta.get(key)
            return m.version if m is not None else 0

    # -- metrics --------------------------------------------------------------

    @property
    def bytes_pulled(self) -> Dict[str, int]:
        """Per-host pulled bytes, aggregated across stripes (read-only view)."""
        out: Dict[str, int] = defaultdict(int)
        for s in self._stripes:
            with s.lock:
                for h, n in s.pulled.items():
                    out[h] += n
        return out

    @property
    def bytes_pushed(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for s in self._stripes:
            with s.lock:
                for h, n in s.pushed.items():
                    out[h] += n
        return out

    def total_transfer(self) -> int:
        total = 0
        for s in self._stripes:
            with s.lock:
                total += sum(s.pulled.values()) + sum(s.pushed.values())
        return total

    def total_copied(self) -> int:
        """Bytes the tier actually memcpy'd (copy accounting: in-place delta
        pushes and lock-free metadata reads move nothing here)."""
        total = 0
        for s in self._stripes:
            with s.lock:
                total += s.copied
        return total

    def reset_metrics(self) -> None:
        for s in self._stripes:
            with s.lock:
                s.pulled.clear()
                s.pushed.clear()
                s.copied = 0
