"""Global state tier: a chunked, thread-safe distributed key-value store.

The authoritative copy of every state value (Faasm §4.2).  Values are byte
arrays (the paper's language-agnostic representation); large values are split
into fixed-size **state chunks** that can be pulled/pushed independently, so a
Faaslet replicates only the subsets it touches (Fig. 4, value C).

Concurrency: the store is **lock-striped** — keys hash onto a fixed array of
stripes, each stripe owning its own mutex, sub-map and transfer counters, so
chunk transfers (``get_range``/``set_range``, the primitives behind
``LocalTier.pull_chunk``/``push_dirty``) on *different* keys never contend.
Per-key metadata (the global read/write lock implementing
``lock_state_global_read/write``, plus a write version) lives next to the
value in its stripe.

Data plane: values are **mutable numpy buffers**, and the zero-copy range
primitives ``readinto``/``write_from`` memcpy directly between global
storage and replica buffers under the stripe lock — no intermediate
``bytes`` materialisation.  ``add_inplace`` applies a HOGWILD delta
(``global += local − base``) arithmetically in the global buffer without
copying the value at all.

Wire fabric (``repro.state.wire``): every delta that crosses the tier
boundary is a :class:`~repro.state.wire.WireFrame`.  ``apply_wire`` lands a
push frame in the global buffer (int8 frames account only their **wire**
bytes, ≈ value/4 for f32) and records it in the key's **retained delta
window**; ``pull_wire`` serves a warm replica the composition of the
retained frames newer than its base version (re-encoded on the requested
wire by the fused ``kernels/state_push`` codec), falling back to a full
pull when the base predates the window floor; ``broadcast`` fans an applied
frame out to subscribed local tiers so peer replicas converge without a
re-pull.  Any non-delta mutation (``set``/``set_range``/``write_from``/
``append``/``rewrite``) invalidates the window: the floor jumps to the new
version and older bases full-pull.

The tier counts every byte it actually memcpys
(``bytes_copied``/``total_copied``) next to the per-host transfer counters —
the experiments' "network transfer" metric (Fig. 6b) reads the latter, the
copy-accounting benchmark reads the former.
"""
from __future__ import annotations

import threading
import time
import zlib
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import overload as oload
from repro.analysis.annotations import holds_stripe
from repro.analysis.sanitizer import make_mutex, wrap_rwlock
from repro.state import wire as _wire_mod
from repro.state.wire import WireFrame, frame_from_quantized, get_codec
from repro.telemetry import clock as _clock

# repro.analysis.sanitizer installs its hook state here (enable()); None
# compiles every check in this module down to one pointer compare
_SAN = None
# repro.telemetry installs its tracer here (enable()); same discipline —
# disarmed is one pointer compare per wire event, zero ring writes
_TEL = None

DEFAULT_CHUNK = 1 << 20          # 1 MiB state chunks
DEFAULT_STRIPES = 64
DEFAULT_DELTA_WINDOW = 8         # retained wire frames per key (delta pulls)
DEFAULT_DELTA_WINDOW_BYTES = 32 << 20   # per-key byte cap on retained frames
FENCE_CAP = 4096                 # retained sealed fence records (see _Fence)


class RWLock:
    """Writer-preferring readers/writer lock."""

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self):
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self):
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_write(self):
        with self._cond:
            self._writers_waiting += 1
            while self._writer or self._readers:
                self._cond.wait()
            self._writers_waiting -= 1
            self._writer = True

    def release_write(self):
        with self._cond:
            self._writer = False
            self._cond.notify_all()


@dataclass
class KeyMeta:
    """Per-key metadata co-located with the value in its stripe."""

    version: int = 0                 # stripe-monotonic; stamped on every write
    floor: int = 0                   # oldest base version the window serves
    frames: deque = field(default_factory=deque)   # retained WireFrames
    frames_bytes: int = 0
    pullers: set = field(default_factory=set)      # tiers holding warm replicas


class _Value:
    """A mutable value buffer: numpy storage with amortised append growth."""

    __slots__ = ("buf", "length")

    def __init__(self, length: int = 0, capacity: int = 0):
        self.buf = np.zeros(max(length, capacity), np.uint8)
        self.length = length

    def ensure(self, end: int) -> None:
        """Grow logical length to ``end`` (capacity doubles, gap zero-filled)."""
        if end > self.buf.size:
            grown = np.zeros(max(end, 2 * self.buf.size), np.uint8)
            grown[:self.length] = self.buf[:self.length]
            self.buf = grown
        if end > self.length:
            self.buf[self.length:end] = 0       # stale capacity must read as 0
            self.length = end


class _Stripe:
    """One lock stripe: a mutex guarding a sub-map of keys + its counters."""

    __slots__ = ("lock", "store", "meta", "locks", "subs", "vc", "pulled",
                 "pushed", "copied", "bcast")

    def __init__(self):
        self.lock = make_mutex("stripe")
        self.store: Dict[str, _Value] = {}
        self.meta: Dict[str, KeyMeta] = {}
        # RW locks live outside the meta map: a delete must not orphan a lock
        # some thread is holding, and version numbers draw from a monotonic
        # per-stripe counter so delete+recreate never aliases a cached version
        self.locks: Dict[str, RWLock] = {}
        self.subs: Dict[str, Dict[str, Callable]] = {}   # key -> host -> cb
        self.vc = 0
        self.pulled: Dict[str, int] = {}     # per-host transfer bytes
        self.pushed: Dict[str, int] = {}
        self.copied = 0                      # bytes actually memcpy'd by the tier
        self.bcast = 0                       # wire bytes fanned out to peers

    @holds_stripe
    def bump(self, key: str) -> None:
        self.vc += 1
        m = self.meta.setdefault(key, KeyMeta())
        if _SAN is not None:
            _SAN.version_bumped(self, key, m.version, self.vc)
        m.version = self.vc

    @holds_stripe
    def record(self, key: str, frame: WireFrame, window: int,
               window_bytes: int) -> None:
        """Retain an applied frame for delta pulls (stripe lock held).
        Trimming the oldest frame raises the window floor to its version:
        pulls from bases at or past the floor stay serviceable."""
        m = self.meta[key]
        if _SAN is not None:
            _SAN.frame_recorded(self, key, frame,
                                m.frames[-1].version if m.frames else None,
                                m.floor)
        m.frames.append(frame)
        m.frames_bytes += frame.nbytes
        while m.frames and (len(m.frames) > window
                            or m.frames_bytes > window_bytes):
            old = m.frames.popleft()
            m.frames_bytes -= old.nbytes
            m.floor = old.version

    @holds_stripe
    def invalidate(self, key: str) -> None:
        """A non-delta mutation: the retained window can no longer express
        the path from any older base — drop it and jump the floor to the
        current version (stripe lock held)."""
        m = self.meta.get(key)
        if m is None:
            return
        m.frames.clear()
        m.frames_bytes = 0
        m.floor = m.version


def _as_u8(a: np.ndarray) -> np.ndarray:
    """Flat uint8 view of a contiguous array (no copy)."""
    return a.reshape(-1).view(np.uint8)


@dataclass
class _Fence:
    """Attempt-fence record for one logical call (see docs/fault_model.md).

    Delta pushes are additive, so a re-executed attempt (requeue after host
    death, straggler speculation) would double-apply its deltas.  Each
    physical attempt carries a fence token ``(call_id, epoch, seq)``; the
    tier admits a push iff the epoch is not superseded (``dead_epoch``),
    the call is not sealed to a different epoch (first settle wins), and
    the per-key effect sequence is fresh (``seq`` > high-water).  Assumes
    deterministic functions: attempt N's i-th push to a key carries the
    same delta as attempt M's, so dropping duplicates converges."""

    dead_epoch: int = 0              # epochs <= this are superseded (requeue)
    sealed: Optional[int] = None     # post-settle: only this epoch may write
    hw: Dict[str, int] = field(default_factory=dict)   # key -> applied seq


class _BcastChannel:
    """One subscriber host's broadcast delivery channel: a bounded
    coalescing frame queue drained by a dedicated pump thread, so a slow or
    stalled subscriber backpressures onto *its own* channel — never onto
    the pusher's thread (see ``GlobalTier.broadcast``)."""

    __slots__ = ("host", "q", "cv", "busy", "stop", "thread")

    def __init__(self, host_id: str, depth: int):
        self.host = host_id
        self.q = oload.CoalescingQueue(depth=depth)
        self.cv = threading.Condition()
        self.busy = False                # a drain batch is being delivered
        self.stop = False
        self.thread: Optional[threading.Thread] = None


class GlobalTier:
    """In-memory stand-in for the distributed KVS backing the global tier.

    On a real deployment this is Redis/Anna sharded across hosts; here one
    process hosts the authoritative map, with the same chunk/locking/byte
    semantics, so every state-protocol decision (what is pulled, when, how
    many bytes, how many copies) is real and measurable.
    """

    def __init__(self, chunk_size: int = DEFAULT_CHUNK,
                 n_stripes: int = DEFAULT_STRIPES,
                 delta_window: int = DEFAULT_DELTA_WINDOW,
                 delta_window_bytes: int = DEFAULT_DELTA_WINDOW_BYTES):
        self.chunk_size = chunk_size
        self.n_stripes = max(1, n_stripes)
        self.delta_window = max(0, delta_window)
        self.delta_window_bytes = delta_window_bytes
        self._stripes = [_Stripe() for _ in range(self.n_stripes)]
        # attempt fences: logical-call write admission (innermost lock kind;
        # taken under a key write lock on the push path, never the reverse)
        self._fence_mu = make_mutex("fence")
        self._fences: Dict[str, _Fence] = {}
        self._fence_sealed: deque = deque()    # FIFO of sealed ids to prune
        self.fence_rejections = 0              # pushes refused by the fence
        # backpressured broadcast plane: one bounded channel + pump thread
        # per subscriber host, created lazily on first fan-out.  Guarded by
        # its own mutex (never nested inside a stripe lock).
        self._bcast_mu = make_mutex("bcast")
        self._bcast_channels: Dict[str, _BcastChannel] = {}
        self._bcast_closed = False
        self.bcast_depth = oload.DEFAULT_BCAST_DEPTH
        self.bcast_coalesced = 0               # frames collapsed to a newer one
        self.bcast_dropped = 0                 # subscribers dropped on overflow

    def _stripe(self, key: str) -> _Stripe:
        return self._stripes[zlib.crc32(key.encode()) % self.n_stripes]

    # -- attempt fences -----------------------------------------------------

    def fence_admit(self, key: str, fence: Tuple[str, int, int]) -> bool:
        """Admission check for a fenced delta push.

        ``fence`` is ``(call_id, epoch, seq)``: the logical call (a twin
        uses its primary's id), the physical attempt's epoch, and the
        attempt-local 1-based sequence of this push on this key.  Rejected
        pushes (superseded epoch, sealed to another epoch, or duplicate
        ``seq``) must perform no tier effect.  Competing pushes to the same
        key already serialise on the key's global write lock, so the check
        is atomic with the apply that follows it."""
        call_id, epoch, seq = fence
        with self._fence_mu:
            f = self._fences.get(call_id)
            if f is None:
                f = self._fences[call_id] = _Fence()
            admitted = not (epoch <= f.dead_epoch
                            or (f.sealed is not None and epoch != f.sealed)
                            or seq <= f.hw.get(key, 0))
            if admitted:
                f.hw[key] = seq
            else:
                self.fence_rejections += 1
        tel = _TEL
        if tel is not None and not admitted:
            tel.instant("fence.reject", "wire", key=key, fence=call_id,
                        epoch=epoch, seq=seq)
        if _SAN is not None:
            _SAN.fence_write(call_id, epoch, key, seq, admitted)
        return admitted

    def fence_supersede(self, call_id: str, epoch: int) -> None:
        """Every epoch of ``call_id`` up to and including ``epoch`` is dead:
        the runtime requeued or retried past it, so late writes from those
        attempts must be rejected (the host they ran on is gone)."""
        with self._fence_mu:
            f = self._fences.setdefault(call_id, _Fence())
            f.dead_epoch = max(f.dead_epoch, epoch)
        if _SAN is not None:
            _SAN.fence_superseded(call_id, epoch)

    def fence_is_dead(self, call_id: str, epoch: int) -> bool:
        """True when ``epoch`` of ``call_id`` has been superseded: the
        runtime requeued the call past it, so any push this attempt made
        after the supersede was rejected.  An attempt that finds its epoch
        dead must not settle the call — its \"success\" may name state
        effects that never landed."""
        with self._fence_mu:
            f = self._fences.get(call_id)
            return f is not None and epoch <= f.dead_epoch

    def fence_seal(self, call_id: str, epoch: int) -> None:
        """The call settled with ``epoch``'s result: no other attempt may
        write its state again (a racing speculation loser pushes into a
        sealed fence and is dropped).  Sealed records are pruned FIFO past
        ``FENCE_CAP`` — a straggler older than that is long cancelled."""
        with self._fence_mu:
            f = self._fences.setdefault(call_id, _Fence())
            if f.sealed is None:
                f.sealed = epoch
                self._fence_sealed.append(call_id)
                while len(self._fence_sealed) > FENCE_CAP:
                    self._fences.pop(self._fence_sealed.popleft(), None)

    # -- basic KV -----------------------------------------------------------

    def exists(self, key: str) -> bool:
        s = self._stripe(key)
        with s.lock:
            return key in s.store

    def keys(self) -> List[str]:
        out: List[str] = []
        for s in self._stripes:
            with s.lock:
                out.extend(s.store.keys())
        return out

    def size(self, key: str) -> int:
        s = self._stripe(key)
        with s.lock:
            v = s.store.get(key)
            return v.length if v is not None else 0

    def delete(self, key: str) -> None:
        s = self._stripe(key)
        with s.lock:
            s.store.pop(key, None)
            s.meta.pop(key, None)
            s.subs.pop(key, None)

    def get(self, key: str, *, host: str = "?") -> bytes:
        s = self._stripe(key)
        with s.lock:
            if _SAN is not None:
                _SAN.stripe_touch(s.lock, key)
            v = s.store[key]
            val = v.buf[:v.length].tobytes()
            s.pulled[host] = s.pulled.get(host, 0) + v.length
            s.copied += v.length
        return val

    def set(self, key: str, value: bytes, *, host: str = "?") -> None:
        s = self._stripe(key)
        n = len(value)
        with s.lock:
            if _SAN is not None:
                _SAN.stripe_touch(s.lock, key)
                _SAN.gen_bump(self, key)
            v = s.store.get(key)
            if v is None or v.buf.size < n:
                v = _Value(capacity=n)
                s.store[key] = v
            v.length = n
            if n:
                v.buf[:n] = np.frombuffer(value, np.uint8)
            s.bump(key)
            s.invalidate(key)
            s.pushed[host] = s.pushed.get(host, 0) + n
            s.copied += n

    def append(self, key: str, value: bytes, *, host: str = "?") -> None:
        """Append ``value`` to the key (amortised O(len(value)): capacity
        doubles, so delta-record logs don't rewrite the whole value)."""
        s = self._stripe(key)
        n = len(value)
        with s.lock:
            if _SAN is not None:
                _SAN.stripe_touch(s.lock, key)
                _SAN.gen_bump(self, key)
            v = s.store.setdefault(key, _Value())
            off = v.length
            v.ensure(off + n)
            if n:
                v.buf[off:off + n] = np.frombuffer(value, np.uint8)
            s.bump(key)
            s.invalidate(key)
            s.pushed[host] = s.pushed.get(host, 0) + n
            s.copied += n

    def rewrite(self, key: str, transform: Callable[[bytes], bytes], *,
                host: str = "?") -> Tuple[bytes, int]:
        """Atomically replace the value with ``transform(current)`` under the
        stripe lock (e.g. compacting a delta-record log).  ``transform`` must
        be pure — it runs with the stripe lock held.  Returns the new value
        and its write version (captured atomically, so callers can cache
        against exactly the state they produced)."""
        s = self._stripe(key)
        with s.lock:
            if _SAN is not None:
                _SAN.stripe_touch(s.lock, key)
                _SAN.gen_bump(self, key)
            v = s.store.get(key)
            cur = v.buf[:v.length].tobytes() if v is not None else b""
            new = transform(cur)
            n = len(new)
            if v is None or v.buf.size < n:
                v = _Value(capacity=n)
                s.store[key] = v
            v.length = n
            if n:
                v.buf[:n] = np.frombuffer(new, np.uint8)
            s.bump(key)
            s.invalidate(key)
            s.copied += len(cur) + n
            return new, s.meta[key].version

    # -- chunked access ------------------------------------------------------
    #
    # get_range / set_range are the bytes-typed transfer primitives; the
    # zero-copy data plane below (readinto / write_from / add_inplace) is
    # what LocalTier.pull/pull_chunk/push/push_dirty/push_delta use.

    def get_range(self, key: str, offset: int, length: int, *,
                  host: str = "?") -> bytes:
        s = self._stripe(key)
        with s.lock:
            if _SAN is not None:
                _SAN.stripe_touch(s.lock, key)
            v = s.store[key]
            if offset < 0 or offset + length > v.length:
                raise IndexError(
                    f"state range [{offset}, {offset + length}) out of bounds "
                    f"for {key!r} of size {v.length}")
            val = v.buf[offset:offset + length].tobytes()
            s.pulled[host] = s.pulled.get(host, 0) + length
            s.copied += length
        return val

    def set_range(self, key: str, offset: int, value: bytes, *,
                  host: str = "?") -> None:
        s = self._stripe(key)
        n = len(value)
        with s.lock:
            if _SAN is not None:
                _SAN.stripe_touch(s.lock, key)
                _SAN.gen_bump(self, key)
            if offset < 0:
                raise IndexError("negative state offset")
            v = s.store.setdefault(key, _Value())
            v.ensure(max(v.length, offset + n))
            if n:
                v.buf[offset:offset + n] = np.frombuffer(value, np.uint8)
            s.bump(key)
            s.invalidate(key)
            s.pushed[host] = s.pushed.get(host, 0) + n
            s.copied += n

    # -- zero-copy data plane (replica buffer <-> global buffer) --------------

    def readinto(self, key: str, offset: int, dest: np.ndarray, *,
                 host: str = "?", clamp: bool = False,
                 return_version: bool = False):
        """memcpy ``value[offset : offset+len(dest)]`` straight into ``dest``
        (a replica buffer view) under the stripe lock — one copy, no
        intermediate ``bytes``.  With ``clamp``, a read past the current
        value end copies what exists (a concurrent truncating push may have
        shrunk the value since the caller sized its buffer).  Returns bytes
        moved; with ``return_version``, ``(bytes, version)`` — the key's
        write version captured atomically with the content, the base a
        later delta pull refreshes from."""
        dest = _as_u8(dest)
        n = dest.size
        s = self._stripe(key)
        with s.lock:
            if _SAN is not None:
                _SAN.stripe_touch(s.lock, key)
                _tok = _SAN.read_begin(self, key)
            v = s.store[key]
            if offset < 0 or (not clamp and offset + n > v.length):
                raise IndexError(
                    f"state range [{offset}, {offset + n}) out of bounds "
                    f"for {key!r} of size {v.length}")
            n = min(n, max(v.length - offset, 0))
            if n:
                dest[:n] = v.buf[offset:offset + n]
            s.pulled[host] = s.pulled.get(host, 0) + n
            s.copied += n
            if _SAN is not None:
                _SAN.read_end(self, key, _tok)
            if return_version:
                m = s.meta.get(key)
                return n, (m.version if m is not None else 0)
        return n

    def write_from(self, key: str, offset: int, src: np.ndarray, *,
                   host: str = "?", truncate: bool = False) -> int:
        """memcpy ``src`` (a replica buffer view) straight into the global
        buffer at ``offset`` under the stripe lock — one copy.  With
        ``truncate`` the value's length becomes exactly ``offset + len(src)``
        (full-value push semantics).  Returns bytes moved."""
        src = _as_u8(src)
        n = src.size
        s = self._stripe(key)
        with s.lock:
            if _SAN is not None:
                _SAN.stripe_touch(s.lock, key)
                _SAN.gen_bump(self, key)
            if offset < 0:
                raise IndexError("negative state offset")
            v = s.store.setdefault(key, _Value())
            v.ensure(max(v.length, offset + n))
            if n:
                v.buf[offset:offset + n] = src
            if truncate:
                v.length = offset + n
            s.bump(key)
            s.invalidate(key)
            s.pushed[host] = s.pushed.get(host, 0) + n
            s.copied += n
        return n

    def add_inplace(self, key: str, local: np.ndarray,
                    base: Optional[np.ndarray] = None, *,
                    host: str = "?", return_version: bool = False,
                    rebase: bool = False,
                    fence: Optional[Tuple[str, int, int]] = None):
        """HOGWILD delta push computed in place in the global buffer:
        ``global += local`` then ``global -= base`` — no value-sized copy at
        all (``bytes_copied`` does not move).  ``local``/``base`` are typed
        replica views; the overlap with the stored value is updated.
        Returns delta bytes accounted as pushed; with ``return_version``,
        ``(bytes, prev_version, version)`` — the version transition
        captured atomically with the add, so the pusher can keep its
        replica's base version current (its buffer *is* the post-push
        content) instead of degrading every later warm pull to a full
        re-pull."""
        dtype = local.dtype
        itemsize = dtype.itemsize
        if fence is not None and not self.fence_admit(key, fence):
            return None                      # superseded/duplicate attempt
        s = self._stripe(key)
        with s.lock:
            if _SAN is not None:
                _SAN.stripe_touch(s.lock, key)
                _SAN.gen_bump(self, key)
            v = s.store[key]
            g = v.buf[:v.length - v.length % itemsize].view(dtype)
            n = min(g.size, local.size)
            if n:
                if rebase and base is not None:
                    # one coherent read of the live replica: the same delta
                    # lands in the global buffer AND in the pusher's base, so
                    # a concurrent HOGWILD add after the read stays pending
                    # for the next push instead of being silently absorbed
                    # into a re-read base (lost update)
                    delta = local[:n] - base[:n]
                    g[:n] += delta
                    base[:n] += delta
                else:
                    g[:n] += local[:n]
                    if base is not None:
                        g[:n] -= base[:n]
            m = s.meta.get(key)
            prev = m.version if m is not None else 0
            s.bump(key)
            # the delta was never materialised: older bases can't be served
            # through the window across this write
            s.invalidate(key)
            moved = n * itemsize
            s.pushed[host] = s.pushed.get(host, 0) + moved
            if return_version:
                return moved, prev, s.meta[key].version
        return moved

    def apply_wire(self, key: str, frame: WireFrame, *,
                   host: str = "?", origin: Optional[str] = None,
                   fence: Optional[Tuple[str, int, int]] = None):
        """Land a push-direction wire frame in the global buffer.

        The frame decodes to a flat f32 delta; the overlap with the stored
        value is accumulated in place.  Accounting counts the frame's
        **wire** bytes (int8: payload + scales ≈ value/4 for f32; exact:
        the f32 delta itself) — exact frames accumulate arithmetically and,
        like :meth:`add_inplace`, add nothing to the memcpy accounting.

        ``host`` is the transfer-metrics id; ``origin`` the pushing *tier*
        (container tiers share a metrics host but are distinct fabric
        parties — defaults to ``host``).

        The frame is stamped with the version transition it performed
        (``prev_version → version``) and — for f32 values, when some
        *other* party has declared interest (a registered warm puller or a
        subscriber) — retained in the key's delta window so warm replicas
        can refresh via :meth:`pull_wire`.  With no interested party the
        window is invalidated instead of fed: write-only keys retain
        nothing.  Callers serialise under the key's global write lock and
        fan the stamped frame out with :meth:`broadcast` *after* releasing
        it.  A fenced push from a superseded or duplicate attempt performs
        no effect and returns ``None`` (see :meth:`fence_admit`)."""
        dt = np.dtype(frame.dtype)
        if fence is not None and not self.fence_admit(key, fence):
            return None                      # superseded/duplicate attempt
        delta = frame.decode()                   # numpy; outside no locks yet
        wire = frame.nbytes
        s = self._stripe(key)
        with s.lock:
            if _SAN is not None:
                _SAN.stripe_touch(s.lock, key)
                _SAN.gen_bump(self, key)
            v = s.store[key]
            g = v.buf[:v.length - v.length % dt.itemsize].view(dt)
            n = min(g.size, frame.numel)
            if n:
                g[:n] += delta[:n].astype(dt, copy=False)
            m = s.meta.get(key)
            frame.prev_version = m.version if m is not None else 0
            s.bump(key)
            m = s.meta[key]
            frame.version = m.version
            frame.origin = origin if origin is not None else host
            if _SAN is not None:
                _SAN.frame_applied(self, key, frame)
            interested = (any(p != frame.origin for p in m.pullers)
                          or any(h != frame.origin
                                 for h in s.subs.get(key, ())))
            if dt == np.float32 and self.delta_window > 0 and interested:
                s.record(key, frame, self.delta_window,
                         self.delta_window_bytes)
            else:
                s.invalidate(key)
            s.pushed[host] = s.pushed.get(host, 0) + wire
            if frame.wire != "exact":
                s.copied += wire
        return wire

    def apply_quantized(self, key: str, q: np.ndarray, scales: np.ndarray,
                        numel: int, *, dtype=np.float32,
                        host: str = "?") -> int:
        """Apply an int8-quantised delta push (the ``kernels/state_push``
        wire tuple) — compatibility front over :meth:`apply_wire`."""
        frame = frame_from_quantized(q, scales, numel, dtype=dtype)
        return self.apply_wire(key, frame, host=host)

    def pull_wire(self, key: str, base_version: int, *, wire: str = "int8",
                  dtype=np.float32, residual: Optional[np.ndarray] = None,
                  exclude_origin: Optional[str] = None,
                  backend: Optional[str] = None, host: str = "?"):
        """Delta pull: encode ``value(now) − value(at base_version)`` from
        the key's retained window for a warm replica refresh.

        ``exclude_origin`` names the pulling host: frames it pushed itself
        are skipped from the composition — its buffer already contains
        those deltas (in un-quantised form), so replaying them would
        double-apply its own writes when its push raced a peer's.

        Returns ``None`` when the pull is not serviceable (non-f32 value,
        unknown base, base older than the window floor, or a gap) — the
        caller falls back to a full pull.  Otherwise returns
        ``(frame, version, residual)``: ``frame`` is ``None`` when the
        replica is already current (0 bytes moved); ``residual`` is the
        puller's updated error-feedback carry (quantisation debt of this
        encode, owned by the pulling replica and threaded back in on its
        next delta pull so repeated int8 refreshes converge)."""
        dt = np.dtype(dtype)
        if dt != np.float32 or base_version < 0:
            return None
        s = self._stripe(key)
        with s.lock:
            m = s.meta.get(key)
            if m is None:
                return None
            # a delta-pull attempt is interest: keep the window fed even if
            # this one was too stale to serve
            m.pullers.add(exclude_origin if exclude_origin is not None
                          else host)
            cur = m.version
            if base_version == cur:
                return None, cur, residual
            if base_version > cur or base_version < m.floor:
                return None
            parts = [f for f in m.frames if f.version > base_version]
            if not parts:
                return None
            served = [f for f in parts
                      if exclude_origin is None or f.origin != exclude_origin]
            if not served:
                # every newer frame is the puller's own push: it is current
                return None, cur, residual
        # decode/compose and encode OUTSIDE the stripe lock: frames are
        # immutable once stamped, and both the per-frame dequantise and the
        # int8 re-encode (a fused-kernel dispatch) are full-value work that
        # must not serialise unrelated keys in the stripe behind it
        tel = _TEL
        cost = _wire_mod._COST
        timed = tel is not None or cost is not None
        t0 = tel.now() if tel is not None else 0.0
        w0 = _clock.now_ns() if timed else 0
        numel = max(f.numel for f in served)
        delta = np.zeros(numel, np.float32)
        for f in served:
            d = f.decode()
            delta[:d.size] += d
        if residual is not None and residual.size == delta.size:
            delta = delta + residual
        enc0 = _clock.now_ns() if timed else 0
        frame = get_codec(wire).encode_delta(delta, backend=backend)
        enc_ns = _clock.now_ns() - enc0 if timed else 0
        new_residual = None
        if frame.wire != "exact":
            new_residual = delta - frame.decode()
            if _SAN is not None:
                _SAN.check_residual(delta, frame.decode(), new_residual)
        frame.prev_version, frame.version = base_version, cur
        with s.lock:
            s.pulled[host] = s.pulled.get(host, 0) + frame.nbytes
            s.copied += frame.nbytes
        if cost is not None:
            # pull-direction evidence: the re-encode is the same codec work
            # a push pays, so it feeds the same per-(wire, size) curve
            cost.observe(frame.wire, frame.numel * 4, enc_ns,
                         wall_ns=_clock.now_ns() - w0)
        if tel is not None:
            tel.record("wire.pull", "wire", t0, tel.now(), key=key,
                       wire=frame.wire, nbytes=frame.nbytes,
                       numel=frame.numel, encode_ns=enc_ns,
                       prev_version=base_version, version=cur,
                       frames=len(served), puller=host)
        return frame, cur, new_residual

    def register_puller(self, key: str, origin: str) -> None:
        """Declare ``origin`` (a tier id) as holding a warm full replica of
        ``key``: from now on applied f32 frames are retained in the delta
        window so its refreshes can ride the wire.  Sticky for the key's
        lifetime (cluster-bounded set); the first refresh after interest is
        declared may still full-pull once while the window warms."""
        s = self._stripe(key)
        with s.lock:
            s.meta.setdefault(key, KeyMeta()).pullers.add(origin)

    def deregister_puller(self, origin: str,
                          key: Optional[str] = None) -> None:
        """Revoke ``origin``'s warm-puller interest for ``key`` (all keys
        when ``None`` — replica eviction/host failure), so write-only keys
        stop materialising and retaining frames once every consumer left."""
        stripes = [self._stripe(key)] if key is not None else self._stripes
        for s in stripes:
            with s.lock:
                metas = ([s.meta[key]] if key is not None and key in s.meta
                         else ([] if key is not None else s.meta.values()))
                for m in metas:
                    m.pullers.discard(origin)

    def wire_interest(self, key: str, exclude: Optional[str] = None) -> bool:
        """True when some party other than ``exclude`` consumes this key's
        wire frames (a registered warm puller or a broadcast subscriber) —
        the signal `LocalTier.push_delta` uses to decide whether an exact
        f32 push is worth materialising as a frame at all."""
        s = self._stripe(key)
        with s.lock:
            m = s.meta.get(key)
            if m is not None and any(p != exclude for p in m.pullers):
                return True
            return any(h != exclude for h in s.subs.get(key, ()))

    # -- peer broadcast (subscribed replicas) ---------------------------------

    def subscribe(self, key: str, host_id: str,
                  callback: Callable[[str, WireFrame], None]) -> None:
        """Register ``callback(key, frame)`` to receive every wire frame
        applied to ``key`` (push fan-out).  One subscription per host id;
        re-subscribing replaces the callback."""
        s = self._stripe(key)
        with s.lock:
            s.subs.setdefault(key, {})[host_id] = callback

    def unsubscribe(self, host_id: str, key: Optional[str] = None) -> None:
        """Drop ``host_id``'s subscription for ``key`` (all keys when
        ``None`` — host eviction/failure)."""
        stripes = [self._stripe(key)] if key is not None else self._stripes
        for s in stripes:
            with s.lock:
                if key is not None:
                    subs = [s.subs[key]] if key in s.subs else []
                else:
                    subs = list(s.subs.values())
                for d in subs:
                    d.pop(host_id, None)

    def broadcast(self, key: str, frame: WireFrame, *,
                  exclude: Optional[str] = None) -> int:
        """Fan an applied (version-stamped) wire frame out to every
        subscriber of ``key`` except ``exclude`` (the pusher, whose replica
        already contains the delta).  Returns subscribers enqueued to.

        Delivery is **asynchronous and backpressured**: the pusher only
        enqueues onto each subscriber's bounded coalescing channel and
        returns — a stalled subscriber can never stall the pusher.  When a
        channel already holds a frame for this key it is collapsed to the
        newest (the skipped predecessor is a version gap the subscriber's
        ``prev_version`` check tolerates; the next delta pull repairs it).
        When the channel is full of *distinct* keys, the subscriber is
        dropped back to pull-repair entirely.  A callback that raises on
        the pump thread (subscriber churn — e.g. its host died) is culled
        the same way the old synchronous fan-out culled it.

        Must be called with **no tier locks held** (the enqueue takes the
        stripe lock and the channel lock in sequence, never nested under a
        caller's lock).  Use :meth:`flush_broadcasts` where a test or
        benchmark needs delivery to have happened."""
        s = self._stripe(key)
        with s.lock:
            targets = [(h, cb) for h, cb in s.subs.get(key, {}).items()
                       if h != exclude]
        enqueued = 0
        for h, cb in targets:
            ch = self._bcast_channel(h)
            if ch is None:                       # tier closed: drop quietly
                break
            outcome = ch.q.put(key, (frame, cb))
            if outcome == "overflow":
                # bounded backlog exceeded: this subscriber is too far
                # behind to follow the fan-out — drop it to pull-repair
                with self._bcast_mu:
                    self.bcast_dropped += 1
                with s.lock:
                    d = s.subs.get(key)
                    if d is not None and d.get(h) is cb:
                        d.pop(h, None)
                continue
            if outcome == "coalesced":
                with self._bcast_mu:
                    self.bcast_coalesced += 1
            enqueued += 1
            with ch.cv:
                ch.cv.notify()
        return enqueued

    def _bcast_channel(self, host_id: str) -> Optional[_BcastChannel]:
        with self._bcast_mu:
            if self._bcast_closed:
                return None
            ch = self._bcast_channels.get(host_id)
            if ch is None:
                ch = _BcastChannel(host_id, self.bcast_depth)
                ch.thread = threading.Thread(
                    target=self._bcast_pump, args=(ch,),
                    name=f"bcast-pump-{host_id}", daemon=True)
                self._bcast_channels[host_id] = ch
                ch.thread.start()
            return ch

    def _bcast_pump(self, ch: _BcastChannel) -> None:
        """Drain loop for one subscriber channel (its own daemon thread).
        Delivers outside all tier locks; accounts ``s.bcast`` under the
        stripe lock after each successful delivery."""
        while True:
            with ch.cv:
                while not ch.stop and len(ch.q) == 0:
                    ch.cv.wait()
                if ch.stop:
                    return
                ch.busy = True
            for key, (frame, cb) in ch.q.drain():
                try:
                    cb(key, frame)
                except Exception:
                    s = self._stripe(key)
                    with s.lock:
                        d = s.subs.get(key)
                        if d is not None and d.get(ch.host) is cb:
                            d.pop(ch.host, None)
                else:
                    s = self._stripe(key)
                    with s.lock:
                        s.bcast += frame.nbytes
            with ch.cv:
                ch.busy = False
                ch.cv.notify_all()               # wake flush waiters

    def flush_broadcasts(self, timeout: float = 5.0) -> bool:
        """Block until every enqueued broadcast frame has been delivered
        (or culled), or ``timeout`` elapses.  Returns True on quiescence.
        Delivery is asynchronous; call this wherever a test or benchmark
        asserts on subscriber state right after a push."""
        end = time.monotonic() + timeout
        with self._bcast_mu:
            channels = list(self._bcast_channels.values())
        for ch in channels:
            with ch.cv:
                while (len(ch.q) or ch.busy) and not ch.stop:
                    left = end - time.monotonic()
                    if left <= 0.0:
                        return False
                    ch.cv.wait(min(left, 0.05))
        return True

    def close(self) -> None:
        """Stop the broadcast pump threads (idempotent).  Frames still
        queued are dropped — subscribers repair through delta pulls."""
        with self._bcast_mu:
            self._bcast_closed = True
            channels = list(self._bcast_channels.values())
            self._bcast_channels.clear()
        for ch in channels:
            with ch.cv:
                ch.stop = True
                ch.cv.notify_all()
        for ch in channels:
            if ch.thread is not None:
                ch.thread.join(timeout=1.0)

    def n_chunks(self, key: str) -> int:
        sz = self.size(key)
        return max(1, -(-sz // self.chunk_size))

    def chunk_bounds(self, key: str, idx: int) -> Tuple[int, int]:
        sz = self.size(key)
        start = idx * self.chunk_size
        return start, min(self.chunk_size, sz - start)

    # -- global locks / metadata ----------------------------------------------

    def lock(self, key: str) -> RWLock:
        s = self._stripe(key)
        with s.lock:
            lk = s.locks.get(key)
            if lk is None:
                lk = s.locks[key] = wrap_rwlock(RWLock(), "key", key)
            return lk

    def version(self, key: str) -> int:
        """Write version of ``key`` (0 if never written)."""
        s = self._stripe(key)
        with s.lock:
            m = s.meta.get(key)
            return m.version if m is not None else 0

    # -- metrics --------------------------------------------------------------

    @property
    def bytes_pulled(self) -> Dict[str, int]:
        """Per-host pulled bytes, aggregated across stripes (read-only view)."""
        out: Dict[str, int] = defaultdict(int)
        for s in self._stripes:
            with s.lock:
                for h, n in s.pulled.items():
                    out[h] += n
        return out

    @property
    def bytes_pushed(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for s in self._stripes:
            with s.lock:
                for h, n in s.pushed.items():
                    out[h] += n
        return out

    def total_transfer(self) -> int:
        total = 0
        for s in self._stripes:
            with s.lock:
                total += sum(s.pulled.values()) + sum(s.pushed.values())
        return total

    def total_copied(self) -> int:
        """Bytes the tier actually memcpy'd (copy accounting: in-place delta
        pushes and lock-free metadata reads move nothing here)."""
        total = 0
        for s in self._stripes:
            with s.lock:
                total += s.copied
        return total

    def total_broadcast(self) -> int:
        """Wire bytes fanned out to peer subscribers (push-side paid; peer
        replicas converge without adding to ``bytes_pulled``)."""
        total = 0
        for s in self._stripes:
            with s.lock:
                total += s.bcast
        return total

    def reset_metrics(self) -> None:
        for s in self._stripes:
            with s.lock:
                s.pulled.clear()
                s.pushed.clear()
                s.copied = 0
                s.bcast = 0
