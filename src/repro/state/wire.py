"""Symmetric wire fabric for two-tier state movement (Faasm §4.2).

Every byte that crosses the tier boundary — delta **pushes** (replica →
global), delta **pulls** (global → warm replica refresh) and **peer
broadcast** (global → every subscribed replica) — travels as one
:class:`WireFrame`, encoded and decoded by a :class:`WireCodec`.  The codec
is direction-agnostic: the int8 encode is the fused ``kernels/state_push``
quantise kernel whichever side runs it, and the decode/apply is the same
``q·scale`` accumulate whether it lands in the global buffer (push), a host
replica (pull/broadcast) or a JAX device replica (``ops.apply_pull``).

Wire tuple layout (the protocol, see ROADMAP "Wire protocol"):

  ``(wire, numel, payload, scales, prev_version → version)``

  * ``wire="exact"`` — ``payload`` is the flat f32 delta itself, ``scales``
    is ``None``; wire bytes = ``4·numel``.
  * ``wire="int8"``  — ``payload`` is the ``(rows, 128)`` int8 quantised
    delta, ``scales`` the per-row f32 absmax scales; wire bytes ≈ ``numel``.
  * ``prev_version``/``version`` stamp the key's global write version the
    frame moved between — a receiver applies a frame only when its replica
    sits exactly at ``prev_version`` (anything else is repaired by the next
    delta pull, which re-bases on the receiver's actual version).

Error-feedback **residual ownership**: quantisation debt always lives with
the party whose value is behind by it.  A push residual belongs to the
pushing replica (host- or device-side, as before); a pull residual belongs
to the pulling replica and is threaded through
:meth:`GlobalTier.pull_wire`, so repeated int8 refreshes converge instead of
random-walking.  Broadcast frames carry no residual: the broadcast payload
is byte-identical to the delta the global tier itself applied, so applying
it is exact replication.

:class:`WirePolicy` replaces the caller-chosen ``wire=`` knob (kept as an
override): per key, it picks int8 vs exact from the observed delta
magnitude/density and the error-feedback residual norm, with flip-flop
damping (a switch needs ``damping`` consecutive contrary observations).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro import faults

WIRES = ("exact", "int8")

# repro.analysis.sanitizer installs its hook state here (enable()); None
# compiles every check in this module down to one pointer compare
_SAN = None

# Values smaller than this stay on the exact wire even when int8 is
# requested: the per-row scales + dispatch overhead eat the 4x payload
# saving on tiny values.  (Historic home: repro.state.local, re-exported
# there for compatibility.)
INT8_WIRE_MIN_BYTES = 4096


@dataclass
class WireFrame:
    """One unit of tier traffic: a flat f32 delta in encoded form."""

    wire: str                           # codec name, one of WIRES
    numel: int                          # flat f32 elements the delta covers
    payload: np.ndarray                 # exact: f32[numel]; int8: (R,128) i8
    scales: Optional[np.ndarray] = None  # int8: (R,1) f32 absmax scales
    dtype: np.dtype = np.dtype(np.float32)  # value dtype the delta applies to
    prev_version: int = -1              # key version the frame applies on top of
    version: int = -1                   # key version the frame produces
    origin: Optional[str] = None        # pushing host (stamped by apply_wire):
    # a replica pulling through the window must skip its own frames — its
    # buffer already holds those deltas in un-quantised form

    @property
    def nbytes(self) -> int:
        """Bytes this frame moves across a tier boundary."""
        n = int(self.payload.nbytes)
        if self.scales is not None:
            n += int(self.scales.nbytes)
        return n

    def decode(self) -> np.ndarray:
        """The flat f32 delta of length ``numel`` (pure numpy — safe to call
        under a stripe lock; kernel-side decode is ``ops.apply_pull``)."""
        if self.wire == "exact":
            return self.payload.reshape(-1)[:self.numel]
        return (self.payload.astype(np.float32)
                * self.scales).reshape(-1)[:self.numel]


class ExactCodec:
    """Identity wire: the frame payload is the f32 delta itself.

    ``encode`` still flushes any error-feedback residual handed to it (the
    exact wire pays quantisation debt in full), so a replica switching wires
    mid-stream never strands debt."""

    name = "exact"

    def encode(self, eff, base, *,
               backend: Optional[str] = None) -> Tuple[WireFrame, Any]:
        """Encode ``eff − base`` as an exact frame.  ``eff``/``base`` are
        flat f32 (numpy or jax; jax inputs are synced).  Returns
        ``(frame, residual)`` with residual ``None`` — the exact wire drops
        nothing."""
        delta = np.asarray(eff, np.float32) - np.asarray(base, np.float32)
        delta = np.ascontiguousarray(delta.reshape(-1))
        return WireFrame(wire=self.name, numel=delta.size,
                         payload=delta), None

    def encode_delta(self, delta: np.ndarray, *,
                     backend: Optional[str] = None) -> WireFrame:
        """Encode an already-computed flat f32 delta (pull direction)."""
        delta = np.ascontiguousarray(np.asarray(delta, np.float32).reshape(-1))
        return WireFrame(wire=self.name, numel=delta.size, payload=delta)


class Int8Codec:
    """Quantised wire: the fused ``kernels/state_push`` int8 codec.

    The encode runs the quantise kernel (device-native when handed device
    arrays) and returns the error-feedback residual — what quantisation
    dropped, to be carried by the owning replica into its next encode."""

    name = "int8"

    def encode(self, eff, base, *,
               backend: Optional[str] = None) -> Tuple[WireFrame, Any]:
        from repro.kernels.state_push import ops

        faults.point("codec-error")
        q, s, n = ops.quantize_delta(eff, base, backend=backend)
        deq = ops.dequantize(q, s, n)
        residual = (eff - base).reshape(-1)[:n] - deq
        if _SAN is not None:
            true_delta = (np.asarray(eff, np.float32).reshape(-1)[:int(n)]
                          - np.asarray(base, np.float32).reshape(-1)[:int(n)])
            _SAN.check_residual(true_delta, deq, residual)
        # np.asarray blocks on the dispatched kernels: nothing in flight
        # still reads the inputs once the frame is materialised
        return WireFrame(wire=self.name, numel=int(n), payload=np.asarray(q),
                         scales=np.asarray(s, np.float32)), residual

    def encode_delta(self, delta: np.ndarray, *,
                     backend: Optional[str] = None) -> WireFrame:
        """Encode an already-computed flat f32 delta (pull direction) —
        same fused quantise kernel, zero base."""
        from repro.kernels.state_push import ops

        delta = np.asarray(delta, np.float32).reshape(-1)
        q, s, n = ops.encode_pull(delta, np.zeros_like(delta),
                                  backend=backend)
        return WireFrame(wire=self.name, numel=int(n), payload=np.asarray(q),
                         scales=np.asarray(s, np.float32))


def frame_from_quantized(q, scales, numel: int, *,
                         dtype=np.float32) -> WireFrame:
    """Wrap a raw ``kernels/state_push`` wire tuple ``(q, scales, numel)``
    as an int8 frame — the codec-layer constructor for compatibility
    fronts (e.g. ``GlobalTier.apply_quantized``) that receive the tuple
    instead of encoding it themselves.  Keeps ``WireFrame`` construction
    inside this module (the ``wire-construct`` lint rule), so frames can't
    skip version stamping or residual ownership."""
    return WireFrame(wire="int8", numel=int(numel), payload=np.asarray(q),
                     scales=np.asarray(scales, np.float32),
                     dtype=np.dtype(dtype))


_CODECS: Dict[str, Any] = {"exact": ExactCodec(), "int8": Int8Codec()}


def get_codec(wire: str):
    try:
        return _CODECS[wire]
    except KeyError:
        raise ValueError(f"wire {wire!r} not in {WIRES}") from None


class WirePolicy:
    """Per-key adaptive wire selection with flip-flop damping.

    ``select`` answers with the current choice (structural fallbacks first:
    non-float dtypes and sub-threshold values are always exact).
    ``observe`` feeds back what the last encode saw:

      * ``residual_ratio`` — mean |residual| over mean |carried delta|.
        Near zero for well-conditioned deltas; grows past ``residual_cap``
        when per-row outliers make the absmax scale coarse (quantisation is
        dropping real signal) → prefer exact.  ``None`` means the push rode
        the exact wire and produced **no quantisation evidence** — such
        observations never vote for int8 (that would guarantee a permanent
        exact↔int8 thrash on keys int8 genuinely mishandles); instead they
        count toward a periodic **re-probe**: after ``probe_after`` dense
        exact pushes, ``select`` routes a single push back onto int8 so its
        residual can re-qualify (or re-disqualify) the cheap wire.
      * ``density`` — nonzero fraction of the encoded delta.  Below
        ``min_density`` the delta is a handful of spot writes; per-row
        scales carry almost no information → prefer exact.

    A switch requires ``damping`` consecutive observations preferring the
    other wire; any confirming observation resets the streak, so an
    alternating workload doesn't thrash the wire (flip-flop damping)."""

    def __init__(self, *, min_bytes: int = INT8_WIRE_MIN_BYTES,
                 residual_cap: float = 0.25, min_density: float = 1.0 / 256,
                 damping: int = 3, probe_after: int = 8):
        self.min_bytes = min_bytes
        self.residual_cap = residual_cap
        self.min_density = min_density
        self.damping = max(1, damping)
        self.probe_after = max(1, probe_after)
        self._wire = "int8"
        self._streak = 0
        self._exact_obs = 0              # dense exact pushes since last probe
        self.flips = 0                   # damped wire switches (telemetry)

    @property
    def wire(self) -> str:
        """The adaptive choice for values past the structural fallbacks."""
        return self._wire

    def select(self, nbytes: int, dtype, *, probe: bool = True) -> str:
        """The wire to use now.  ``probe=False`` (pull-side selection) reads
        the current choice without consuming the int8 re-probe — a pull's
        encode produces no ``observe`` feedback, so spending the probe on
        it would starve the push wire's re-qualification."""
        if np.dtype(dtype).kind != "f" or nbytes < self.min_bytes:
            return "exact"
        if (probe and self._wire == "exact"
                and self._exact_obs >= self.probe_after):
            self._exact_obs = 0
            return "int8"                # one probe push; observe() decides
        return self._wire

    def observe(self, *, delta_absmax: float, density: float,
                residual_ratio: Optional[float] = None) -> None:
        if delta_absmax == 0.0:
            return                       # a no-op push teaches nothing
        if residual_ratio is None:
            # exact-wire push: quantisation quality unknown.  Sparse deltas
            # still vote exact; dense ones only advance the re-probe clock.
            if density < self.min_density:
                self._vote("exact")
            elif self._wire == "exact":
                self._exact_obs += 1
            return
        prefer_exact = (residual_ratio > self.residual_cap
                        or density < self.min_density)
        self._vote("exact" if prefer_exact else "int8")

    def _vote(self, want: str) -> None:
        if want == self._wire:
            self._streak = 0
            return
        self._streak += 1
        if self._streak >= self.damping:
            self._wire = want
            self._streak = 0
            self._exact_obs = 0
            self.flips += 1
