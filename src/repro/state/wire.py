"""Symmetric wire fabric for two-tier state movement (Faasm §4.2).

Every byte that crosses the tier boundary — delta **pushes** (replica →
global), delta **pulls** (global → warm replica refresh) and **peer
broadcast** (global → every subscribed replica) — travels as one
:class:`WireFrame`, encoded and decoded by a :class:`WireCodec`.  The codec
is direction-agnostic: the int8 encode is the fused ``kernels/state_push``
quantise kernel whichever side runs it, and the decode/apply is the same
``q·scale`` accumulate whether it lands in the global buffer (push), a host
replica (pull/broadcast) or a JAX device replica (``ops.apply_pull``).

Wire tuple layout (the protocol, see ROADMAP "Wire protocol"):

  ``(wire, numel, payload, scales, prev_version → version)``

  * ``wire="exact"`` — ``payload`` is the flat f32 delta itself, ``scales``
    is ``None``; wire bytes = ``4·numel``.
  * ``wire="int8"``  — ``payload`` is the ``(rows, 128)`` int8 quantised
    delta, ``scales`` the per-row f32 absmax scales; wire bytes ≈ ``numel``.
  * ``wire="int4"``  — codes in ``[-7, 7]``, two per byte (lane 2k low
    nibble, lane 2k+1 high): ``payload`` is ``(rows, 64)`` uint8; wire
    bytes ≈ ``numel/2``.  Opt-in (``LocalTier.wire_tiers``).
  * ``wire="fp8"``   — ``payload`` is ``(rows, 128)`` float8_e4m3fn codes
    scaled to ±448; wire bytes ≈ ``numel``, but the format keeps ~2 decimal
    digits of per-element precision where int8 keeps a fixed absolute step.
    Opt-in; gated on ``ml_dtypes`` being importable.
  * ``prev_version``/``version`` stamp the key's global write version the
    frame moved between — a receiver applies a frame only when its replica
    sits exactly at ``prev_version`` (anything else is repaired by the next
    delta pull, which re-bases on the receiver's actual version).

Error-feedback **residual ownership**: quantisation debt always lives with
the party whose value is behind by it.  A push residual belongs to the
pushing replica (host- or device-side, as before); a pull residual belongs
to the pulling replica and is threaded through
:meth:`GlobalTier.pull_wire`, so repeated int8 refreshes converge instead of
random-walking.  Broadcast frames carry no residual: the broadcast payload
is byte-identical to the delta the global tier itself applied, so applying
it is exact replication.

:class:`WirePolicy` replaces the caller-chosen ``wire=`` knob (kept as an
override): per key, it picks int8 vs exact from the observed delta
magnitude/density and the error-feedback residual norm, with flip-flop
damping (a switch needs ``damping`` consecutive contrary observations).
When the :class:`WireCostModel` is armed (``enable_cost_model``), selection
upgrades from the magnitude heuristic to **measured wall-clock**: the model
learns per-(wire, size-bucket) encode and delivery cost online from the
``wire.push``/``wire.pull`` spans' ``encode_ns`` tags (seeded from
``BENCH_codec.json``), and ``select`` answers with the wire whose predicted
end-to-end push is cheapest among the residual-qualified candidates.
Disarmed — the default — every cost hook is one pointer compare
(``_COST is None``), same discipline as the sanitizer and tracer hooks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterable, Optional, Tuple

import numpy as np

from repro import faults
# numpy-only (jax-free) host codec helpers: nibble pack/unpack + row decode
from repro.kernels.state_push import hostcodec

WIRES = ("exact", "int8", "int4", "fp8")

# quantised tiers narrower than int8; opt-in via LocalTier.wire_tiers or an
# explicit wire= override, never chosen by a default WirePolicy
NARROW_TIERS = ("int4", "fp8")

# repro.analysis.sanitizer installs its hook state here (enable()); None
# compiles every check in this module down to one pointer compare
_SAN = None

# Values smaller than this stay on the exact wire even when int8 is
# requested: the per-row scales + dispatch overhead eat the 4x payload
# saving on tiny values.  (Historic home: repro.state.local, re-exported
# there for compatibility.)
INT8_WIRE_MIN_BYTES = 4096


@dataclass
class WireFrame:
    """One unit of tier traffic: a flat f32 delta in encoded form."""

    wire: str                           # codec name, one of WIRES
    numel: int                          # flat f32 elements the delta covers
    payload: np.ndarray                 # exact: f32[numel]; int8: (R,128) i8
    scales: Optional[np.ndarray] = None  # int8: (R,1) f32 absmax scales
    dtype: np.dtype = np.dtype(np.float32)  # value dtype the delta applies to
    prev_version: int = -1              # key version the frame applies on top of
    version: int = -1                   # key version the frame produces
    origin: Optional[str] = None        # pushing host (stamped by apply_wire):
    # a replica pulling through the window must skip its own frames — its
    # buffer already holds those deltas in un-quantised form

    @property
    def nbytes(self) -> int:
        """Bytes this frame moves across a tier boundary."""
        n = int(self.payload.nbytes)
        if self.scales is not None:
            n += int(self.scales.nbytes)
        return n

    def decode(self) -> np.ndarray:
        """The flat f32 delta of length ``numel`` (pure numpy — safe to call
        under a stripe lock; kernel-side decode is ``ops.apply_pull``)."""
        if self.wire == "exact":
            return self.payload.reshape(-1)[:self.numel]
        payload = self.payload
        if self.wire == "int4":
            payload = hostcodec.unpack_int4(payload)
        return (payload.astype(np.float32)
                * self.scales).reshape(-1)[:self.numel]

    def codes(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """The kernel-applyable ``(q, scales)`` row pair for quantised frames
        (int4 payloads are nibble-unpacked to int8), ``None`` for exact —
        the device fast path (``ops.apply_pull``) consumes this so a
        ``DeviceReplica`` value never round-trips through a host decode."""
        if self.wire == "exact":
            return None
        payload = self.payload
        if self.wire == "int4":
            payload = hostcodec.unpack_int4(payload)
        return payload, self.scales


class ExactCodec:
    """Identity wire: the frame payload is the f32 delta itself.

    ``encode`` still flushes any error-feedback residual handed to it (the
    exact wire pays quantisation debt in full), so a replica switching wires
    mid-stream never strands debt."""

    name = "exact"

    def encode(self, eff, base, *,
               backend: Optional[str] = None) -> Tuple[WireFrame, Any]:
        """Encode ``eff − base`` as an exact frame.  ``eff``/``base`` are
        flat f32 (numpy or jax; jax inputs are synced).  Returns
        ``(frame, residual)`` with residual ``None`` — the exact wire drops
        nothing."""
        if hostcodec.usable(eff, base):
            # chunked host path: each completed chunk of the payload is
            # final wire bytes while later chunks are still encoding
            delta = hostcodec.encode_exact(eff, base)
        else:
            delta = np.asarray(eff, np.float32) - np.asarray(base, np.float32)
            delta = np.ascontiguousarray(delta.reshape(-1))
        return WireFrame(wire=self.name, numel=delta.size,
                         payload=delta), None

    def encode_delta(self, delta: np.ndarray, *,
                     backend: Optional[str] = None) -> WireFrame:
        """Encode an already-computed flat f32 delta (pull direction)."""
        delta = np.ascontiguousarray(np.asarray(delta, np.float32).reshape(-1))
        return WireFrame(wire=self.name, numel=delta.size, payload=delta)


class QuantCodec:
    """Quantised wire: the fused ``kernels/state_push`` codec family.

    The encode runs the fused quantise path — host-native numpy for
    host-resident buffers, one cached jitted executable with chunk-pipelined
    copy-out for device arrays — and returns the error-feedback residual:
    what quantisation dropped, to be carried by the owning replica into its
    next encode.  Subclasses fix the tier: int8 (codes ±127), int4 (codes
    ±7, nibble-packed two per byte) and fp8 (float8_e4m3fn codes ±448)."""

    name = "int8"
    qmax = 127
    packed = False       # int4: payload is nibble-packed (R, 64) uint8

    def _encode_rows(self, eff, base, backend, with_residual):
        from repro.kernels.state_push import ops

        return ops.encode_quant(eff, base, qmax=self.qmax, backend=backend,
                                with_residual=with_residual)

    def encode(self, eff, base, *,
               backend: Optional[str] = None) -> Tuple[WireFrame, Any]:
        from repro.kernels.state_push import ops

        faults.point("codec-error")
        q, s, n, residual = self._encode_rows(eff, base, backend, True)
        if _SAN is not None:
            # recompute the dequantised carry from the codes themselves so
            # the conservation check is independent of the fused residual
            deq = ops.dequantize(np.asarray(q), np.asarray(s), int(n))
            true_delta = (np.asarray(eff, np.float32).reshape(-1)[:int(n)]
                          - np.asarray(base, np.float32).reshape(-1)[:int(n)])
            _SAN.check_residual(true_delta, np.asarray(deq), residual)
        payload = np.asarray(q)
        if self.packed:
            payload = hostcodec.pack_int4(payload)
        return WireFrame(wire=self.name, numel=int(n), payload=payload,
                         scales=np.asarray(s, np.float32)), residual

    def encode_delta(self, delta: np.ndarray, *,
                     backend: Optional[str] = None) -> WireFrame:
        """Encode an already-computed flat f32 delta (pull direction) —
        same fused quantise path, zero base (no zeros materialised)."""
        delta = np.asarray(delta, np.float32).reshape(-1)
        q, s, n, _ = self._encode_rows(delta, None, backend, False)
        payload = np.asarray(q)
        if self.packed:
            payload = hostcodec.pack_int4(payload)
        return WireFrame(wire=self.name, numel=int(n), payload=payload,
                         scales=np.asarray(s, np.float32))


class Int8Codec(QuantCodec):
    name = "int8"
    qmax = 127


class Int4Codec(QuantCodec):
    """Narrow tier: codes in [-7, 7], two per byte — ≈ numel/2 wire bytes.

    Coarse (absmax/7 step) — viable only under the error-feedback residual
    discipline, and only where ``WirePolicy.residual_cap`` admits it."""

    name = "int4"
    qmax = 7
    packed = True


class Fp8Codec(QuantCodec):
    """Narrow tier: float8_e4m3fn codes scaled to ±448 — ≈ numel wire bytes
    with relative (not absolute) per-element precision.  Gated on
    ``ml_dtypes`` importability (``hostcodec.fp8_available()``)."""

    name = "fp8"
    qmax = 0             # unused; fp8 scales to ±FP8_MAX

    def _encode_rows(self, eff, base, backend, with_residual):
        from repro.kernels.state_push import ops

        return ops.encode_fp8(eff, base, backend=backend,
                              with_residual=with_residual)


def frame_from_quantized(q, scales, numel: int, *,
                         dtype=np.float32) -> WireFrame:
    """Wrap a raw ``kernels/state_push`` wire tuple ``(q, scales, numel)``
    as an int8 frame — the codec-layer constructor for compatibility
    fronts (e.g. ``GlobalTier.apply_quantized``) that receive the tuple
    instead of encoding it themselves.  Keeps ``WireFrame`` construction
    inside this module (the ``wire-construct`` lint rule), so frames can't
    skip version stamping or residual ownership."""
    return WireFrame(wire="int8", numel=int(numel), payload=np.asarray(q),
                     scales=np.asarray(scales, np.float32),
                     dtype=np.dtype(dtype))


_CODECS: Dict[str, Any] = {"exact": ExactCodec(), "int8": Int8Codec(),
                           "int4": Int4Codec()}
if hostcodec.fp8_available():
    _CODECS["fp8"] = Fp8Codec()


def get_codec(wire: str):
    try:
        return _CODECS[wire]
    except KeyError:
        if wire == "fp8":
            raise ValueError(
                "wire 'fp8' requires ml_dtypes (float8_e4m3fn)") from None
        raise ValueError(f"wire {wire!r} not in {WIRES}") from None


def available_wires() -> Tuple[str, ...]:
    """The wires this process can actually encode (fp8 needs ml_dtypes)."""
    return tuple(w for w in WIRES if w in _CODECS)


class WireCostModel:
    """Measured per-(wire, size-bucket) push cost, learned online.

    Every armed ``wire.push``/``wire.pull`` feeds one observation:
    ``encode_ns`` (the codec's own time, the span's ``encode_ns`` tag) and
    the remainder of the span wall (delivery: version stamping, apply,
    broadcast hand-off — the "transfer" of an in-process fabric).  Both are
    EWMA-smoothed per wire per power-of-two **value** size bucket, so
    ``predict`` answers "what will a push of this value cost end-to-end on
    this wire, here, now" from evidence rather than a magnitude heuristic.

    ``seed(BENCH_codec.json)`` pre-loads the curve from the span-derived
    benchmark so the first pushes after arming already rank wires sensibly;
    online observations then keep it honest.

    ``link_bytes_per_s`` models a real interconnect: when set, ``predict``
    adds ``frame_bytes/link`` so quantised tiers win exactly where the
    bytes saved outrun their encode cost — the crossover the benchmark
    summarises."""

    MIN_BUCKET, MAX_BUCKET = 10, 30      # 1 KB .. 1 GB value sizes

    def __init__(self, *, alpha: float = 0.25,
                 link_bytes_per_s: Optional[float] = None):
        self.alpha = alpha
        self.link_bytes_per_s = link_bytes_per_s
        self._enc: Dict[Tuple[str, int], float] = {}   # EWMA encode ns
        self._rest: Dict[Tuple[str, int], float] = {}  # EWMA non-encode ns
        self.samples = 0

    @classmethod
    def bucket(cls, value_bytes: int) -> int:
        b = max(1, int(value_bytes)).bit_length() - 1
        return min(max(b, cls.MIN_BUCKET), cls.MAX_BUCKET)

    @staticmethod
    def frame_bytes(wire: str, value_bytes: int) -> int:
        """Analytic wire bytes for a f32 value of ``value_bytes``."""
        numel = max(1, value_bytes // 4)
        rows = max(1, -(-numel // 128))
        scales = rows * 4
        if wire == "exact":
            return value_bytes
        if wire == "int4":
            return rows * 64 + scales
        return rows * 128 + scales       # int8 / fp8: one byte per element

    def observe(self, wire: str, value_bytes: int, encode_ns: float,
                wall_ns: Optional[float] = None) -> None:
        key = (wire, self.bucket(value_bytes))
        a = self.alpha
        prev = self._enc.get(key)
        self._enc[key] = encode_ns if prev is None else prev + a * (encode_ns - prev)
        if wall_ns is not None:
            rest = max(0.0, wall_ns - encode_ns)
            prev = self._rest.get(key)
            self._rest[key] = rest if prev is None else prev + a * (rest - prev)
        self.samples += 1

    def _lookup(self, table: Dict[Tuple[str, int], float], wire: str,
                bucket: int, value_bytes: int) -> Optional[float]:
        """Nearest observed bucket for ``wire``, linearly rescaled to
        ``value_bytes`` (encode and delivery are ~linear in size past the
        dispatch floor, so per-byte extrapolation is the right first-order
        model between buckets)."""
        got = table.get((wire, bucket))
        if got is not None:
            return got
        best = None
        for (w, b), ns in table.items():
            if w != wire:
                continue
            if best is None or abs(b - bucket) < abs(best[0] - bucket):
                best = (b, ns)
        if best is None:
            return None
        return best[1] * (value_bytes / float(1 << best[0]))

    def predict(self, wire: str, value_bytes: int) -> Optional[float]:
        """Predicted end-to-end push wall in ns, or ``None`` when this wire
        has never been observed at any size (the caller should probe it)."""
        bucket = self.bucket(value_bytes)
        enc = self._lookup(self._enc, wire, bucket, value_bytes)
        if enc is None:
            return None
        total = enc
        rest = self._lookup(self._rest, wire, bucket, value_bytes)
        if rest is not None:
            total += rest
        if self.link_bytes_per_s:
            total += self.frame_bytes(wire, value_bytes) \
                / self.link_bytes_per_s * 1e9
        return total

    def seed(self, bench: Any) -> int:
        """Seed from a ``BENCH_codec.json`` dict (or path).  Returns the
        number of (wire, size) rows loaded; unknown wires are skipped."""
        if isinstance(bench, (str, bytes)):
            import json
            with open(bench) as fh:
                bench = json.load(fh)
        loaded = 0
        for kb in bench.get("value_kb", ()):
            row = bench.get(f"{kb}kb", {})
            for w, stats in row.items():
                if w not in WIRES or not isinstance(stats, dict):
                    continue
                enc_ns = stats.get("encode_us_p50", 0.0) * 1e3
                wall_ns = stats.get("push_us_p50", 0.0) * 1e3
                self.observe(w, int(kb) << 10, enc_ns, wall_ns or None)
                loaded += 1
        return loaded

    def snapshot(self) -> Dict[str, Dict[int, Tuple[float, float]]]:
        """{wire: {bucket: (encode_ns, rest_ns)}} — the scrape-time
        collector publishes this as ``faasm_wire_cost_*`` gauges."""
        out: Dict[str, Dict[int, Tuple[float, float]]] = {}
        for (w, b), enc in self._enc.items():
            out.setdefault(w, {})[b] = (enc, self._rest.get((w, b), 0.0))
        return out


# the armed cost model, or None (the default): every consult site is one
# pointer compare, the same zero-overhead discipline as _SAN/_TEL hooks
_COST: Optional[WireCostModel] = None


def enable_cost_model(model: Optional[WireCostModel] = None,
                      **kwargs) -> WireCostModel:
    """Arm the measured-cost wire selection (and span-fed learning).
    Returns the installed model; ``kwargs`` construct one when not given."""
    global _COST
    _COST = model if model is not None else WireCostModel(**kwargs)
    return _COST


def disable_cost_model() -> None:
    global _COST
    _COST = None


def cost_model() -> Optional[WireCostModel]:
    return _COST


class WirePolicy:
    """Per-key adaptive wire selection with flip-flop damping.

    Two selection regimes share the structural fallbacks (non-float dtypes
    and sub-``min_bytes`` values are always exact):

    * **heuristic** (cost model disarmed, the default): the historic binary
      exact-vs-quantised choice driven by residual/density votes, below.
    * **measured-cost** (``enable_cost_model()`` armed): ``select`` asks the
      :class:`WireCostModel` for the predicted end-to-end push wall of
      ``exact`` and every *residual-qualified* tier in ``tiers``, and
      answers the cheapest; a never-observed wire is probed once so the
      model can learn it.  Residual discipline still rules: a tier whose
      last ``damping`` observations breached ``residual_cap`` is banned
      from candidacy until a re-probe (every ``probe_after`` pushes)
      re-qualifies it — cost never overrides correctness.

    ``tiers`` lists the quantised wires this key may ride (default
    ``("int8",)``; the narrow int4/fp8 tiers are opt-in via
    ``LocalTier.wire_tiers``).  ``observe`` feeds back what the last encode
    saw:

      * ``residual_ratio`` — mean |residual| over mean |carried delta|.
        Near zero for well-conditioned deltas; grows past ``residual_cap``
        when per-row outliers make the absmax scale coarse (quantisation is
        dropping real signal) → prefer exact.  ``None`` means the push rode
        the exact wire and produced **no quantisation evidence** — such
        observations never vote for int8 (that would guarantee a permanent
        exact↔int8 thrash on keys int8 genuinely mishandles); instead they
        count toward a periodic **re-probe**: after ``probe_after`` dense
        exact pushes, ``select`` routes a single push back onto int8 so its
        residual can re-qualify (or re-disqualify) the cheap wire.
      * ``density`` — nonzero fraction of the encoded delta.  Below
        ``min_density`` the delta is a handful of spot writes; per-row
        scales carry almost no information → prefer exact.

    A switch requires ``damping`` consecutive observations preferring the
    other wire; any confirming observation resets the streak, so an
    alternating workload doesn't thrash the wire (flip-flop damping)."""

    def __init__(self, *, min_bytes: int = INT8_WIRE_MIN_BYTES,
                 residual_cap: float = 0.25, min_density: float = 1.0 / 256,
                 damping: int = 3, probe_after: int = 8,
                 tiers: Iterable[str] = ("int8",)):
        self.min_bytes = min_bytes
        self.residual_cap = residual_cap
        self.min_density = min_density
        self.damping = max(1, damping)
        self.probe_after = max(1, probe_after)
        self.tiers = tuple(tiers)
        for t in self.tiers:
            if t == "exact" or t not in WIRES:
                raise ValueError(f"tier {t!r} not a quantised wire in {WIRES}")
        self._quant = self.tiers[0] if self.tiers else "int8"
        self._wire = self._quant
        self._streak = 0
        self._exact_obs = 0              # dense exact pushes since last probe
        self.flips = 0                   # damped wire switches (telemetry)
        self._over_cap = {t: 0 for t in self.tiers}  # consecutive breaches
        self._banned: set = set()        # residual-disqualified tiers
        self._since_ban: Dict[str, int] = {}

    @property
    def wire(self) -> str:
        """The adaptive choice for values past the structural fallbacks."""
        return self._wire

    def select(self, nbytes: int, dtype, *, probe: bool = True) -> str:
        """The wire to use now.  ``probe=False`` (pull-side selection) reads
        the current choice without consuming the quantised re-probe — a
        pull's encode produces no ``observe`` feedback, so spending the
        probe on it would starve the push wire's re-qualification."""
        if np.dtype(dtype).kind != "f" or nbytes < self.min_bytes:
            return "exact"
        cost = _COST
        if cost is not None:
            return self._select_cost(cost, nbytes, probe)
        if (probe and self._wire == "exact"
                and self._exact_obs >= self.probe_after):
            self._exact_obs = 0
            return self._quant           # one probe push; observe() decides
        return self._wire

    def _select_cost(self, cost: WireCostModel, nbytes: int,
                     probe: bool) -> str:
        """Measured-cost selection: cheapest predicted end-to-end push among
        exact and the residual-qualified tiers; never-observed wires are
        probed once so the model can rank them."""
        choice, best_ns = None, None
        for w in ("exact",) + self.tiers:
            if w in self._banned:
                if probe:
                    self._since_ban[w] = self._since_ban.get(w, 0) + 1
                    if self._since_ban[w] >= self.probe_after:
                        # one re-qualification push on the banned tier
                        self._since_ban[w] = 0
                        choice = w
                        break
                continue
            p = cost.predict(w, nbytes)
            if p is None:
                choice = w               # unknown cost: probe to learn
                break
            if best_ns is None or p < best_ns:
                choice, best_ns = w, p
        if choice != self._wire:
            self._wire = choice
            self.flips += 1
        return choice

    def observe(self, *, delta_absmax: float, density: float,
                residual_ratio: Optional[float] = None,
                wire: Optional[str] = None) -> None:
        if delta_absmax == 0.0:
            return                       # a no-op push teaches nothing
        if residual_ratio is not None and wire in self._over_cap:
            # per-tier residual discipline (both regimes): `damping`
            # consecutive cap breaches ban the tier; a clean observation
            # (e.g. the re-probe) re-qualifies it
            if residual_ratio > self.residual_cap:
                self._over_cap[wire] += 1
                if self._over_cap[wire] >= self.damping:
                    self._over_cap[wire] = 0
                    self._banned.add(wire)
                    self._since_ban[wire] = 0
            else:
                self._over_cap[wire] = 0
                self._banned.discard(wire)
        if _COST is not None:
            return                       # cost regime: selection is measured
        if residual_ratio is None:
            # exact-wire push: quantisation quality unknown.  Sparse deltas
            # still vote exact; dense ones only advance the re-probe clock.
            if density < self.min_density:
                self._vote("exact")
            elif self._wire == "exact":
                self._exact_obs += 1
            return
        prefer_exact = (residual_ratio > self.residual_cap
                        or density < self.min_density)
        self._vote("exact" if prefer_exact else self._quant)

    def _vote(self, want: str) -> None:
        if want == self._wire:
            self._streak = 0
            return
        self._streak += 1
        if self._streak >= self.damping:
            self._wire = want
            self._streak = 0
            self._exact_obs = 0
            self.flips += 1
