from repro.state.kv import GlobalTier, RWLock, DEFAULT_CHUNK
from repro.state.local import LocalTier, Replica
from repro.state.ddo import (Counter, DistDict, MatrixReadOnly,
                             SparseMatrixReadOnly, VectorAsync)

__all__ = ["GlobalTier", "RWLock", "DEFAULT_CHUNK", "LocalTier", "Replica",
           "Counter", "DistDict", "MatrixReadOnly", "SparseMatrixReadOnly",
           "VectorAsync"]
