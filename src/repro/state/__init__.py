from repro.state.kv import GlobalTier, RWLock, DEFAULT_CHUNK
from repro.state.local import LocalTier, Replica
from repro.state.wire import (INT8_WIRE_MIN_BYTES, WIRES, WireFrame,
                              WirePolicy, get_codec)
from repro.state.ddo import (Counter, DistDict, MatrixReadOnly,
                             SparseMatrixReadOnly, VectorAsync)

__all__ = ["GlobalTier", "RWLock", "DEFAULT_CHUNK", "LocalTier", "Replica",
           "INT8_WIRE_MIN_BYTES", "WIRES", "WireFrame", "WirePolicy",
           "get_codec", "Counter", "DistDict", "MatrixReadOnly",
           "SparseMatrixReadOnly", "VectorAsync"]
