"""Distributed data objects (Faasm §4): typed fronts over the byte-array state API.

These mirror Listing 1 of the paper: ``SparseMatrixReadOnly`` /
``MatrixReadOnly`` pull only the state *chunks* backing the columns a function
touches; ``VectorAsync`` gives HOGWILD-style direct writes to a shared-region
pointer with sporadic ``push()`` to the global tier (eventual consistency);
``DistDict`` / ``Counter`` demonstrate strongly-consistent DDOs built with
global locks.
"""
from __future__ import annotations

import json
from typing import Optional, Tuple

import numpy as np

META_SUFFIX = "::meta"


def _write_meta(gt, key: str, meta: dict) -> None:
    gt.set(key + META_SUFFIX, json.dumps(meta).encode(), host="upload")


def _read_meta(api, key: str) -> dict:
    return json.loads(bytes(api.get_state(key + META_SUFFIX, writable=False)))


class MatrixReadOnly:
    """Dense 2-D matrix stored column-major so column ranges are contiguous
    byte ranges — a ``columns`` access pulls only the covering chunks."""

    @staticmethod
    def create(global_tier, key: str, value: np.ndarray) -> None:
        value = np.asarray(value, np.float32)
        global_tier.set(key, np.asfortranarray(value).tobytes(order="F"),
                        host="upload")
        _write_meta(global_tier, key, {"shape": list(value.shape),
                                       "dtype": "float32"})

    def __init__(self, api, key: str):
        self.api = api
        self.key = key
        meta = _read_meta(api, key)
        self.shape: Tuple[int, int] = tuple(meta["shape"])
        self.itemsize = 4

    def columns(self, c0: int, c1: int) -> np.ndarray:
        """Read-only view of columns [c0, c1) — pulls only what is needed."""
        rows = self.shape[0]
        off = c0 * rows * self.itemsize
        length = (c1 - c0) * rows * self.itemsize
        raw = self.api.get_state_offset(self.key, off, length, writable=False)
        return np.frombuffer(bytes(raw), np.float32).reshape(
            rows, c1 - c0, order="F")


class SparseMatrixReadOnly:
    """CSC sparse matrix over three state values (data/indices/indptr)."""

    @staticmethod
    def create(global_tier, key: str, dense: np.ndarray) -> None:
        dense = np.asarray(dense, np.float32)
        rows, cols = dense.shape
        data, indices, indptr = [], [], [0]
        for c in range(cols):
            nz = np.nonzero(dense[:, c])[0]
            data.extend(dense[nz, c].tolist())
            indices.extend(nz.tolist())
            indptr.append(len(data))
        global_tier.set(key + "::data", np.asarray(data, np.float32).tobytes(),
                        host="upload")
        global_tier.set(key + "::indices",
                        np.asarray(indices, np.int32).tobytes(), host="upload")
        global_tier.set(key + "::indptr",
                        np.asarray(indptr, np.int64).tobytes(), host="upload")
        _write_meta(global_tier, key, {"shape": [rows, cols], "nnz": len(data)})

    def __init__(self, api, key: str):
        self.api = api
        self.key = key
        meta = _read_meta(api, key)
        self.shape = tuple(meta["shape"])
        self.nnz = meta["nnz"]
        self._indptr = np.frombuffer(
            bytes(api.get_state(key + "::indptr", writable=False)), np.int64)

    def columns(self, c0: int, c1: int):
        """Yield (col_idx, row_indices, values) for columns [c0, c1)."""
        p0, p1 = int(self._indptr[c0]), int(self._indptr[c1])
        vals = np.frombuffer(bytes(self.api.get_state_offset(
            self.key + "::data", p0 * 4, (p1 - p0) * 4, writable=False)),
            np.float32)
        idxs = np.frombuffer(bytes(self.api.get_state_offset(
            self.key + "::indices", p0 * 4, (p1 - p0) * 4, writable=False)),
            np.int32)
        for c in range(c0, c1):
            a, b = int(self._indptr[c] - p0), int(self._indptr[c + 1] - p0)
            yield c, idxs[a:b], vals[a:b]


class VectorAsync:
    """Shared f32 vector with lock-free local writes and sporadic push().

    The local view is a *pointer into the host-shared region*: co-located
    functions see each other's updates immediately (HOGWILD!).  ``push()``
    writes only dirty chunks to the global tier; consistency between tiers is
    eventual, as tolerated by SGD (paper §4.1).
    """

    @staticmethod
    def create(global_tier, key: str, value: np.ndarray) -> None:
        value = np.asarray(value, np.float32)
        global_tier.set(key, value.tobytes(), host="upload")
        _write_meta(global_tier, key, {"shape": list(value.shape),
                                       "dtype": "float32"})

    def __init__(self, api, key: str):
        self.api = api
        self.key = key
        meta = _read_meta(api, key)
        self.shape = tuple(meta["shape"])
        raw = api.get_state(key, writable=True)      # maps the shared region
        self._view = raw.view(np.float32)[:int(np.prod(self.shape))]

    @property
    def values(self) -> np.ndarray:
        return self._view

    def __getitem__(self, i):
        return self._view[i]

    def __setitem__(self, i, v):
        self._view[i] = v
        self.api._local().mark_dirty(self.key, 0, self._view.nbytes)

    def add(self, idx, delta) -> None:
        """Unlocked accumulate (HOGWILD) through the shared-region pointer."""
        np.add.at(self._view, idx, delta)
        self.api._local().mark_dirty(self.key, 0, self._view.nbytes)

    def _flush_if_copy(self) -> None:
        """Container isolation hands out *copies* (data shipping): mutations
        must be written back through set_state before a push — exactly the
        extra copy the paper's Knative baseline pays."""
        if getattr(self.api.host, "isolation", "faaslet") == "container":
            self.api.set_state(self.key,
                               np.asarray(self._view, np.float32).tobytes())

    def push(self) -> None:
        self._flush_if_copy()
        self.api.push_state_partial(self.key)

    def push_delta(self, wire: str = "auto") -> None:
        """Accumulating push — concurrent pushes from different hosts compose.

        ``wire="auto"`` (default) lets the key's adaptive ``WirePolicy``
        choose; ``"int8"`` forces the quantised ``kernels/state_push``
        frame (~¼ of the f32 bytes, error-feedback carried across pushes)
        and ``"exact"`` the f32 delta frame."""
        self._flush_if_copy()
        self.api.push_state_delta(self.key, dtype=np.float32, wire=wire)

    def pull(self, track_delta: bool = False, wire: str = None) -> None:
        """Refresh the local view.  Warm replicas refresh through the wire
        fabric (delta pull, ``wire`` as in :meth:`push_delta`); a replica
        subscribed via :meth:`subscribe` is typically already current and
        the pull moves zero bytes."""
        self.api.pull_state(self.key, track_delta=track_delta, wire=wire)
        raw = self.api.get_state(self.key, writable=True)
        self._view = raw.view(np.float32)[:int(np.prod(self.shape))]

    def subscribe(self) -> None:
        """Subscribe the host replica to peer push fan-out (Cloudburst-style
        push-based cache refresh): later pulls on this host are free unless
        a broadcast was missed."""
        self.api.subscribe_state(self.key)


class DistDict:
    """Strongly-consistent dict: global write locks around read-modify-write."""

    def __init__(self, api, key: str):
        self.api = api
        self.key = key

    def _load(self) -> dict:
        gt = self.api.runtime.global_tier
        if not gt.exists(self.key):
            return {}
        return json.loads(gt.get(self.key, host=self.api.host.id) or b"{}")

    def get(self, k, default=None):
        self.api.lock_state_global_read(self.key)
        try:
            return self._load().get(k, default)
        finally:
            self.api.unlock_state_global_read(self.key)

    def set(self, k, v) -> None:
        self.api.lock_state_global_write(self.key)
        try:
            d = self._load()
            d[k] = v
            self.api.runtime.global_tier.set(
                self.key, json.dumps(d).encode(), host=self.api.host.id)
        finally:
            self.api.unlock_state_global_write(self.key)


class Counter:
    """Atomic distributed counter (global write lock)."""

    def __init__(self, api, key: str):
        self.api = api
        self.key = key

    def increment(self, by: int = 1) -> int:
        gt = self.api.runtime.global_tier
        self.api.lock_state_global_write(self.key)
        try:
            cur = int(gt.get(self.key, host=self.api.host.id) or b"0") \
                if gt.exists(self.key) else 0
            cur += by
            gt.set(self.key, str(cur).encode(), host=self.api.host.id)
            return cur
        finally:
            self.api.unlock_state_global_write(self.key)

    def value(self) -> int:
        gt = self.api.runtime.global_tier
        if not gt.exists(self.key):
            return 0
        return int(gt.get(self.key, host=self.api.host.id))
