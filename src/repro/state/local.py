"""Local state tier: zero-copy shared replicas on one host (Faasm §4.2).

Replicas live in *shared memory regions* (§3.3): one numpy buffer per state
value, and every Faaslet on the host maps a **view** of the same buffer into
its address space — reads and writes are genuinely shared, no serialisation.
Chunk presence is tracked so a pull only transfers missing chunks.

Tier synchronisation is single-copy each way: pulls ``readinto`` the replica
buffer straight from global storage and pushes ``write_from`` it straight
back (no get→bytes→frombuffer→assign round trip), and ``push_delta`` applies
``global += local − base`` arithmetically in the global buffer — the
HOGWILD serialisation point holds the key's global write lock for one
in-place pass instead of four full-value copies.

Device-resident replica plane: a replica can additionally hold its value as
a **JAX device array** (:class:`DeviceReplica`) with explicit
``to_device``/``from_device`` sync.  Staleness is tracked against the
replica's write version — every host-side mutation (``mark_dirty``, pull)
bumps ``Replica.version``; the device copy records the version it was
synced at, so a stale device array is never silently pushed.

Symmetric wire fabric (``repro.state.wire``): every delta crossing the tier
boundary is a :class:`~repro.state.wire.WireFrame` encoded by a
:class:`~repro.state.wire.WireCodec` — identically in both directions.

  * **Push** — ``push_delta(wire="int8")`` runs the fused
    ``kernels/state_push`` quantise kernel on the pusher (device-native when
    a fresh :class:`DeviceReplica` is bound — the value never round-trips
    through host buffers) and the global tier lands the frame via
    :meth:`GlobalTier.apply_wire` (~¼ of the f32 bytes).  Exact f32 pushes
    travel as exact frames so they too are recorded/broadcast.  Per-replica
    **error feedback** carries the quantisation residual into the next push.
  * **Pull** — a warm replica that knows its base version refreshes through
    :meth:`GlobalTier.pull_wire`: only the retained delta ships (int8 ≈ ¼
    of a full f32 re-pull), with a full-pull fallback when the base
    predates the retained window; the pull-side residual is owned by the
    pulling replica.
  * **Broadcast** — a :meth:`subscribe`\\ d replica receives every frame a
    peer pushes and applies it in place (host buffer, delta base, fresh
    device arrays via ``ops.apply_pull``), converging with zero pull bytes.

``wire="auto"`` (or ``None``) delegates the choice to the key's
:class:`~repro.state.wire.WirePolicy`: with the
:class:`~repro.state.wire.WireCostModel` armed it argmins the measured
per-size end-to-end push cost over ``exact`` and the residual-qualified
tiers in ``wire_tiers`` (the opt-in menu — ``set_wire_tiers("int8",
"int4", "fp8")``); disarmed, the historic exact-vs-quantised vote from
observed delta magnitude/density and residual norm, with flip-flop
damping.  Explicit ``wire=`` strings remain as overrides.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Set

import numpy as np

from repro import faults
from repro.analysis.sanitizer import make_mutex, wrap_rwlock
from repro.state import wire as _wire_mod
from repro.state.kv import GlobalTier, RWLock
from repro.state.wire import (INT8_WIRE_MIN_BYTES, WIRES, WireFrame,
                              WirePolicy, get_codec)
from repro.telemetry import clock as _clock

__all__ = ["DeviceReplica", "INT8_WIRE_MIN_BYTES", "LocalTier", "Replica"]

# per-wire maximum |code|: absmax ≈ scale·QMAX reconstructs the delta absmax
# from the wire tuple without a second full-array pass
_WIRE_QMAX = {"int8": 127.0, "int4": 7.0, "fp8": 448.0}


class CodecFallback(Exception):
    """Internal: a quantised encode failed mid-push; ``push_delta`` retries
    the same delta (same fence token) on the exact wire so no state is
    lost."""

# repro.analysis.sanitizer installs its hook state here (enable()); None
# compiles every check in this module down to one pointer compare
_SAN = None
# repro.telemetry installs its tracer here (enable()); same discipline —
# disarmed is one pointer compare per wire event, zero ring writes.  Ring
# writes are lock-safe (single-writer per thread), so spans may be
# recorded under replica/key locks; only collector drains may not.
_TEL = None


def _mean_abs(x) -> float:
    """Mean |x| as a python float; works for numpy and jax arrays (a jax
    input syncs only the scalar, not the array)."""
    if x is None or getattr(x, "size", 0) == 0:
        return 0.0
    return float(abs(x).mean())


@dataclass
class DeviceReplica:
    """Optional JAX device residency for a replica (one value, one device).

    ``value`` is the flat typed device array mirroring the replica buffer;
    ``base`` the device-side snapshot a delta push diffs against (refreshing
    it after a push is a rebind — device arrays are immutable, no copy);
    ``residual`` the error-feedback carry for int8 wire pushes.
    ``synced_version`` is the :attr:`Replica.version` the device copy was
    taken at; ``device_dirty`` marks device-side writes (``update_device``)
    not yet propagated back to the shared host buffer."""

    dtype: np.dtype = np.dtype(np.float32)
    value: Any = None                    # jnp.ndarray, flat
    base: Any = None                     # jnp.ndarray snapshot for delta push
    residual: Any = None                 # jnp.ndarray f32 error-feedback carry
    synced_version: int = -1
    device_dirty: bool = False

    def fresh(self, replica: "Replica") -> bool:
        """True when the device arrays are safe to push from: either in sync
        with the host buffer or strictly ahead of it (device-side writes)."""
        return self.value is not None and (
            self.device_dirty or self.synced_version == replica.version)


@dataclass
class Replica:
    buf: np.ndarray                      # uint8, the shared region backing
    lock: RWLock = field(
        default_factory=lambda: wrap_rwlock(RWLock(), "replica"))
    present_chunks: Set[int] = field(default_factory=set)
    dirty_chunks: Set[int] = field(default_factory=set)
    full: bool = False                   # whole value present
    base: Optional[np.ndarray] = None    # snapshot for delta-accumulating push
    version: int = 0                     # bumped on every host-side mutation
    residual: Optional[np.ndarray] = None  # f32 error-feedback carry (int8 wire)
    device: Optional[DeviceReplica] = None
    # wire-fabric state: the global write version this replica's content
    # incorporates (-1 = unknown, e.g. locally fabricated via set_state —
    # such replicas keep the legacy never-refresh semantics), and the
    # pull-direction error-feedback carry (owned by the pulling replica)
    global_version: int = -1
    pull_residual: Optional[np.ndarray] = None


class LocalTier:
    """Per-host replica store.  All Faaslets of the host share these buffers."""

    def __init__(self, host_id: str, global_tier: GlobalTier):
        self.host_id = host_id
        self.global_tier = global_tier
        # fabric identity: host_id may later be re-pointed at the physical
        # host for transfer metrics (container tiers charge the host), but
        # frames must be attributed to THIS tier — sibling container tiers
        # sharing a metrics id must not skip each other's frames on pull or
        # collide on one broadcast subscription slot
        self.origin_id = host_id
        self._replicas: Dict[str, Replica] = {}
        self._policies: Dict[str, WirePolicy] = {}
        self._subscribed: Set[str] = set()
        self._mutex = make_mutex("tier", f"tier:{host_id}")
        self.codec_fallbacks = 0     # quantised encodes rescued by exact wire
        # quantised tiers the per-key policies may choose from; the narrow
        # int4/fp8 tiers are opt-in (set_wire_tiers) — their coarser codes
        # ride the same residual_cap error-feedback discipline
        self.wire_tiers = ("int8",)

    # -- replica lifecycle ------------------------------------------------------

    def replica(self, key: str, size: Optional[int] = None) -> Replica:
        """Get or create the shared replica buffer for ``key`` (no transfer)."""
        with self._mutex:
            r = self._replicas.get(key)
            if r is None:
                if size is None:
                    size = self.global_tier.size(key)
                r = Replica(buf=np.zeros(size, np.uint8))
                self._replicas[key] = r
            elif size is not None and size > r.buf.size:
                grown = np.zeros(size, np.uint8)
                grown[:r.buf.size] = r.buf
                r.buf = grown
                r.version += 1
            return r

    def has(self, key: str) -> bool:
        with self._mutex:
            return key in self._replicas

    def drop(self, key: Optional[str] = None) -> None:
        """Evict replicas (host failure / memory pressure).  Any broadcast
        subscriptions and warm-puller registrations for the dropped keys
        are cancelled — a host that leaves mid-broadcast stops receiving
        frames, and pushers stop retaining window frames for it."""
        with self._mutex:
            if key is None:
                self._replicas.clear()
                self._subscribed.clear()
            else:
                self._replicas.pop(key, None)
                self._subscribed.discard(key)
        self.global_tier.unsubscribe(self.origin_id, key)
        self.global_tier.deregister_puller(self.origin_id, key)

    def memory_bytes(self) -> int:
        with self._mutex:
            return sum(r.buf.size for r in self._replicas.values())

    def keys(self):
        with self._mutex:
            return list(self._replicas.keys())

    # -- device residency (explicit sync, version-checked staleness) -----------

    def to_device(self, key: str, dtype=np.float32, *,
                  track_delta: bool = False):
        """Materialise (or refresh) the replica as a JAX device array.

        Returns the device value.  A no-op when the device copy is already
        at the replica's current write version.  With ``track_delta`` the
        device-side base snapshot is (re)taken at this sync point, arming a
        subsequent device-native ``push_delta``.  A host-side error-feedback
        residual moves to the device with the value (ownership transfer —
        the debt must not be applied twice).  While device-side writes are
        pending (``update_device`` without a push or ``from_device``),
        ``track_delta`` is a no-op: re-arming the base to the unsynced value
        would silently drop that delta from every future push."""
        import jax.numpy as jnp

        r = self._replicas[key]
        dt = np.dtype(dtype)
        # write lock: this mutates r.device and the DeviceReplica fields, and
        # concurrent to_device calls must not race on creating/arming them
        r.lock.acquire_write()
        try:
            d = r.device
            if d is None or d.dtype != dt:
                d = DeviceReplica(dtype=dt)
                r.device = d
            if not d.device_dirty and (d.value is None
                                       or d.synced_version != r.version):
                # copy=True: jnp.asarray may alias host memory on the CPU
                # backend, but the device replica must be a *snapshot* at
                # this version — later host writes must not leak through
                d.value = jnp.array(r.buf.view(dt), copy=True)
                if r.residual is not None and \
                        r.residual.size == int(d.value.size):
                    d.residual = jnp.array(r.residual, copy=True)
                    r.residual = None            # device owns the debt now
                d.synced_version = r.version
            if track_delta and not d.device_dirty:
                d.base = d.value
            return d.value
        finally:
            r.lock.release_write()

    def update_device(self, key: str, value) -> None:
        """Install a device-computed value as the replica's device copy.

        The device copy is now *ahead* of the shared host buffer; call
        :meth:`from_device` to propagate it (or ``push_delta`` to ship the
        delta straight to the global tier without a host round-trip)."""
        r = self._replicas[key]
        r.lock.acquire_write()
        try:
            d = r.device
            if d is None:
                raise RuntimeError(f"no device replica for {key!r}; "
                                   "call to_device first")
            if int(np.prod(np.shape(value))) * d.dtype.itemsize > r.buf.size:
                raise ValueError(f"device value larger than replica {key!r}")
            d.value = value
            d.device_dirty = True
        finally:
            r.lock.release_write()

    def from_device(self, key: str) -> int:
        """Copy the device value back into the shared host buffer (one D2H
        memcpy), bump the write version, and mark the range dirty.  The
        device-side delta base and error-feedback residual come back with
        it, so a later *host-path* push diffs against the content the global
        tier last saw instead of re-pushing device-era deltas.  Returns
        bytes synced."""
        r = self._replicas[key]
        r.lock.acquire_write()
        try:
            d = r.device
            if d is None or d.value is None:
                raise RuntimeError(f"no device value for {key!r}")
            # snapshot d.value under the lock: a concurrent update_device
            # must not land between the read and the device_dirty clear
            host = np.asarray(d.value).reshape(-1).view(np.uint8)
            n = min(host.size, r.buf.size)
            r.buf[:n] = host[:n]
            if d.base is not None:
                hb = np.asarray(d.base).reshape(-1).view(np.uint8)
                if r.base is None or r.base.size != r.buf.size:
                    r.base = np.zeros(r.buf.size, np.uint8)
                m = min(hb.size, r.base.size)
                r.base[:m] = hb[:m]
            if d.residual is not None:
                r.residual = np.array(d.residual, dtype=np.float32)
                d.residual = None                # host owns the debt again
            cs = self.global_tier.chunk_size
            if n:
                r.dirty_chunks.update(range(0, (n - 1) // cs + 1))
            r.version += 1
            d.synced_version = r.version
            d.device_dirty = False
        finally:
            r.lock.release_write()
        return n

    def device_replica(self, key: str) -> Optional[DeviceReplica]:
        r = self._replicas.get(key)
        return r.device if r is not None else None

    def device_stale(self, key: str) -> bool:
        """True when host-side writes postdate the last device sync (and the
        device holds no unsynced writes of its own)."""
        r = self._replicas[key]
        d = r.device
        if d is None or d.value is None:
            return True
        return not d.device_dirty and d.synced_version != r.version

    # -- wire policy / broadcast subscription -----------------------------------

    def wire_policy(self, key: str) -> WirePolicy:
        """The key's adaptive wire selector (shared by push and pull)."""
        with self._mutex:
            p = self._policies.get(key)
            if p is None:
                p = self._policies[key] = WirePolicy(tiers=self.wire_tiers)
            return p

    def set_wire_tiers(self, *tiers: str) -> None:
        """Opt this tier's keys into a different quantised-tier menu (e.g.
        ``set_wire_tiers("int8", "int4")``).  Existing per-key policies are
        rebuilt — learned selection state restarts from the defaults."""
        for t in tiers:
            get_codec(t)                 # unknown/unavailable wires fail loud
        self.wire_tiers = tuple(tiers)
        with self._mutex:
            self._policies.clear()

    def policy_flips(self) -> int:
        """Total damped wire switches across this tier's per-key policies
        (telemetry: published as ``faasm_wire_policy_flips_total``)."""
        with self._mutex:
            return sum(p.flips for p in self._policies.values())

    def subscribe(self, key: str) -> int:
        """Subscribe this tier's replica to the key's push fan-out: every
        wire frame another host applies to the global value is delivered and
        applied in place (host buffer, delta base, fresh device arrays), so
        the warm replica converges with **zero pull bytes**.  Returns the
        bytes the initial sync pulled.

        The callback registers *before* the initial pull: a frame pushed in
        between is either already inside the pulled content (the pull
        captures value+version atomically) or arrives with a version that
        chains onto it — registering after the pull would lose any frame
        landing in the gap and leave every later one skipped on the version
        check.  Early deliveries against the not-yet-pulled replica are
        version-mismatched no-ops."""
        self.replica(key, self.global_tier.size(key))
        with self._mutex:
            self._subscribed.add(key)
        self.global_tier.subscribe(key, self.origin_id, self._deliver)
        return self.pull(key)

    def unsubscribe(self, key: Optional[str] = None) -> None:
        with self._mutex:
            if key is None:
                self._subscribed.clear()
            else:
                self._subscribed.discard(key)
        self.global_tier.unsubscribe(self.origin_id, key)

    def _deliver(self, key: str, frame: WireFrame) -> None:
        """Broadcast delivery: apply when the frame extends exactly this
        replica's version; anything else (gap from a missed frame, an
        out-of-order race between two pushers, a duplicate) is skipped —
        the next pull repairs it through the delta window.  Raising (e.g.
        the replica was evicted) drops the subscription tier-side."""
        if faults.point("wire-frame-drop", key=key, host=self.host_id):
            return                       # frame lost on the wire to this peer
        faults.point("wire-frame-delay", key=key, host=self.host_id)
        faults.point("subscriber-raise", key=key, host=self.host_id)
        # a stalled subscriber: runs on the broadcast pump thread, so the
        # stall backpressures this host's bounded channel (coalescing, then
        # drop-to-pull-repair) — never the pusher (asserted in test_chaos)
        faults.point("subscriber-stall", key=key, host=self.host_id)
        with self._mutex:
            r = self._replicas.get(key)
        if r is None:
            raise KeyError(f"replica {key!r} evicted")
        tel = _TEL
        t0 = tel.now() if tel is not None else 0.0
        applied = False
        r.lock.acquire_write()
        try:
            if frame.prev_version == r.global_version:
                self._apply_frame_locked(r, frame)
                applied = True
        finally:
            r.lock.release_write()
        if tel is not None:
            tel.record("wire.bcast", "wire", t0, tel.now(), key=key,
                       wire=frame.wire, nbytes=frame.nbytes, applied=applied,
                       prev_version=frame.prev_version, version=frame.version,
                       subscriber=self.origin_id)

    def _apply_frame_locked(self, r: Replica, frame: WireFrame, *,
                            backend: Optional[str] = None,
                            set_version: Optional[int] = None) -> None:
        """Apply a wire frame to the replica (write lock held): the host
        buffer, the delta base (the global tier already holds this delta —
        without the base update the next ``push_delta`` would re-push it),
        and a fresh device replica's arrays, so a device-native push keeps
        diffing against content the global tier has seen."""
        if _SAN is not None:
            _SAN.assert_write_held(r.lock, "_apply_frame_locked")
        delta = frame.decode()
        dt = np.dtype(frame.dtype)
        # the frame names the value dtype it applies to: viewing the buffer
        # as anything else would scramble e.g. an f64 key's bytes
        fv = r.buf[:r.buf.size - r.buf.size % dt.itemsize].view(dt)
        n = min(fv.size, delta.size)
        if n:
            fv[:n] += delta[:n].astype(dt, copy=False)
        if r.base is not None and r.base.size >= dt.itemsize:
            bv = r.base[:r.base.size - r.base.size % dt.itemsize].view(dt)
            m = min(bv.size, delta.size)
            if m:
                bv[:m] += delta[:m].astype(dt, copy=False)
        d = r.device
        was_fresh = d is not None and d.value is not None and d.fresh(r)
        if was_fresh:
            import jax.numpy as jnp
            k = min(int(d.value.size), delta.size)
            if k:
                codes = (frame.codes()
                         if int(d.value.size) == frame.numel else None)
                if codes is not None:
                    # quantised frame onto a device value: the fused kernel
                    # applies q·scale on device — no host round-trip (int4
                    # arrives nibble-unpacked, fp8 casts in-kernel)
                    from repro.kernels.state_push import ops
                    d.value = ops.apply_pull(d.value, codes[0], codes[1],
                                             backend=backend)
                else:
                    upd = jnp.asarray(delta[:k]).astype(d.value.dtype)
                    d.value = d.value.at[:k].add(upd)
                if d.base is not None:
                    kb = min(int(d.base.size), delta.size)
                    ub = jnp.asarray(delta[:kb]).astype(d.base.dtype)
                    d.base = d.base.at[:kb].add(ub)
        r.version += 1
        if was_fresh and not d.device_dirty:
            d.synced_version = r.version
        r.global_version = frame.version if set_version is None \
            else set_version

    # -- pull / push (tier synchronisation) ----------------------------------------

    def pull(self, key: str, *, wire: Optional[str] = None,
             backend: Optional[str] = None) -> int:
        """Ensure the replica holds the current global value.  Returns bytes
        moved (0 on an up-to-date replica) — symmetric with :meth:`push`.

        Cold replicas full-pull as before.  A replica that already holds
        the full value and knows its base version **refreshes through the
        wire fabric**: the global tier ships only the retained delta
        (``wire="int8"`` re-encodes it with the fused ``kernels/state_push``
        quantise kernel, ~¼ of the f32 re-pull bytes; ``wire=None``/"auto"
        lets the key's :class:`WirePolicy` decide; ``wire="exact"`` ships
        the f32 delta), falling back to a full pull when the base predates
        the retained delta window.  Pull-side quantisation error is carried
        per replica as an error-feedback residual into the next delta pull."""
        faults.point("tier-pull-stall", key=key, host=self.host_id)
        size = self.global_tier.size(key)
        r = self.replica(key, size)
        moved = 0
        r.lock.acquire_write()
        try:
            if not r.full:
                moved = self._full_pull_locked(key, r, size)
                r.full = True
                r.present_chunks = set(range(self.global_tier.n_chunks(key)))
            elif r.global_version >= 0:
                moved = self._refresh_locked(key, r, size, wire, backend)
        finally:
            r.lock.release_write()
        return moved

    def _full_pull_locked(self, key: str, r: Replica, size: int, *,
                          refresh_base: bool = False) -> int:
        """Whole-value pull (replica write lock held): one ``readinto``
        memcpy, base version captured atomically with the content.

        ``refresh_base`` (the warm-refresh fallback) re-stamps the delta
        base from the pulled buffer: the buffer now *is* the global value,
        so the base must say the global tier has seen it — otherwise the
        next ``push_delta`` would re-push every peer write since the old
        snapshot.  The cold path keeps the legacy leave-the-base semantics
        (callers re-arm with ``track_delta``/``snapshot_base``)."""
        tel = _TEL
        t0 = tel.now() if tel is not None else 0.0
        moved = 0
        if size:
            moved, ver = self.global_tier.readinto(
                key, 0, r.buf[:size], host=self.host_id, clamp=True,
                return_version=True)
        else:
            ver = self.global_tier.version(key)
        if tel is not None and moved:
            tel.record("wire.full_pull", "wire", t0, tel.now(), key=key,
                       nbytes=moved, version=ver, puller=self.origin_id)
        # a warm full replica is a future delta-puller: declare interest so
        # pushers start feeding the key's retained window
        self.global_tier.register_puller(key, self.origin_id)
        r.global_version = ver
        r.pull_residual = None
        if moved:
            r.version += 1
            if refresh_base and r.base is not None:
                self._refresh_base(r)
        return moved

    def _refresh_locked(self, key: str, r: Replica, size: int,
                        wire: Optional[str],
                        backend: Optional[str]) -> int:
        """Warm-replica refresh (replica write lock held): delta pull
        through the wire fabric, full-pull fallback on a stale base."""
        w = wire
        if w in (None, "auto"):
            w = self.wire_policy(key).select(r.buf.size,
                                             np.dtype(np.float32),
                                             probe=False)
        res = self.global_tier.pull_wire(
            key, r.global_version, wire=w, residual=r.pull_residual,
            exclude_origin=self.origin_id, backend=backend,
            host=self.host_id)
        if res is None:
            # base older than the window floor (or non-delta writes landed):
            # the delta path can't express the catch-up.  With un-pushed
            # local writes pending, a full pull would clobber them — keep
            # the legacy warm no-op (the replica refreshes after its push);
            # a clean replica full-pulls and re-bases.
            if r.dirty_chunks:
                return 0
            return self._full_pull_locked(key, r, size, refresh_base=True)
        frame, ver, residual = res
        if frame is None:
            r.global_version = ver
            return 0
        self._apply_frame_locked(r, frame, backend=backend, set_version=ver)
        r.pull_residual = residual
        return frame.nbytes

    def pull_chunk(self, key: str, chunk_idx: int) -> int:
        """Replicate a single state chunk (Fig. 4: partial values).
        Returns bytes moved (0 on a local hit)."""
        size = self.global_tier.size(key)
        r = self.replica(key, size)
        moved = 0
        r.lock.acquire_write()
        try:
            if chunk_idx not in r.present_chunks:
                start, length = self.global_tier.chunk_bounds(key, chunk_idx)
                if length > 0:
                    moved = self.global_tier.readinto(
                        key, start, r.buf[start:start + length],
                        host=self.host_id, clamp=True)
                r.present_chunks.add(chunk_idx)
                if len(r.present_chunks) == self.global_tier.n_chunks(key):
                    r.full = True
                if moved:
                    r.version += 1
        finally:
            r.lock.release_write()
        return moved

    def pull_range(self, key: str, offset: int, length: int) -> int:
        """Pull exactly the chunks covering [offset, offset+length).
        Returns bytes moved."""
        cs = self.global_tier.chunk_size
        moved = 0
        for idx in range(offset // cs, (offset + max(length, 1) - 1) // cs + 1):
            moved += self.pull_chunk(key, idx)
        return moved

    def push(self, key: str) -> int:
        """Write the full local replica to the global tier (single memcpy
        from the replica buffer).  Returns bytes."""
        with self._mutex:
            r = self._replicas[key]
        r.lock.acquire_read()
        try:
            moved = self.global_tier.write_from(key, 0, r.buf,
                                                host=self.host_id,
                                                truncate=True)
        finally:
            r.lock.release_read()
        r.dirty_chunks.clear()
        return moved

    def push_dirty(self, key: str) -> int:
        """Push only chunks marked dirty (partial push).  Returns bytes."""
        with self._mutex:
            r = self._replicas[key]
        moved = 0
        r.lock.acquire_read()
        try:
            dirty = sorted(r.dirty_chunks)
            cs = self.global_tier.chunk_size
            for idx in dirty:
                start = idx * cs
                end = min(start + cs, r.buf.size)
                if end > start:
                    moved += self.global_tier.write_from(
                        key, start, r.buf[start:end], host=self.host_id)
        finally:
            r.lock.release_read()
        r.dirty_chunks.clear()
        return moved

    def _resync_locked(self, key: str, r: Replica) -> None:
        """Throw away the replica's local divergence and re-pull the global
        truth (replica write lock held by the caller).

        Used when the replica's content can no longer be trusted to feed a
        delta push: a fenced-out push (the winning attempt's equivalent
        delta is — or will be — the global content; keeping ours would
        double-apply it on the next broadcast/pull) and a failed call's
        un-pushed dirty writes (:meth:`discard_unpushed`).  The full pull
        re-stamps the delta base, clears the dirty record and drops both
        error-feedback residuals; a bound device replica is marked stale so
        its next use re-syncs from the host buffer."""
        if _SAN is not None:
            _SAN.assert_write_held(r.lock, "_resync_locked")
        size = self.global_tier.size(key)
        self._full_pull_locked(key, r, size, refresh_base=r.base is not None)
        r.full = True
        r.present_chunks = set(range(self.global_tier.n_chunks(key)))
        r.dirty_chunks.clear()
        r.residual = None
        d = r.device
        if d is not None:
            d.synced_version = -1
            d.device_dirty = False
            d.residual = None
            d.base = None

    def discard_unpushed(self, key: str) -> bool:
        """Drop a replica's un-pushed local writes (failed/cancelled call).

        The container path already discards its whole private tier on a
        failed settle; warm faaslet-mode replicas are shared, so a failed
        call's half-written dirty chunks would otherwise survive and be
        served by the next pull.  Granularity is the replica: a concurrent
        call's not-yet-pushed writes to the *same* key are discarded too
        (both re-pull; pushed state is never touched).  Returns True when
        there was anything to discard."""
        with self._mutex:
            r = self._replicas.get(key)
        if r is None:
            return False
        r.lock.acquire_write()
        try:
            if not r.dirty_chunks:
                return False
            self._resync_locked(key, r)
            return True
        finally:
            r.lock.release_write()

    @staticmethod
    def _refresh_base(r: Replica) -> None:
        """Re-stamp the delta base from the buffer (replica write lock held
        by the caller)."""
        if r.base is None or r.base.size != r.buf.size:
            # faasmlint: disable=tier-copy -- replica-internal base snapshot
            r.base = r.buf.copy()
        else:
            r.base[:] = r.buf                # reuse the allocation

    @staticmethod
    def _rebase_pushed(r: Replica, pushed: np.ndarray) -> None:
        """Re-stamp the delta base from the f32 content a push actually read
        (replica write lock held).  Unlike :meth:`_refresh_base` this never
        re-reads the live buffer: co-located faaslets write it HOGWILD with
        no lock, so a base taken from a second read silently absorbs any add
        that landed between the push's read and the refresh — a lost update
        the delta stream can never repair.  Rebasing from the pushed
        snapshot keeps such an add pending for the next delta instead."""
        if r.base is None or r.base.size != r.buf.size:
            # faasmlint: disable=tier-copy -- replica-internal base snapshot
            r.base = r.buf.copy()
        bv = r.base.view(np.float32)
        n = min(bv.size, pushed.size)
        bv[:n] = pushed[:n]

    @staticmethod
    def _base_f32(r: Replica, dt: np.dtype, n: int) -> np.ndarray:
        """The delta base as f32 of exactly ``n`` elements (replica lock
        held).  A base snapshotted before the buffer grew is zero-extended —
        the new tail was never pushed, so its base *is* zero; silently using
        an all-zeros base instead would re-push the whole value.

        The common f32 full-size case returns a **view of r.base** (no
        value-sized alloc+copy per push): callers must force any kernel
        dispatched on it before mutating the base."""
        if (r.base is not None and dt == np.float32
                and r.base.size >= n * 4):
            return r.base.view(np.float32)[:n]
        out = np.zeros(n, np.float32)
        if r.base is not None:
            bv = r.base.view(dt)[:n]
            out[:bv.size] = bv.astype(np.float32, copy=False)
        return out

    def snapshot_base(self, key: str, *, force: bool = True) -> None:
        """Record the replica contents as the base for a future delta push.

        Takes the replica write lock: the base is mutated in place (reusing
        the allocation), and a concurrent ``push_delta`` holds the same lock
        — exclusion keeps it from observing a torn base.

        ``force=False`` arms tracking only when no current-sized base exists
        yet.  An existing base is already maintained by every push and pull
        (rebase-from-pushed-content, frame applies, full-pull re-stamps), so
        re-stamping it from the live buffer would silently absorb a
        co-located faaslet's not-yet-pushed HOGWILD writes into the base —
        a lost update.  ``pull_state(track_delta=True)`` on a warm shared
        replica uses this arm-only mode."""
        r = self._replicas[key]
        r.lock.acquire_write()
        try:
            if force or r.base is None or r.base.size != r.buf.size:
                self._refresh_base(r)
        finally:
            r.lock.release_write()

    def push_delta(self, key: str, dtype=np.float32, *, wire: str = "exact",
                   backend: Optional[str] = None,
                   fence: Optional[tuple] = None) -> int:
        """Accumulating push: global += (local − base), then refresh base.

        The cross-host-safe HOGWILD push: concurrent pushes from different
        hosts compose instead of overwriting.  Runs under the key's global
        write lock.  Returns bytes moved.

        ``wire`` selects the codec: ``"int8"`` runs the fused
        ``kernels/state_push`` quantise kernel on the pusher — from the
        device arrays when a fresh :class:`DeviceReplica` is bound, so
        device-resident values never round-trip through host buffers — and
        ships the int8+scales frame (~¼ of the f32 bytes) with per-replica
        error feedback; ``"exact"`` (default) ships the f32 delta frame (f32
        values) or accumulates in place (other dtypes).  ``"auto"``/``None``
        delegates to the key's :class:`WirePolicy`.  Float values smaller
        than ``INT8_WIRE_MIN_BYTES`` (and non-float dtypes) always take the
        exact path.

        Applied f32 frames are recorded in the key's retained delta window
        (feeding warm-replica delta pulls) and fanned out to subscribed
        peer replicas once the global lock is released.

        Locking: both wires take the replica write lock first (same-replica
        pushes are atomic — read, encode, base refresh) and the key's
        global write lock second.  The encode — the expensive kernel
        dispatch — runs *before* the global lock is taken, so concurrent
        pushers of the same key from different hosts pipeline their encodes
        and only the cheap wire apply serialises.  Broadcast fan-out runs
        with no locks held.

        ``fence`` is an attempt-fence token ``(call_id, epoch, seq)`` (see
        ``GlobalTier.fence_admit``): a push from a superseded or duplicate
        attempt performs no global effect, resynchronises the replica from
        the global truth, and returns 0."""
        faults.point("host-crash-pre-push", key=key, host=self.host_id)
        r = self._replicas[key]
        gt = self.global_tier
        dt = np.dtype(dtype)
        auto = wire in (None, "auto")
        if auto:
            wire = self.wire_policy(key).select(r.buf.size, dt)
        if wire not in WIRES:
            raise ValueError(f"wire {wire!r} not in {WIRES + ('auto',)}")
        exact_framed = (dt == np.float32 and gt.delta_window > 0
                        and gt.wire_interest(key, exclude=self.origin_id))
        if (wire != "exact" and dt.kind == "f"
                and r.buf.size >= INT8_WIRE_MIN_BYTES):
            try:
                moved = self._push_delta_quant(key, r, dt, backend, wire=wire,
                                               auto=auto, fence=fence)
            except CodecFallback:
                # the quantised encode failed before any tier effect: the
                # delta must not be lost — re-push it on the exact wire with
                # the same fence token
                self.codec_fallbacks += 1
                if exact_framed:
                    moved = self._push_delta_exact_f32(key, r, backend,
                                                       fence=fence)
                else:
                    moved = self._push_delta_inplace(key, r, dt, fence=fence)
        elif exact_framed:
            moved = self._push_delta_exact_f32(key, r, backend, auto=auto,
                                               fence=fence)
        else:
            moved = self._push_delta_inplace(key, r, dt, fence=fence)
        faults.point("host-crash-post-push", key=key, host=self.host_id)
        return moved

    def _push_delta_inplace(self, key: str, r: Replica, dt: np.dtype, *,
                            fence: Optional[tuple] = None) -> int:
        """The zero-copy fast path: non-f32 dtypes — and f32 nobody else
        consumes frames of (no warm puller, no subscriber) or with the
        window disabled.  No frame is materialised, nothing retained; the
        tier invalidates the key's window.  The first consumer to appear
        full-pulls once and declares interest, flipping later pushes onto
        the frame path."""
        gt = self.global_tier
        tel = _TEL
        t0 = tel.now() if tel is not None else 0.0
        r.lock.acquire_write()
        try:
            local = r.buf.view(dt)
            base = (r.base.view(dt)[:local.size]
                    if r.base is not None else None)
            rebased = base is not None and base.size == local.size
            lock = gt.lock(key)
            lock.acquire_write()
            try:
                res = gt.add_inplace(
                    key, local, base, host=self.host_id,
                    return_version=True, rebase=rebased, fence=fence)
            finally:
                lock.release_write()
            if res is None:              # fenced out: superseded/duplicate
                self._resync_locked(key, r)
                if tel is not None:
                    tel.record("wire.push", "wire", t0, tel.now(), key=key,
                               wire="inplace", nbytes=0, fenced=True,
                               origin=self.origin_id)
                return 0
            moved, prev, new = res
            if not rebased:
                # first tracked push (no base yet): snapshot one.  Later
                # pushes rebase inside add_inplace from the read itself.
                self._refresh_base(r)
            r.dirty_chunks.clear()
            # the pusher's buffer is the post-push content: keep its base
            # version current (same rule as _after_push) so its own warm
            # pulls stay 0-byte no-ops instead of full re-pulls
            if r.global_version == prev:
                r.global_version = new
            if tel is not None:
                tel.record("wire.push", "wire", t0, tel.now(), key=key,
                           wire="inplace", nbytes=moved, encode_ns=0,
                           prev_version=prev, version=new,
                           origin=self.origin_id)
            return moved
        finally:
            r.lock.release_write()

    def _push_delta_exact_f32(self, key: str, r: Replica,
                              backend: Optional[str], *,
                              auto: bool = False,
                              fence: Optional[tuple] = None) -> int:
        """Exact f32 push as a wire frame: the delta is materialised once,
        accumulated in place in the global buffer, retained in the key's
        delta window and broadcast to subscribed peers.  Any error-feedback
        residual is flushed into the frame — the exact wire pays
        quantisation debt in full.

        Like the int8 path, a fresh :class:`DeviceReplica` is pushed from
        its device arrays (device-side updates must not be silently dropped
        when the policy routes a device-resident key onto the exact wire);
        the exact wire ships f32 either way, so the D2H of the delta is the
        wire payload itself."""
        gt = self.global_tier
        codec = get_codec("exact")
        tel = _TEL
        cost = _wire_mod._COST
        timed = tel is not None or cost is not None
        t0 = tel.now() if tel is not None else 0.0
        enc0 = _clock.now_ns() if timed else 0
        r.lock.acquire_write()
        try:
            snap = None
            d = r.device
            if d is not None and d.fresh(r):
                local = np.asarray(d.value, dtype=np.float32).reshape(-1)
                if d.base is not None:
                    base = np.asarray(d.base,
                                      dtype=np.float32).reshape(-1)
                else:
                    base = self._base_f32(r, np.dtype(np.float32),
                                          local.size)
                eff = local
                if d.residual is not None:
                    eff = local + np.asarray(d.residual, np.float32)
                    d.residual = None        # exact wire pays the debt
                frame, _ = codec.encode(eff, base, backend=backend)
                d.base = d.value             # device snapshot: a rebind
                host_synced = not d.device_dirty
            else:
                local = r.buf.view(np.float32)
                base = self._base_f32(r, np.dtype(np.float32), local.size)
                eff = local
                flushed = None
                if r.residual is not None and r.residual.size == local.size:
                    flushed = r.residual
                    eff = local + r.residual
                    r.residual = None
                frame, _ = codec.encode(eff, base, backend=backend)
                # the buffer content the encode actually read, reconstructed
                # without a second read: base + payload == eff-as-read
                snap = base + frame.payload
                if flushed is not None:
                    snap -= flushed
                host_synced = True
            if host_synced:
                if snap is not None:
                    self._rebase_pushed(r, snap)
                else:
                    self._refresh_base(r)
                r.dirty_chunks.clear()
        finally:
            r.lock.release_write()
        enc_ns = (_clock.now_ns() - enc0) if timed else 0
        lock = gt.lock(key)
        lock.acquire_write()
        try:
            moved = gt.apply_wire(key, frame, host=self.host_id,
                                  origin=self.origin_id, fence=fence)
        finally:
            lock.release_write()
        if moved is None:                # fenced out: superseded/duplicate
            r.lock.acquire_write()
            try:
                self._resync_locked(key, r)
            finally:
                r.lock.release_write()
            if tel is not None:
                tel.record("wire.push", "wire", t0, tel.now(), key=key,
                           wire=frame.wire, nbytes=0, fenced=True,
                           encode_ns=enc_ns, origin=self.origin_id)
            return 0
        self._after_push(key, r, frame)
        if cost is not None:
            cost.observe(frame.wire, frame.numel * 4, enc_ns,
                         wall_ns=_clock.now_ns() - enc0)
        if tel is not None:
            tel.record("wire.push", "wire", t0, tel.now(), key=key,
                       wire=frame.wire, nbytes=frame.nbytes,
                       numel=frame.numel, encode_ns=enc_ns,
                       prev_version=frame.prev_version,
                       version=frame.version, origin=self.origin_id)
        if auto:
            # adaptive feedback only when the policy made the choice: forced
            # pushes skip the two extra full-array metric passes
            delta = frame.payload
            self.wire_policy(key).observe(
                delta_absmax=float(np.abs(delta).max()) if delta.size else 0.0,
                density=float(np.count_nonzero(delta)) / max(delta.size, 1),
                wire=frame.wire)
        return moved

    def _push_delta_quant(self, key: str, r: Replica, dt: np.dtype,
                          backend: Optional[str], *, wire: str = "int8",
                          auto: bool = False,
                          fence: Optional[tuple] = None) -> int:
        """Quantised delta push (int8 / int4 / fp8): encode under the
        replica write lock, apply under the key's global write lock,
        broadcast with no locks held.

        Device-native when the replica has a fresh device copy: quantise
        runs on ``DeviceReplica.value``/``base`` and only the wire frame
        comes back to the host.  Otherwise the host replica buffer feeds
        the host-native fused codec directly (no JAX dispatch)."""
        gt = self.global_tier
        codec = get_codec(wire)
        tel = _TEL
        cost = _wire_mod._COST
        timed = tel is not None or cost is not None
        t0 = tel.now() if tel is not None else 0.0
        enc0 = _clock.now_ns() if timed else 0
        r.lock.acquire_write()
        try:
            snap = None
            d = r.device
            if d is not None and d.fresh(r):
                import jax.numpy as jnp
                local = d.value
                if d.base is not None:
                    base = d.base.astype(jnp.float32)
                else:
                    # device copy synced without track_delta: diff against
                    # the host-side snapshot (what the exact wire would use),
                    # NOT against zeros — zeros would re-push the full value.
                    # copy=True: async kernel execution must not read a host
                    # base buffer this push later mutates
                    base = jnp.array(
                        self._base_f32(r, dt, int(local.size)), copy=True)
                eff = local.astype(jnp.float32)
                if d.residual is not None:
                    eff = eff + d.residual
                # codec.encode materialises the frame (np.asarray blocks on
                # the dispatched kernels), so nothing in flight still reads
                # r.base when _refresh_base mutates it below
                try:
                    frame, residual = codec.encode(eff, base, backend=backend)
                except Exception as e:
                    raise CodecFallback(e) from e
                d.residual = residual
                d.base = local               # device snapshot: a rebind
                # d.value mirrors the host buffer only when no device-side
                # writes are pending; then this push covered the host
                # content too — refresh the host base (or a later host push
                # re-applies this delta) and clear the dirty record.  With
                # pending device writes the host chunks stay dirty: their
                # content was NOT in this push.
                host_synced = not d.device_dirty
            else:
                local = r.buf.view(dt)
                base = self._base_f32(r, dt, local.size)
                if r.residual is None or r.residual.size != local.size:
                    r.residual = np.zeros(local.size, np.float32)
                snap = local.astype(np.float32)  # one coherent buffer read
                eff = snap + r.residual
                try:
                    frame, residual = codec.encode(eff, base, backend=backend)
                except Exception as e:
                    raise CodecFallback(e) from e
                # owned writable copy: np.asarray of a jax array is read-only
                # and would alias the device buffer
                r.residual = np.array(residual, dtype=np.float32)
                host_synced = True
            frame.dtype = dt
            if host_synced:
                if snap is not None and dt == np.float32:
                    self._rebase_pushed(r, snap)
                else:
                    self._refresh_base(r)
                r.dirty_chunks.clear()
        finally:
            r.lock.release_write()
        enc_ns = (_clock.now_ns() - enc0) if timed else 0
        lock = gt.lock(key)
        lock.acquire_write()
        try:
            moved = gt.apply_wire(key, frame, host=self.host_id,
                                  origin=self.origin_id, fence=fence)
        finally:
            lock.release_write()
        if moved is None:                # fenced out: superseded/duplicate
            r.lock.acquire_write()
            try:
                self._resync_locked(key, r)
            finally:
                r.lock.release_write()
            if tel is not None:
                tel.record("wire.push", "wire", t0, tel.now(), key=key,
                           wire=frame.wire, nbytes=0, fenced=True,
                           encode_ns=enc_ns, origin=self.origin_id)
            return 0
        self._after_push(key, r, frame)
        if cost is not None:
            cost.observe(frame.wire, frame.numel * 4, enc_ns,
                         wall_ns=_clock.now_ns() - enc0)
        if tel is not None:
            tel.record("wire.push", "wire", t0, tel.now(), key=key,
                       wire=frame.wire, nbytes=frame.nbytes,
                       numel=frame.numel, encode_ns=enc_ns,
                       prev_version=frame.prev_version,
                       version=frame.version, origin=self.origin_id)
        if auto:
            # adaptive feedback (policy-chosen pushes only): what the
            # quantisation dropped vs what it carried.  Carried mass is
            # derived from the wire tuple itself (per-row mean|q|·scale),
            # not a second full f32 decode of the frame.
            q, sc = frame.codes()
            qf = np.abs(q.astype(np.float32))
            carried = float((qf.mean(axis=1) * sc[:, 0]).mean()) if q.size \
                else 0.0
            self.wire_policy(key).observe(
                delta_absmax=(float(sc.max()) * _WIRE_QMAX[frame.wire]
                              if sc is not None and sc.size else 0.0),
                density=float(np.count_nonzero(qf)) / max(q.size, 1),
                residual_ratio=_mean_abs(residual) / (carried + 1e-12),
                wire=frame.wire)
        return moved

    def _after_push(self, key: str, r: Replica, frame: WireFrame) -> None:
        """Post-apply bookkeeping: advance the replica's global base version
        when the push extended exactly the version it last synced at (any
        other transition means peer pushes landed that this replica hasn't
        seen — its version stays put and the next pull delta-refreshes),
        then fan the stamped frame out to subscribed peers."""
        r.lock.acquire_write()
        try:
            if r.global_version == frame.prev_version:
                r.global_version = frame.version
        finally:
            r.lock.release_write()
        self.global_tier.broadcast(key, frame, exclude=self.origin_id)

    def mark_dirty(self, key: str, offset: int, length: int) -> None:
        r = self._replicas[key]
        cs = self.global_tier.chunk_size
        for idx in range(offset // cs, (offset + max(length, 1) - 1) // cs + 1):
            r.dirty_chunks.add(idx)
        r.version += 1
