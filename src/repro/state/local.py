"""Local state tier: zero-copy shared replicas on one host (Faasm §4.2).

Replicas live in *shared memory regions* (§3.3): one numpy buffer per state
value, and every Faaslet on the host maps a **view** of the same buffer into
its address space — reads and writes are genuinely shared, no serialisation.
Chunk presence is tracked so a pull only transfers missing chunks.

Tier synchronisation is single-copy each way: pulls ``readinto`` the replica
buffer straight from global storage and pushes ``write_from`` it straight
back (no get→bytes→frombuffer→assign round trip), and ``push_delta`` applies
``global += local − base`` arithmetically in the global buffer — the
HOGWILD serialisation point holds the key's global write lock for one
in-place pass instead of four full-value copies.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Set

import numpy as np

from repro.state.kv import GlobalTier, RWLock


@dataclass
class Replica:
    buf: np.ndarray                      # uint8, the shared region backing
    lock: RWLock = field(default_factory=RWLock)
    present_chunks: Set[int] = field(default_factory=set)
    dirty_chunks: Set[int] = field(default_factory=set)
    full: bool = False                   # whole value present
    base: Optional[np.ndarray] = None    # snapshot for delta-accumulating push


class LocalTier:
    """Per-host replica store.  All Faaslets of the host share these buffers."""

    def __init__(self, host_id: str, global_tier: GlobalTier):
        self.host_id = host_id
        self.global_tier = global_tier
        self._replicas: Dict[str, Replica] = {}
        self._mutex = threading.RLock()

    # -- replica lifecycle ------------------------------------------------------

    def replica(self, key: str, size: Optional[int] = None) -> Replica:
        """Get or create the shared replica buffer for ``key`` (no transfer)."""
        with self._mutex:
            r = self._replicas.get(key)
            if r is None:
                if size is None:
                    size = self.global_tier.size(key)
                r = Replica(buf=np.zeros(size, np.uint8))
                self._replicas[key] = r
            elif size is not None and size > r.buf.size:
                grown = np.zeros(size, np.uint8)
                grown[:r.buf.size] = r.buf
                r.buf = grown
            return r

    def has(self, key: str) -> bool:
        with self._mutex:
            return key in self._replicas

    def drop(self, key: Optional[str] = None) -> None:
        """Evict replicas (host failure / memory pressure)."""
        with self._mutex:
            if key is None:
                self._replicas.clear()
            else:
                self._replicas.pop(key, None)

    def memory_bytes(self) -> int:
        with self._mutex:
            return sum(r.buf.size for r in self._replicas.values())

    def keys(self):
        with self._mutex:
            return list(self._replicas.keys())

    # -- pull / push (tier synchronisation) ----------------------------------------

    def pull(self, key: str) -> int:
        """Ensure the full value is replicated locally.  Returns bytes moved
        (0 on a local hit) — symmetric with :meth:`push`."""
        size = self.global_tier.size(key)
        r = self.replica(key, size)
        moved = 0
        r.lock.acquire_write()
        try:
            if not r.full:
                if size:
                    moved = self.global_tier.readinto(key, 0, r.buf[:size],
                                                      host=self.host_id,
                                                      clamp=True)
                r.full = True
                r.present_chunks = set(range(self.global_tier.n_chunks(key)))
        finally:
            r.lock.release_write()
        return moved

    def pull_chunk(self, key: str, chunk_idx: int) -> int:
        """Replicate a single state chunk (Fig. 4: partial values).
        Returns bytes moved (0 on a local hit)."""
        size = self.global_tier.size(key)
        r = self.replica(key, size)
        moved = 0
        r.lock.acquire_write()
        try:
            if chunk_idx not in r.present_chunks:
                start, length = self.global_tier.chunk_bounds(key, chunk_idx)
                if length > 0:
                    moved = self.global_tier.readinto(
                        key, start, r.buf[start:start + length],
                        host=self.host_id, clamp=True)
                r.present_chunks.add(chunk_idx)
                if len(r.present_chunks) == self.global_tier.n_chunks(key):
                    r.full = True
        finally:
            r.lock.release_write()
        return moved

    def pull_range(self, key: str, offset: int, length: int) -> int:
        """Pull exactly the chunks covering [offset, offset+length).
        Returns bytes moved."""
        cs = self.global_tier.chunk_size
        moved = 0
        for idx in range(offset // cs, (offset + max(length, 1) - 1) // cs + 1):
            moved += self.pull_chunk(key, idx)
        return moved

    def push(self, key: str) -> int:
        """Write the full local replica to the global tier (single memcpy
        from the replica buffer).  Returns bytes."""
        with self._mutex:
            r = self._replicas[key]
        r.lock.acquire_read()
        try:
            moved = self.global_tier.write_from(key, 0, r.buf,
                                                host=self.host_id,
                                                truncate=True)
        finally:
            r.lock.release_read()
        r.dirty_chunks.clear()
        return moved

    def push_dirty(self, key: str) -> int:
        """Push only chunks marked dirty (partial push).  Returns bytes."""
        with self._mutex:
            r = self._replicas[key]
        moved = 0
        r.lock.acquire_read()
        try:
            dirty = sorted(r.dirty_chunks)
            cs = self.global_tier.chunk_size
            for idx in dirty:
                start = idx * cs
                end = min(start + cs, r.buf.size)
                if end > start:
                    moved += self.global_tier.write_from(
                        key, start, r.buf[start:end], host=self.host_id)
        finally:
            r.lock.release_read()
        r.dirty_chunks.clear()
        return moved

    def snapshot_base(self, key: str) -> None:
        """Record the replica contents as the base for a future delta push.

        Takes the replica write lock: the base is mutated in place (reusing
        the allocation), and a concurrent ``push_delta`` reads it under the
        read lock — exclusion here keeps it from observing a torn base."""
        r = self._replicas[key]
        r.lock.acquire_write()
        try:
            if r.base is None or r.base.size != r.buf.size:
                r.base = r.buf.copy()
            else:
                r.base[:] = r.buf            # reuse the allocation
        finally:
            r.lock.release_write()

    def push_delta(self, key: str, dtype=np.float32) -> int:
        """Accumulating push: global += (local − base), then refresh base.

        The cross-host-safe HOGWILD push (the fused ``kernels/state_push``
        path on device): concurrent pushes from different hosts compose
        instead of overwriting.  Runs under the key's global write lock, and
        the accumulation happens *in place in the global buffer* — no
        full-value copy on this path.  Returns bytes moved."""
        r = self._replicas[key]
        gt = self.global_tier
        lock = gt.lock(key)
        lock.acquire_write()
        try:
            r.lock.acquire_read()
            try:
                local = r.buf.view(dtype)
                base = (r.base.view(dtype)[:local.size]
                        if r.base is not None else None)
                moved = gt.add_inplace(key, local, base, host=self.host_id)
            finally:
                r.lock.release_read()
            self.snapshot_base(key)
            r.dirty_chunks.clear()
            return moved
        finally:
            lock.release_write()

    def mark_dirty(self, key: str, offset: int, length: int) -> None:
        r = self._replicas[key]
        cs = self.global_tier.chunk_size
        for idx in range(offset // cs, (offset + max(length, 1) - 1) // cs + 1):
            r.dirty_chunks.add(idx)
