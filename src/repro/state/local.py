"""Local state tier: zero-copy shared replicas on one host (Faasm §4.2).

Replicas live in *shared memory regions* (§3.3): one numpy buffer per state
value, and every Faaslet on the host maps a **view** of the same buffer into
its address space — reads and writes are genuinely shared, no serialisation.
Chunk presence is tracked so a pull only transfers missing chunks.

Tier synchronisation is single-copy each way: pulls ``readinto`` the replica
buffer straight from global storage and pushes ``write_from`` it straight
back (no get→bytes→frombuffer→assign round trip), and ``push_delta`` applies
``global += local − base`` arithmetically in the global buffer — the
HOGWILD serialisation point holds the key's global write lock for one
in-place pass instead of four full-value copies.

Device-resident replica plane: a replica can additionally hold its value as
a **JAX device array** (:class:`DeviceReplica`) with explicit
``to_device``/``from_device`` sync.  Staleness is tracked against the
replica's write version — every host-side mutation (``mark_dirty``, pull)
bumps ``Replica.version``; the device copy records the version it was
synced at, so a stale device array is never silently pushed.

Quantised push wire: ``push_delta(..., wire="int8")`` runs the fused
``kernels/state_push`` quantise kernel on the pusher (device-native when a
fresh :class:`DeviceReplica` is bound — the value never round-trips through
host buffers), ships the ``(q, scales, numel)`` wire tuple, and the global
tier applies it via :meth:`GlobalTier.apply_quantized` — an f32 push moves
~¼ of the exact-path bytes.  Per-replica **error feedback** carries the
quantisation residual into the next push so repeated int8 pushes don't
accumulate bias; sub-threshold values fall back to the exact in-place path.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Set

import numpy as np

from repro.state.kv import GlobalTier, RWLock

# Values smaller than this push exact even when wire="int8" is requested:
# the per-row scales + dispatch overhead eat the 4x payload saving on tiny
# values, and the exact in-place path moves zero value bytes anyway.
INT8_WIRE_MIN_BYTES = 4096


def _encode_delta(eff, base, backend):
    """Quantise ``eff − base`` to the int8 wire and compute the
    error-feedback residual (what the quantisation dropped, carried into the
    next push).  Array-namespace agnostic: numpy or jax arrays in; the wire
    tuple and residual come back as jax arrays — the single home of the
    feedback math for both the host and device push branches."""
    from repro.kernels.state_push import ops

    q, s, n = ops.quantize_delta(eff, base, backend=backend)
    deq = ops.dequantize(q, s, n)
    residual = (eff - base).reshape(-1)[:n] - deq
    return q, s, n, residual


@dataclass
class DeviceReplica:
    """Optional JAX device residency for a replica (one value, one device).

    ``value`` is the flat typed device array mirroring the replica buffer;
    ``base`` the device-side snapshot a delta push diffs against (refreshing
    it after a push is a rebind — device arrays are immutable, no copy);
    ``residual`` the error-feedback carry for int8 wire pushes.
    ``synced_version`` is the :attr:`Replica.version` the device copy was
    taken at; ``device_dirty`` marks device-side writes (``update_device``)
    not yet propagated back to the shared host buffer."""

    dtype: np.dtype = np.dtype(np.float32)
    value: Any = None                    # jnp.ndarray, flat
    base: Any = None                     # jnp.ndarray snapshot for delta push
    residual: Any = None                 # jnp.ndarray f32 error-feedback carry
    synced_version: int = -1
    device_dirty: bool = False

    def fresh(self, replica: "Replica") -> bool:
        """True when the device arrays are safe to push from: either in sync
        with the host buffer or strictly ahead of it (device-side writes)."""
        return self.value is not None and (
            self.device_dirty or self.synced_version == replica.version)


@dataclass
class Replica:
    buf: np.ndarray                      # uint8, the shared region backing
    lock: RWLock = field(default_factory=RWLock)
    present_chunks: Set[int] = field(default_factory=set)
    dirty_chunks: Set[int] = field(default_factory=set)
    full: bool = False                   # whole value present
    base: Optional[np.ndarray] = None    # snapshot for delta-accumulating push
    version: int = 0                     # bumped on every host-side mutation
    residual: Optional[np.ndarray] = None  # f32 error-feedback carry (int8 wire)
    device: Optional[DeviceReplica] = None


class LocalTier:
    """Per-host replica store.  All Faaslets of the host share these buffers."""

    def __init__(self, host_id: str, global_tier: GlobalTier):
        self.host_id = host_id
        self.global_tier = global_tier
        self._replicas: Dict[str, Replica] = {}
        self._mutex = threading.RLock()

    # -- replica lifecycle ------------------------------------------------------

    def replica(self, key: str, size: Optional[int] = None) -> Replica:
        """Get or create the shared replica buffer for ``key`` (no transfer)."""
        with self._mutex:
            r = self._replicas.get(key)
            if r is None:
                if size is None:
                    size = self.global_tier.size(key)
                r = Replica(buf=np.zeros(size, np.uint8))
                self._replicas[key] = r
            elif size is not None and size > r.buf.size:
                grown = np.zeros(size, np.uint8)
                grown[:r.buf.size] = r.buf
                r.buf = grown
                r.version += 1
            return r

    def has(self, key: str) -> bool:
        with self._mutex:
            return key in self._replicas

    def drop(self, key: Optional[str] = None) -> None:
        """Evict replicas (host failure / memory pressure)."""
        with self._mutex:
            if key is None:
                self._replicas.clear()
            else:
                self._replicas.pop(key, None)

    def memory_bytes(self) -> int:
        with self._mutex:
            return sum(r.buf.size for r in self._replicas.values())

    def keys(self):
        with self._mutex:
            return list(self._replicas.keys())

    # -- device residency (explicit sync, version-checked staleness) -----------

    def to_device(self, key: str, dtype=np.float32, *,
                  track_delta: bool = False):
        """Materialise (or refresh) the replica as a JAX device array.

        Returns the device value.  A no-op when the device copy is already
        at the replica's current write version.  With ``track_delta`` the
        device-side base snapshot is (re)taken at this sync point, arming a
        subsequent device-native ``push_delta``.  A host-side error-feedback
        residual moves to the device with the value (ownership transfer —
        the debt must not be applied twice).  While device-side writes are
        pending (``update_device`` without a push or ``from_device``),
        ``track_delta`` is a no-op: re-arming the base to the unsynced value
        would silently drop that delta from every future push."""
        import jax.numpy as jnp

        r = self._replicas[key]
        dt = np.dtype(dtype)
        # write lock: this mutates r.device and the DeviceReplica fields, and
        # concurrent to_device calls must not race on creating/arming them
        r.lock.acquire_write()
        try:
            d = r.device
            if d is None or d.dtype != dt:
                d = DeviceReplica(dtype=dt)
                r.device = d
            if not d.device_dirty and (d.value is None
                                       or d.synced_version != r.version):
                # copy=True: jnp.asarray may alias host memory on the CPU
                # backend, but the device replica must be a *snapshot* at
                # this version — later host writes must not leak through
                d.value = jnp.array(r.buf.view(dt), copy=True)
                if r.residual is not None and \
                        r.residual.size == int(d.value.size):
                    d.residual = jnp.array(r.residual, copy=True)
                    r.residual = None            # device owns the debt now
                d.synced_version = r.version
            if track_delta and not d.device_dirty:
                d.base = d.value
            return d.value
        finally:
            r.lock.release_write()

    def update_device(self, key: str, value) -> None:
        """Install a device-computed value as the replica's device copy.

        The device copy is now *ahead* of the shared host buffer; call
        :meth:`from_device` to propagate it (or ``push_delta`` to ship the
        delta straight to the global tier without a host round-trip)."""
        r = self._replicas[key]
        r.lock.acquire_write()
        try:
            d = r.device
            if d is None:
                raise RuntimeError(f"no device replica for {key!r}; "
                                   "call to_device first")
            if int(np.prod(np.shape(value))) * d.dtype.itemsize > r.buf.size:
                raise ValueError(f"device value larger than replica {key!r}")
            d.value = value
            d.device_dirty = True
        finally:
            r.lock.release_write()

    def from_device(self, key: str) -> int:
        """Copy the device value back into the shared host buffer (one D2H
        memcpy), bump the write version, and mark the range dirty.  The
        device-side delta base and error-feedback residual come back with
        it, so a later *host-path* push diffs against the content the global
        tier last saw instead of re-pushing device-era deltas.  Returns
        bytes synced."""
        r = self._replicas[key]
        r.lock.acquire_write()
        try:
            d = r.device
            if d is None or d.value is None:
                raise RuntimeError(f"no device value for {key!r}")
            # snapshot d.value under the lock: a concurrent update_device
            # must not land between the read and the device_dirty clear
            host = np.asarray(d.value).reshape(-1).view(np.uint8)
            n = min(host.size, r.buf.size)
            r.buf[:n] = host[:n]
            if d.base is not None:
                hb = np.asarray(d.base).reshape(-1).view(np.uint8)
                if r.base is None or r.base.size != r.buf.size:
                    r.base = np.zeros(r.buf.size, np.uint8)
                m = min(hb.size, r.base.size)
                r.base[:m] = hb[:m]
            if d.residual is not None:
                r.residual = np.array(d.residual, dtype=np.float32)
                d.residual = None                # host owns the debt again
            cs = self.global_tier.chunk_size
            if n:
                r.dirty_chunks.update(range(0, (n - 1) // cs + 1))
            r.version += 1
            d.synced_version = r.version
            d.device_dirty = False
        finally:
            r.lock.release_write()
        return n

    def device_replica(self, key: str) -> Optional[DeviceReplica]:
        r = self._replicas.get(key)
        return r.device if r is not None else None

    def device_stale(self, key: str) -> bool:
        """True when host-side writes postdate the last device sync (and the
        device holds no unsynced writes of its own)."""
        r = self._replicas[key]
        d = r.device
        if d is None or d.value is None:
            return True
        return not d.device_dirty and d.synced_version != r.version

    # -- pull / push (tier synchronisation) ----------------------------------------

    def pull(self, key: str) -> int:
        """Ensure the full value is replicated locally.  Returns bytes moved
        (0 on a local hit) — symmetric with :meth:`push`."""
        size = self.global_tier.size(key)
        r = self.replica(key, size)
        moved = 0
        r.lock.acquire_write()
        try:
            if not r.full:
                if size:
                    moved = self.global_tier.readinto(key, 0, r.buf[:size],
                                                      host=self.host_id,
                                                      clamp=True)
                r.full = True
                r.present_chunks = set(range(self.global_tier.n_chunks(key)))
                if moved:
                    r.version += 1
        finally:
            r.lock.release_write()
        return moved

    def pull_chunk(self, key: str, chunk_idx: int) -> int:
        """Replicate a single state chunk (Fig. 4: partial values).
        Returns bytes moved (0 on a local hit)."""
        size = self.global_tier.size(key)
        r = self.replica(key, size)
        moved = 0
        r.lock.acquire_write()
        try:
            if chunk_idx not in r.present_chunks:
                start, length = self.global_tier.chunk_bounds(key, chunk_idx)
                if length > 0:
                    moved = self.global_tier.readinto(
                        key, start, r.buf[start:start + length],
                        host=self.host_id, clamp=True)
                r.present_chunks.add(chunk_idx)
                if len(r.present_chunks) == self.global_tier.n_chunks(key):
                    r.full = True
                if moved:
                    r.version += 1
        finally:
            r.lock.release_write()
        return moved

    def pull_range(self, key: str, offset: int, length: int) -> int:
        """Pull exactly the chunks covering [offset, offset+length).
        Returns bytes moved."""
        cs = self.global_tier.chunk_size
        moved = 0
        for idx in range(offset // cs, (offset + max(length, 1) - 1) // cs + 1):
            moved += self.pull_chunk(key, idx)
        return moved

    def push(self, key: str) -> int:
        """Write the full local replica to the global tier (single memcpy
        from the replica buffer).  Returns bytes."""
        with self._mutex:
            r = self._replicas[key]
        r.lock.acquire_read()
        try:
            moved = self.global_tier.write_from(key, 0, r.buf,
                                                host=self.host_id,
                                                truncate=True)
        finally:
            r.lock.release_read()
        r.dirty_chunks.clear()
        return moved

    def push_dirty(self, key: str) -> int:
        """Push only chunks marked dirty (partial push).  Returns bytes."""
        with self._mutex:
            r = self._replicas[key]
        moved = 0
        r.lock.acquire_read()
        try:
            dirty = sorted(r.dirty_chunks)
            cs = self.global_tier.chunk_size
            for idx in dirty:
                start = idx * cs
                end = min(start + cs, r.buf.size)
                if end > start:
                    moved += self.global_tier.write_from(
                        key, start, r.buf[start:end], host=self.host_id)
        finally:
            r.lock.release_read()
        r.dirty_chunks.clear()
        return moved

    @staticmethod
    def _refresh_base(r: Replica) -> None:
        """Re-stamp the delta base from the buffer (replica write lock held
        by the caller)."""
        if r.base is None or r.base.size != r.buf.size:
            r.base = r.buf.copy()
        else:
            r.base[:] = r.buf                # reuse the allocation

    @staticmethod
    def _base_f32(r: Replica, dt: np.dtype, n: int) -> np.ndarray:
        """The delta base as f32 of exactly ``n`` elements (replica lock
        held).  A base snapshotted before the buffer grew is zero-extended —
        the new tail was never pushed, so its base *is* zero; silently using
        an all-zeros base instead would re-push the whole value.

        The common f32 full-size case returns a **view of r.base** (no
        value-sized alloc+copy per push): callers must force any kernel
        dispatched on it before mutating the base."""
        if (r.base is not None and dt == np.float32
                and r.base.size >= n * 4):
            return r.base.view(np.float32)[:n]
        out = np.zeros(n, np.float32)
        if r.base is not None:
            bv = r.base.view(dt)[:n]
            out[:bv.size] = bv.astype(np.float32, copy=False)
        return out

    def snapshot_base(self, key: str) -> None:
        """Record the replica contents as the base for a future delta push.

        Takes the replica write lock: the base is mutated in place (reusing
        the allocation), and a concurrent ``push_delta`` holds the same lock
        — exclusion keeps it from observing a torn base."""
        r = self._replicas[key]
        r.lock.acquire_write()
        try:
            self._refresh_base(r)
        finally:
            r.lock.release_write()

    def push_delta(self, key: str, dtype=np.float32, *, wire: str = "exact",
                   backend: Optional[str] = None) -> int:
        """Accumulating push: global += (local − base), then refresh base.

        The cross-host-safe HOGWILD push: concurrent pushes from different
        hosts compose instead of overwriting.  Runs under the key's global
        write lock.  Returns bytes moved.

        ``wire="exact"`` (default) accumulates *in place in the global
        buffer* — no full-value copy on this path.  ``wire="int8"`` runs the
        fused ``kernels/state_push`` quantise kernel on the pusher — from
        the device arrays when a fresh :class:`DeviceReplica` is bound, so
        device-resident values never round-trip through host buffers — and
        ships the int8+scales wire tuple (~¼ of the f32 bytes), applied
        globally via :meth:`GlobalTier.apply_quantized`.  Quantisation error
        is carried per replica as an error-feedback residual into the next
        push; float values smaller than ``INT8_WIRE_MIN_BYTES`` (and
        non-float dtypes) fall back to the exact path.

        Locking: both wires take the replica write lock first (same-replica
        pushes are atomic — read, encode/add, base refresh) and the key's
        global write lock second.  The int8 encode — the expensive kernel
        dispatch — runs *before* the global lock is taken, so concurrent
        pushers of the same key from different hosts pipeline their encodes
        and only the cheap wire apply serialises."""
        if wire not in ("exact", "int8"):
            raise ValueError(f"wire {wire!r} not in ('exact', 'int8')")
        r = self._replicas[key]
        gt = self.global_tier
        dt = np.dtype(dtype)
        if (wire == "int8" and dt.kind == "f"
                and r.buf.size >= INT8_WIRE_MIN_BYTES):
            return self._push_delta_int8(key, r, dt, backend)
        r.lock.acquire_write()
        try:
            local = r.buf.view(dt)
            base = (r.base.view(dt)[:local.size]
                    if r.base is not None else None)
            lock = gt.lock(key)
            lock.acquire_write()
            try:
                moved = gt.add_inplace(key, local, base, host=self.host_id)
            finally:
                lock.release_write()
            self._refresh_base(r)
            r.dirty_chunks.clear()
            return moved
        finally:
            r.lock.release_write()

    def _push_delta_int8(self, key: str, r: Replica, dt: np.dtype,
                         backend: Optional[str]) -> int:
        """Quantised delta push: encode under the replica write lock, apply
        under the key's global write lock.

        Device-native when the replica has a fresh device copy: quantise
        runs on ``DeviceReplica.value``/``base`` and only the wire tuple
        comes back to the host.  Otherwise the host replica buffer feeds the
        kernel directly."""
        gt = self.global_tier
        r.lock.acquire_write()
        try:
            d = r.device
            if d is not None and d.fresh(r):
                import jax.numpy as jnp
                local = d.value
                if d.base is not None:
                    base = d.base.astype(jnp.float32)
                else:
                    # device copy synced without track_delta: diff against
                    # the host-side snapshot (what the exact wire would use),
                    # NOT against zeros — zeros would re-push the full value.
                    # copy=True: async kernel execution must not read a host
                    # base buffer this push later mutates
                    base = jnp.array(
                        self._base_f32(r, dt, int(local.size)), copy=True)
                eff = local.astype(jnp.float32)
                if d.residual is not None:
                    eff = eff + d.residual
                q, s, n, residual = _encode_delta(eff, base, backend)
                d.residual = residual
                d.base = local               # device snapshot: a rebind
                # d.value mirrors the host buffer only when no device-side
                # writes are pending; then this push covered the host
                # content too — refresh the host base (or a later host push
                # re-applies this delta) and clear the dirty record.  With
                # pending device writes the host chunks stay dirty: their
                # content was NOT in this push.
                host_synced = not d.device_dirty
            else:
                local = r.buf.view(dt)
                base = self._base_f32(r, dt, local.size)
                if r.residual is None or r.residual.size != local.size:
                    r.residual = np.zeros(local.size, np.float32)
                eff = local.astype(np.float32) + r.residual
                q, s, n, residual = _encode_delta(eff, base, backend)
                # owned writable copy: np.asarray of a jax array is read-only
                # and would alias the device buffer
                r.residual = np.array(residual, dtype=np.float32)
                host_synced = True
            # np.asarray blocks on the dispatched kernels, so nothing
            # in flight still reads r.base when _refresh_base mutates it
            q, s = np.asarray(q), np.asarray(s)
            if host_synced:
                self._refresh_base(r)
                r.dirty_chunks.clear()
        finally:
            r.lock.release_write()
        lock = gt.lock(key)
        lock.acquire_write()
        try:
            return gt.apply_quantized(key, q, s, n, dtype=dt,
                                      host=self.host_id)
        finally:
            lock.release_write()

    def mark_dirty(self, key: str, offset: int, length: int) -> None:
        r = self._replicas[key]
        cs = self.global_tier.chunk_size
        for idx in range(offset // cs, (offset + max(length, 1) - 1) // cs + 1):
            r.dirty_chunks.add(idx)
        r.version += 1
