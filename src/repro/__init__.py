"""repro: FAASM-on-TPU — a stateful serverless runtime for JAX training/serving.

Reproduction of "Faasm: Lightweight Isolation for Efficient Stateful
Serverless Computing" (Shillaker & Pietzuch, 2020), adapted to TPU pods:
Faaslet execution contexts, two-tier state, Proto-Faaslet snapshots and an
Omega-style scheduler orchestrating pjit-distributed JAX train/serve steps.
"""

__version__ = "0.1.0"
