"""Proto-Faaslets: ahead-of-time snapshots restored in ~µs (Faasm §5.2).

Two cold-start costs exist on a TPU serving/training host, both attacked here:

  1. **Execution state** — the function's initialised linear memory plus any
     host objects its init code built (e.g. weights already laid out).  A
     ``ProtoFaaslet`` captures these once; ``restore()`` stamps out a fresh
     Faaslet from the snapshot.  Snapshots are plain bytes: OS-independent and
     restorable on any host in the cluster (cross-host restore).
  2. **XLA compilation** — seconds-to-minutes per (function, arch, shape,
     mesh).  The ``ExecutableCache`` is the Proto-Faaslet of the compiled
     artifact: the first lowering pays the compile; every Faaslet spawned
     afterwards binds the cached executable.

After every call the runtime *resets* the Faaslet from its Proto-Faaslet
(§5.2 multi-tenant reset): no information from the previous call survives in
private memory.

Restore cost is O(1), not O(arena): the snapshot is decoded once per process
into a shared read-only :class:`~repro.core.faaslet.ArenaBase` that every
restore maps copy-on-write (``Faaslet.bind_base``), and the pickled
init-code products are decoded once into a cached template instead of paying
``pickle.loads`` per restore.  The template is shared read-only across all
restores on the process — the same discipline as the shared state tier
(§3.3); functions must not mutate it.  The pre-CoW full-copy path survives
as :meth:`ProtoFaaslet.restore_copy` (the benchmark baseline).
"""
from __future__ import annotations

import pickle
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.faaslet import ArenaBase, Faaslet
from repro.telemetry import clock as tclock

_cache_lock = threading.Lock()
_PICKLE_FIELDS = ("func_name", "arena", "brk", "memory_limit", "user_state")


@dataclass(frozen=True)
class ProtoFaaslet:
    func_name: str
    arena: bytes
    brk: int
    memory_limit: int
    user_state: bytes = b""               # pickled init-code products

    @staticmethod
    def capture(faaslet: Faaslet, user_state: Any = None) -> "ProtoFaaslet":
        return ProtoFaaslet(
            func_name=faaslet.func_name,
            arena=faaslet.snapshot_arena(),
            brk=faaslet.brk_value,
            memory_limit=faaslet.memory_limit,
            user_state=pickle.dumps(user_state) if user_state is not None else b"",
        )

    # -- per-process decoded caches (built once, shared by every restore) ------

    def arena_base(self) -> ArenaBase:
        """The shared read-only CoW base for this snapshot (decoded once)."""
        base = self.__dict__.get("_arena_base")
        if base is None:
            with _cache_lock:
                base = self.__dict__.get("_arena_base")
                if base is None:
                    base = ArenaBase(self.arena, self.memory_limit)
                    object.__setattr__(self, "_arena_base", base)
        return base

    def user_state_template(self) -> Any:
        """Init-code products decoded once (no per-restore ``pickle.loads``).

        Shared read-only across every Faaslet restored from this proto."""
        if not self.user_state:
            return None
        if "_user_state_tpl" not in self.__dict__:
            with _cache_lock:
                if "_user_state_tpl" not in self.__dict__:
                    object.__setattr__(self, "_user_state_tpl",
                                       pickle.loads(self.user_state))
        return self.__dict__["_user_state_tpl"]

    # -- restore ---------------------------------------------------------------

    def restore(self, host_id: str) -> Tuple[Faaslet, Any]:
        """Stamp out a fresh Faaslet from this snapshot (any host).

        O(1) in arena size: binds the shared CoW base instead of copying."""
        f = Faaslet(self.func_name, host_id, memory_limit=self.memory_limit,
                    initial_pages=0)
        f.bind_base(self.arena_base(), self.brk)
        f.restored_from_proto = True
        return f, self.user_state_template()

    def restore_copy(self, host_id: str) -> Tuple[Faaslet, Any]:
        """Full-copy restore: the pre-CoW path (O(arena) memcpy + fresh
        ``pickle.loads``), kept as the benchmark comparison baseline."""
        f = Faaslet(self.func_name, host_id, memory_limit=self.memory_limit)
        f.restore_arena(self.arena, self.brk)
        f.restored_from_proto = True
        state = pickle.loads(self.user_state) if self.user_state else None
        return f, state

    # -- cross-host / global-tier transport -----------------------------------

    def __getstate__(self):
        # decoded caches (memfd-backed ArenaBase, live template objects) must
        # not travel with the snapshot bytes
        return {k: getattr(self, k) for k in _PICKLE_FIELDS}

    def __setstate__(self, state):
        for k in _PICKLE_FIELDS:
            object.__setattr__(self, k, state[k])

    def serialize(self) -> bytes:
        return pickle.dumps(self)

    @staticmethod
    def deserialize(data: bytes) -> "ProtoFaaslet":
        obj = pickle.loads(data)
        if not isinstance(obj, ProtoFaaslet):
            raise TypeError("not a ProtoFaaslet snapshot")
        return obj

    def size_bytes(self) -> int:
        return len(self.arena) + len(self.user_state)


class ExecutableCache:
    """Compiled-executable snapshots keyed by (fn, arch, shape, mesh) fingerprint."""

    def __init__(self):
        self._cache: Dict[Tuple, Any] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.compile_seconds = 0.0

    def get_or_build(self, key: Tuple, build: Callable[[], Any]):
        """Returns (executable, was_hit, seconds_spent)."""
        with self._lock:
            if key in self._cache:
                self.hits += 1
                return self._cache[key], True, 0.0
        t0 = tclock.now()
        built = build()
        dt = tclock.now() - t0
        with self._lock:
            self._cache.setdefault(key, built)
            self.misses += 1
            self.compile_seconds += dt
        return built, False, dt

    def contains(self, key: Tuple) -> bool:
        with self._lock:
            return key in self._cache

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._cache),
                    "compile_seconds": self.compile_seconds}
