"""Omega-style distributed shared-state scheduler (Faasm §5.1).

Every host runs a *local scheduler*.  The set of warm hosts per function is
**shared state living in the global tier** (key ``sched/warm/<fn>``); each
scheduler reads and updates it while making a placement decision — the Omega
optimistic-concurrency pattern.

The warm set is a **delta-record log**: registration appends one ``+host``
record and deregistration one ``-host`` record via the tier's atomic
``append`` (stripe-lock only — no global key lock, no whole-list JSON
rewrite on the registration path).  Readers replay the log, and compact it
back to one record per member (under the tier's atomic ``rewrite``) once the
log outgrows the membership.

Placement policy (paper §5.1): execute locally if warm with capacity; else
share with a warm host; else cold-start locally and register warm.  The
sharing queue doubles as the work-stealing channel used for straggler
mitigation.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

WARM_PREFIX = "sched/warm/"
_COMPACT_SLACK = 8          # compact when records exceed membership by this


def _replay(raw: bytes) -> Tuple[List[str], int]:
    """Replay a delta-record log; returns (sorted members, record count)."""
    members = {}
    n = 0
    for rec in raw.decode().split("\n"):
        if not rec:
            continue
        n += 1
        op, host = rec[0], rec[1:]
        if op == "+":
            members[host] = True
        elif op == "-":
            members.pop(host, None)
    return sorted(members), n


def _encode(hosts: List[str]) -> bytes:
    return "".join(f"+{h}\n" for h in hosts).encode()


class LocalScheduler:
    def __init__(self, host, runtime):
        self.host = host
        self.runtime = runtime
        # warm-set read cache, invalidated by the key's write version in the
        # global tier — placement on the hot path skips the log replay
        # unless some scheduler actually changed the set.
        self._warm_cache = {}                   # fn -> (version, hosts)

    # -- warm-set shared state --------------------------------------------------

    def _warm_key(self, fn: str) -> str:
        return WARM_PREFIX + fn

    def warm_hosts(self, fn: str) -> List[str]:
        gt = self.runtime.global_tier
        key = self._warm_key(fn)
        ver = gt.version(key)
        cached = self._warm_cache.get(fn)
        if cached is not None and cached[0] == ver:
            return cached[1]
        if not gt.exists(key):
            hosts: List[str] = []
        else:
            hosts, n_records = _replay(gt.get(key, host=self.host.id))
            if n_records > len(hosts) + _COMPACT_SLACK:
                # the log outgrew the membership: compact it atomically.
                # Cache against the version rewrite itself stamped — an
                # append racing in right after must invalidate this cache.
                raw, ver = gt.rewrite(
                    key, lambda cur: _encode(_replay(cur)[0]),
                    host=self.host.id)
                hosts, _ = _replay(raw)
        self._warm_cache[fn] = (ver, hosts)
        return hosts

    def register_warm(self, fn: str) -> None:
        if self.host.id in self.warm_hosts(fn):
            return                              # already a member: no record
        gt = self.runtime.global_tier
        gt.append(self._warm_key(fn), f"+{self.host.id}\n".encode(),
                  host=self.host.id)

    def deregister_warm(self, host_id: str, fn: Optional[str] = None) -> None:
        gt = self.runtime.global_tier
        keys = ([self._warm_key(fn)] if fn else
                [k for k in gt.keys() if k.startswith(WARM_PREFIX)])
        for key in keys:
            if gt.exists(key):
                gt.append(key, f"-{host_id}\n".encode(), host=host_id)

    # -- placement ---------------------------------------------------------------

    def place(self, call) -> "Host":
        """Choose the executing host for ``call`` (may be self).

        Alongside liveness and capacity, placement consults the runtime's
        per-host circuit breakers (``repro.overload.CircuitBreaker``): a
        host whose breaker is open left the warm candidate set until a
        half-open probe readmits it.  Disarmed (no breakers configured) the
        consult is one pointer compare per candidate.  If *every* warm host
        is breaker-open the unfiltered set is kept — placement fails open
        rather than turning breaker trips into a total outage."""
        rt = self.runtime
        warm = [h for h in self.warm_hosts(call.fn)
                if h in rt.hosts and rt.hosts[h].alive]
        admitted = [h for h in warm if rt._breaker_allows(h)]
        if admitted:
            warm = admitted
        me = self.host
        if me.id in warm and me.has_capacity():
            return me
        # share with another warm host that has capacity
        for hid in warm:
            h = rt.hosts[hid]
            if h is not me and h.has_capacity():
                return h
        if me.id in warm:                      # warm but saturated: queue locally
            return me
        if warm:                               # all warm hosts saturated
            return rt.hosts[warm[call.id % len(warm)]]
        # nobody warm: cold start locally, register in the shared warm set
        self.register_warm(call.fn)
        return me
