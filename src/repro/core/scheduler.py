"""Omega-style distributed shared-state scheduler (Faasm §5.1).

Every host runs a *local scheduler*.  The set of warm hosts per function is
**shared state living in the global tier** (key ``sched/warm/<fn>``); each
scheduler reads and atomically updates it under the key's global lock while
making a placement decision — the Omega optimistic-concurrency pattern.

Placement policy (paper §5.1): execute locally if warm with capacity; else
share with a warm host; else cold-start locally and register warm.  The
sharing queue doubles as the work-stealing channel used for straggler
mitigation.
"""
from __future__ import annotations

import json
from typing import List, Optional

WARM_PREFIX = "sched/warm/"


class LocalScheduler:
    def __init__(self, host, runtime):
        self.host = host
        self.runtime = runtime
        # warm-set read cache, invalidated by the key's write version in the
        # global tier — placement on the hot path skips the JSON re-parse
        # unless some scheduler actually changed the set.
        self._warm_cache = {}                   # fn -> (version, hosts)

    # -- warm-set shared state --------------------------------------------------

    def _warm_key(self, fn: str) -> str:
        return WARM_PREFIX + fn

    def warm_hosts(self, fn: str) -> List[str]:
        gt = self.runtime.global_tier
        key = self._warm_key(fn)
        ver = gt.version(key)
        cached = self._warm_cache.get(fn)
        if cached is not None and cached[0] == ver:
            return cached[1]
        if not gt.exists(key):
            hosts: List[str] = []
        else:
            try:
                hosts = json.loads(gt.get(key, host=self.host.id).decode())
            except Exception:
                hosts = []
        self._warm_cache[fn] = (ver, hosts)
        return hosts

    def register_warm(self, fn: str) -> None:
        gt = self.runtime.global_tier
        key = self._warm_key(fn)
        lock = gt.lock(key)
        lock.acquire_write()
        try:
            hosts = set()
            if gt.exists(key):
                hosts = set(json.loads(gt.get(key, host=self.host.id).decode()))
            hosts.add(self.host.id)
            gt.set(key, json.dumps(sorted(hosts)).encode(), host=self.host.id)
        finally:
            lock.release_write()

    def deregister_warm(self, host_id: str, fn: Optional[str] = None) -> None:
        gt = self.runtime.global_tier
        keys = ([self._warm_key(fn)] if fn else
                [k for k in gt.keys() if k.startswith(WARM_PREFIX)])
        for key in keys:
            lock = gt.lock(key)
            lock.acquire_write()
            try:
                if gt.exists(key):
                    hosts = set(json.loads(gt.get(key, host=host_id).decode()))
                    hosts.discard(host_id)
                    gt.set(key, json.dumps(sorted(hosts)).encode(), host=host_id)
            finally:
                lock.release_write()

    # -- placement ---------------------------------------------------------------

    def place(self, call) -> "Host":
        """Choose the executing host for ``call`` (may be self)."""
        rt = self.runtime
        warm = [h for h in self.warm_hosts(call.fn)
                if h in rt.hosts and rt.hosts[h].alive]
        me = self.host
        if me.id in warm and me.has_capacity():
            return me
        # share with another warm host that has capacity
        for hid in warm:
            h = rt.hosts[hid]
            if h is not me and h.has_capacity():
                return h
        if me.id in warm:                      # warm but saturated: queue locally
            return me
        if warm:                               # all warm hosts saturated
            return rt.hosts[warm[call.id % len(warm)]]
        # nobody warm: cold start locally, register in the shared warm set
        self.register_warm(call.fn)
        return me
