"""Faaslet: the paper's isolation abstraction, adapted to this runtime.

A Faaslet owns
  * a **private linear memory** (the WebAssembly-style byte arena): a single
    contiguous address space starting at 0, grown via brk/mmap, with every
    access bounds-checked — the software-fault-isolation discipline.  Compute
    inside XLA executables is already confined to its buffers; the SFI
    enforcement point here is the host side that stitches calls and state.
  * **shared memory regions** (§3.3): page-aligned windows of the linear
    address space remapped onto local-tier replica buffers.  The function
    keeps seeing one dense address space; accesses to mapped offsets hit the
    *same numpy buffer* every co-located Faaslet maps — genuine zero-copy
    sharing (Fig. 2).
  * **resource budgets** — the cgroup/traffic-shaping analogue: CPU-time and
    network-byte accounting with hard caps enforced at the host interface.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

WASM_PAGE = 65536
FAASLET_OVERHEAD_BYTES = 200 * 1024       # paper Tab. 3: ~200 kB per Faaslet
CONTAINER_OVERHEAD_BYTES = 8 * (1 << 20)  # paper §6.2: ~8 MB per container

_ids = itertools.count()


class FaasletMemoryFault(Exception):
    """Out-of-bounds access trapped by the SFI layer."""


class ResourceLimitExceeded(Exception):
    """cgroup/tc analogue: CPU or network budget exhausted."""


@dataclass
class SharedRegion:
    base: int                 # address in the Faaslet's linear memory
    size: int
    key: str                  # state key this region is mapped onto
    backing: np.ndarray       # view into the local-tier replica buffer
    writable: bool = True


@dataclass
class ResourceUsage:
    cpu_ns: int = 0
    net_in: int = 0
    net_out: int = 0
    cpu_budget_ns: Optional[int] = None
    net_budget: Optional[int] = None

    def charge_cpu(self, ns: int):
        self.cpu_ns += ns
        if self.cpu_budget_ns is not None and self.cpu_ns > self.cpu_budget_ns:
            raise ResourceLimitExceeded(f"cpu budget exceeded ({self.cpu_ns} ns)")

    def charge_net(self, n_in: int = 0, n_out: int = 0):
        self.net_in += n_in
        self.net_out += n_out
        if self.net_budget is not None and \
                self.net_in + self.net_out > self.net_budget:
            raise ResourceLimitExceeded("network budget exceeded")


class Faaslet:
    """One isolated execution context bound to a host."""

    def __init__(self, func_name: str, host_id: str, *,
                 memory_limit: int = 64 * WASM_PAGE,
                 initial_pages: int = 4,
                 cpu_budget_ns: Optional[int] = None,
                 net_budget: Optional[int] = None):
        self.id = next(_ids)
        self.func_name = func_name
        self.host_id = host_id
        self.memory_limit = memory_limit
        self._arena = np.zeros(initial_pages * WASM_PAGE, np.uint8)
        self._brk = 0
        self._regions: List[SharedRegion] = []
        self._region_top = memory_limit            # shared regions map above it
        self.usage = ResourceUsage(cpu_budget_ns=cpu_budget_ns,
                                   net_budget=net_budget)
        self.created_at = time.perf_counter()
        self.calls_served = 0
        self.restored_from_proto = False
        self._lock = threading.RLock()

    # -- private linear memory (brk/mmap) --------------------------------------

    @property
    def brk_value(self) -> int:
        return self._brk

    def brk(self, new_brk: int) -> int:
        with self._lock:
            if new_brk < 0 or new_brk > self.memory_limit:
                raise FaasletMemoryFault(
                    f"brk {new_brk} beyond memory limit {self.memory_limit}")
            if new_brk > self._arena.size:
                pages = -(-new_brk // WASM_PAGE)
                grown = np.zeros(pages * WASM_PAGE, np.uint8)
                grown[:self._arena.size] = self._arena
                self._arena = grown
            self._brk = new_brk
            return self._brk

    def sbrk(self, delta: int) -> int:
        old = self._brk
        self.brk(self._brk + delta)
        return old

    def mmap(self, length: int) -> int:
        """Anonymous private mapping == arena grow (the paper's mmap action)."""
        return self.sbrk(-(-length // WASM_PAGE) * WASM_PAGE)

    # -- shared regions (§3.3) ------------------------------------------------------

    def map_shared_region(self, key: str, backing: np.ndarray,
                          writable: bool = True) -> SharedRegion:
        """Extend linear memory and remap the new pages onto ``backing``.

        Returns the region; its ``base`` is the Faaslet-local address."""
        with self._lock:
            size = -(-backing.size // WASM_PAGE) * WASM_PAGE
            region = SharedRegion(base=self._region_top, size=backing.size,
                                  key=key, backing=backing, writable=writable)
            self._regions.append(region)
            self._region_top += size
            return region

    def unmap_shared_region(self, region: SharedRegion) -> None:
        with self._lock:
            self._regions.remove(region)

    def region_for(self, key: str) -> Optional[SharedRegion]:
        with self._lock:
            for r in self._regions:
                if r.key == key:
                    return r
            return None

    # -- bounds-checked access (the SFI guarantee) -----------------------------------

    def _locate(self, addr: int, length: int) -> Tuple[np.ndarray, int]:
        if length < 0:
            raise FaasletMemoryFault("negative length")
        if 0 <= addr and addr + length <= self._brk:
            return self._arena, addr
        for r in self._regions:
            if r.base <= addr and addr + length <= r.base + r.size:
                return r.backing, addr - r.base
        raise FaasletMemoryFault(
            f"access [{addr}, {addr + length}) outside private memory "
            f"[0, {self._brk}) and all shared regions")

    def read(self, addr: int, length: int) -> np.ndarray:
        """Zero-copy view of linear memory (trap on out-of-bounds)."""
        buf, off = self._locate(addr, length)
        return buf[off:off + length]

    def write(self, addr: int, data) -> None:
        data = np.frombuffer(bytes(data), np.uint8) if not isinstance(
            data, np.ndarray) else data.view(np.uint8).reshape(-1)
        buf, off = self._locate(addr, len(data))
        for r in self._regions:
            if r.backing is buf and not r.writable:
                raise FaasletMemoryFault(f"write to read-only region {r.key!r}")
        buf[off:off + len(data)] = data

    # -- introspection ----------------------------------------------------------------

    def memory_bytes(self) -> int:
        """Private footprint (shared regions are counted once per host)."""
        return self._arena.size + FAASLET_OVERHEAD_BYTES

    def snapshot_arena(self) -> bytes:
        with self._lock:
            return self._arena[:self._brk].tobytes()

    def restore_arena(self, data: bytes, brk: int) -> None:
        with self._lock:
            self.brk(max(brk, len(data)))
            self._arena[:len(data)] = np.frombuffer(data, np.uint8)
            self._brk = brk
