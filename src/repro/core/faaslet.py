"""Faaslet: the paper's isolation abstraction, adapted to this runtime.

A Faaslet owns
  * a **private linear memory** (the WebAssembly-style byte arena): a single
    contiguous address space starting at 0, grown via brk/mmap, with every
    access bounds-checked — the software-fault-isolation discipline.  Compute
    inside XLA executables is already confined to its buffers; the SFI
    enforcement point here is the host side that stitches calls and state.
  * **shared memory regions** (§3.3): page-aligned windows of the linear
    address space remapped onto local-tier replica buffers.  The function
    keeps seeing one dense address space; accesses to mapped offsets hit the
    *same numpy buffer* every co-located Faaslet maps — genuine zero-copy
    sharing (Fig. 2).
  * **resource budgets** — the cgroup/traffic-shaping analogue: CPU-time and
    network-byte accounting with hard caps enforced at the host interface.

Restore/reset cost (§5.2) is proportional to what *changed*, not to arena
size: a Faaslet tracks dirty WASM pages (``write``/``brk`` mark them), a
Proto-Faaslet snapshot is bound as a shared read-only :class:`ArenaBase`
(mapped copy-on-write, no per-restore arena copy), and the post-call reset
restores only the dirty pages from that base — handing them back to the
kernel via ``madvise(MADV_DONTNEED)`` on the mmap path (RSS shrinks under
churn; ``reclaimed_pages`` counts them), memcpy re-stamping elsewhere.
"""
from __future__ import annotations

import itertools
import mmap
import os
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro import faults
from repro.analysis.sanitizer import make_mutex
from repro.telemetry import clock as tclock

WASM_PAGE = 65536
FAASLET_OVERHEAD_BYTES = 200 * 1024       # paper Tab. 3: ~200 kB per Faaslet
CONTAINER_OVERHEAD_BYTES = 8 * (1 << 20)  # paper §6.2: ~8 MB per container

_ids = itertools.count()

# Snapshots at or below this size restore by eager copy: a µs-scale memcpy
# beats an mmap syscall for tiny arenas, and the dirty-page reset on top is
# O(dirty) either way.  Larger snapshots map the base MAP_PRIVATE so restore
# stays O(1) and clean pages are shared across Faaslets.
EAGER_COPY_MAX_BYTES = 1 << 20


class FaasletMemoryFault(Exception):
    """Out-of-bounds access trapped by the SFI layer."""


class ArenaBase:
    """Shared read-only arena snapshot backing copy-on-write restores (§5.2).

    The snapshot bytes are written once into an anonymous memfd sized to the
    Faaslet's full memory limit (the tail beyond the snapshot is a file hole
    that reads as zeros, which covers pages later exposed by ``brk``).  Every
    restore maps the fd ``MAP_PRIVATE``: the mapping itself is O(1), clean
    pages are shared by all Faaslets stamped from this base, and the kernel
    copies a page only when it is first written.  Where memfd/mmap are
    unavailable the restore falls back to one eager copy — the software
    dirty-page reset on top stays O(dirty) either way.
    """

    def __init__(self, snapshot: bytes, memory_limit: int):
        self.snapshot = snapshot
        self.view = np.frombuffer(snapshot, np.uint8)       # zero-copy, RO
        pages = max(1, -(-max(memory_limit, len(snapshot)) // WASM_PAGE))
        self.span = pages * WASM_PAGE
        self._fd = -1
        if len(snapshot) <= EAGER_COPY_MAX_BYTES:
            return                          # small snapshot: eager-copy restores
        try:
            fd = os.memfd_create("faaslet-arena-base")
            os.truncate(fd, self.span)
            os.pwrite(fd, snapshot, 0)
            self._fd = fd
        except (AttributeError, OSError):
            self._fd = -1

    def __del__(self):
        if getattr(self, "_fd", -1) >= 0:
            try:
                os.close(self._fd)
            except OSError:
                pass

    def map_private(self) -> Tuple[np.ndarray, Optional[mmap.mmap]]:
        """A writable CoW view of the base (plus the mapping keeping it alive)."""
        if self._fd >= 0:
            try:
                mm = mmap.mmap(self._fd, self.span, flags=mmap.MAP_PRIVATE,
                               prot=mmap.PROT_READ | mmap.PROT_WRITE)
                return np.frombuffer(mm, np.uint8), mm
            except (OSError, ValueError):
                pass
        pages = -(-self.view.size // WASM_PAGE)
        arena = np.zeros(pages * WASM_PAGE, np.uint8)
        arena[:self.view.size] = self.view
        return arena, None

    def stamp(self, dest: np.ndarray, lo: int, hi: int) -> None:
        """Overwrite ``dest[lo:hi]`` with the base content of that range."""
        cut = min(hi, self.view.size)
        if lo < cut:
            dest[lo:cut] = self.view[lo:cut]
        if max(lo, cut) < hi:
            dest[max(lo, cut):hi] = 0


class ResourceLimitExceeded(Exception):
    """cgroup/tc analogue: CPU or network budget exhausted."""


@dataclass
class SharedRegion:
    base: int                 # address in the Faaslet's linear memory
    size: int
    key: str                  # state key this region is mapped onto
    backing: np.ndarray       # view into the local-tier replica buffer
    writable: bool = True


@dataclass
class ResourceUsage:
    cpu_ns: int = 0
    net_in: int = 0
    net_out: int = 0
    cpu_budget_ns: Optional[int] = None
    net_budget: Optional[int] = None

    def charge_cpu(self, ns: int):
        self.cpu_ns += ns
        if self.cpu_budget_ns is not None and self.cpu_ns > self.cpu_budget_ns:
            raise ResourceLimitExceeded(f"cpu budget exceeded ({self.cpu_ns} ns)")

    def charge_net(self, n_in: int = 0, n_out: int = 0):
        self.net_in += n_in
        self.net_out += n_out
        if self.net_budget is not None and \
                self.net_in + self.net_out > self.net_budget:
            raise ResourceLimitExceeded("network budget exceeded")


class Faaslet:
    """One isolated execution context bound to a host."""

    def __init__(self, func_name: str, host_id: str, *,
                 memory_limit: int = 64 * WASM_PAGE,
                 initial_pages: int = 4,
                 cpu_budget_ns: Optional[int] = None,
                 net_budget: Optional[int] = None):
        self.id = next(_ids)
        self.func_name = func_name
        self.host_id = host_id
        self.memory_limit = memory_limit
        self._arena = np.zeros(initial_pages * WASM_PAGE, np.uint8)
        self._brk = 0
        self._base: Optional[ArenaBase] = None   # CoW base (set by bind_base)
        self._base_brk = 0
        self._mm: Optional[mmap.mmap] = None     # keeps the private mapping alive
        self._dirty: Set[int] = set()            # page indices written since reset
        self._regions: List[SharedRegion] = []
        self._region_top = memory_limit            # shared regions map above it
        self.usage = ResourceUsage(cpu_budget_ns=cpu_budget_ns,
                                   net_budget=net_budget)
        self.created_at = tclock.now()
        self.calls_served = 0
        self.restored_from_proto = False
        self.reclaimed_pages = 0        # dirty pages handed back via madvise
        self.retained_pages = 0         # dirty pages re-stamped, kept resident
        self._lock = make_mutex("faaslet", f"faaslet:{self.id}")

    # -- private linear memory (brk/mmap) --------------------------------------

    @property
    def brk_value(self) -> int:
        return self._brk

    def brk(self, new_brk: int) -> int:
        with self._lock:
            if new_brk < 0 or new_brk > self.memory_limit:
                raise FaasletMemoryFault(
                    f"brk {new_brk} beyond memory limit {self.memory_limit}")
            if new_brk > self._arena.size:
                pages = -(-new_brk // WASM_PAGE)
                grown = np.zeros(pages * WASM_PAGE, np.uint8)
                grown[:self._arena.size] = self._arena
                self._arena = grown
            if new_brk > self._brk:
                self._mark_dirty(self._brk, new_brk - self._brk)
            self._brk = new_brk
            return self._brk

    def sbrk(self, delta: int) -> int:
        old = self._brk
        self.brk(self._brk + delta)
        return old

    def mmap(self, length: int) -> int:
        """Anonymous private mapping == arena grow (the paper's mmap action)."""
        return self.sbrk(-(-length // WASM_PAGE) * WASM_PAGE)

    # -- dirty-page tracking / copy-on-write base (§5.2) -----------------------

    def _mark_dirty(self, addr: int, length: int) -> None:
        if length > 0:
            self._dirty.update(range(addr // WASM_PAGE,
                                     (addr + length - 1) // WASM_PAGE + 1))

    @property
    def dirty_pages(self) -> Set[int]:
        """Arena pages written (or newly exposed by brk) since the last reset."""
        return set(self._dirty)

    def clear_dirty(self) -> None:
        with self._lock:
            self._dirty.clear()

    def has_base(self) -> bool:
        return self._base is not None

    def bind_base(self, base: ArenaBase, brk: int) -> None:
        """Bind a shared read-only snapshot as this Faaslet's arena (CoW).

        The arena becomes a private mapping of the base: no arena copy is
        made here; the kernel shares clean pages with every other Faaslet
        bound to the same base and copies a page on first write.
        """
        with self._lock:
            arena, mm = base.map_private()
            self._base_brk = min(brk, self.memory_limit)
            need = -(-self._base_brk // WASM_PAGE) * WASM_PAGE
            if arena.size < need:               # eager-copied base below brk
                grown = np.zeros(need, np.uint8)
                grown[:arena.size] = arena
                arena = grown
            self._arena, self._mm = arena, mm
            self._base = base
            self._brk = self._base_brk
            self._dirty.clear()

    def reset_from_base(self, reclaim: str = "always",
                        pressure: bool = False) -> int:
        """§5.2 post-call reset in O(dirty): restore only the dirty pages
        from the bound base (byte-identical to a full ``restore_arena`` from
        the same snapshot).  Returns the number of pages reset.

        ``reclaim`` picks the latency-for-RSS trade per reset:

          * ``"always"`` — on the mmap MAP_PRIVATE path, hand dirty pages
            back with ``madvise(MADV_DONTNEED)``: the private copy is
            dropped, the next access refaults the *shared* base page (file
            holes read as zeros, matching ``stamp``), so RSS shrinks under
            churn — but the next call pays a refault per re-dirtied page.
          * ``"never"`` — memcpy re-stamp only: pages stay resident, hot
            Faaslets stay refault-free.
          * ``"auto"`` — ``"always"`` when the caller signals memory
            ``pressure`` (host RSS over threshold, or the Faaslet is going
            cold behind other warm instances), ``"never"`` otherwise.

        ``reclaimed_pages`` counts pages actually madvise'd back;
        ``retained_pages`` counts pages re-stamped and kept resident (the
        madvise-unavailable fallback lands there too)."""
        if reclaim not in ("auto", "always", "never"):
            raise ValueError(
                f"reclaim {reclaim!r} not in ('auto', 'always', 'never')")
        if reclaim == "auto":
            reclaim = "always" if pressure else "never"
        faults.point("slow-host", host=self.host_id)
        with self._lock:
            if self._base is None:
                raise RuntimeError("no ArenaBase bound; use restore_arena")
            reset = 0
            can_reclaim = (reclaim == "always"
                           and self._mm is not None
                           and hasattr(mmap, "MADV_DONTNEED")
                           and hasattr(self._mm, "madvise"))
            for lo, hi in self._dirty_runs():
                if lo >= self._arena.size:
                    continue
                hi = min(hi, self._arena.size)
                n_pages = -(-(hi - lo) // WASM_PAGE)
                if can_reclaim:
                    try:
                        self._mm.madvise(mmap.MADV_DONTNEED, lo, hi - lo)
                        self.reclaimed_pages += n_pages
                        reset += n_pages
                        continue
                    except (OSError, ValueError):
                        can_reclaim = False      # fall back for the rest
                for p_lo in range(lo, hi, WASM_PAGE):
                    self._base.stamp(self._arena, p_lo,
                                     min(p_lo + WASM_PAGE, self._arena.size))
                    self.retained_pages += 1
                    reset += 1
            self._dirty.clear()
            self._brk = self._base_brk
            return reset

    def _dirty_runs(self):
        """Yield (lo, hi) byte ranges of maximal runs of dirty pages, so the
        madvise path issues one syscall per contiguous run."""
        run_start = prev = None
        for p in sorted(self._dirty):
            if prev is not None and p == prev + 1:
                prev = p
                continue
            if run_start is not None:
                yield run_start * WASM_PAGE, (prev + 1) * WASM_PAGE
            run_start = prev = p
        if run_start is not None:
            yield run_start * WASM_PAGE, (prev + 1) * WASM_PAGE

    # -- shared regions (§3.3) ------------------------------------------------------

    def map_shared_region(self, key: str, backing: np.ndarray,
                          writable: bool = True) -> SharedRegion:
        """Extend linear memory and remap the new pages onto ``backing``.

        Returns the region; its ``base`` is the Faaslet-local address."""
        with self._lock:
            size = -(-backing.size // WASM_PAGE) * WASM_PAGE
            region = SharedRegion(base=self._region_top, size=backing.size,
                                  key=key, backing=backing, writable=writable)
            self._regions.append(region)
            self._region_top += size
            return region

    def unmap_shared_region(self, region: SharedRegion) -> None:
        with self._lock:
            self._regions.remove(region)

    def region_for(self, key: str) -> Optional[SharedRegion]:
        with self._lock:
            for r in self._regions:
                if r.key == key:
                    return r
            return None

    # -- bounds-checked access (the SFI guarantee) -----------------------------------

    def _locate(self, addr: int, length: int) -> Tuple[np.ndarray, int]:
        if length < 0:
            raise FaasletMemoryFault("negative length")
        if 0 <= addr and addr + length <= self._brk:
            return self._arena, addr
        for r in self._regions:
            if r.base <= addr and addr + length <= r.base + r.size:
                return r.backing, addr - r.base
        raise FaasletMemoryFault(
            f"access [{addr}, {addr + length}) outside private memory "
            f"[0, {self._brk}) and all shared regions")

    def read(self, addr: int, length: int) -> np.ndarray:
        """Zero-copy view of linear memory (trap on out-of-bounds).

        Arena views come back read-only: mutations must go through
        :meth:`write` so dirty-page tracking sees them (otherwise a warm
        reset could miss them and leak bytes into the next call).  Shared
        regions stay writable — that is the §3.3 zero-copy write path —
        unless the region itself was mapped read-only."""
        buf, off = self._locate(addr, length)
        view = buf[off:off + length]
        if buf is self._arena:
            view.setflags(write=False)
        else:
            for r in self._regions:
                if r.backing is buf and not r.writable:
                    view.setflags(write=False)
                    break
        return view

    def write(self, addr: int, data) -> None:
        data = np.frombuffer(bytes(data), np.uint8) if not isinstance(
            data, np.ndarray) else data.view(np.uint8).reshape(-1)
        buf, off = self._locate(addr, len(data))
        for r in self._regions:
            if r.backing is buf and not r.writable:
                raise FaasletMemoryFault(f"write to read-only region {r.key!r}")
        if buf is self._arena:
            self._mark_dirty(off, len(data))
        buf[off:off + len(data)] = data

    # -- introspection ----------------------------------------------------------------

    def memory_bytes(self) -> int:
        """Private footprint (shared regions are counted once per host).

        A mmap-CoW Faaslet privately owns only its dirty pages — clean pages
        belong to the shared base, which :meth:`base_footprint` reports so
        the host can count it once across all Faaslets bound to it.  An
        eager-copied arena (small snapshot, or mmap unavailable) is fully
        private and charged in full."""
        if self._mm is not None:
            return len(self._dirty) * WASM_PAGE + FAASLET_OVERHEAD_BYTES
        return self._arena.size + FAASLET_OVERHEAD_BYTES

    def base_footprint(self) -> Optional[Tuple[int, int]]:
        """(base identity, resident bytes) of the shared CoW base, or None
        when the arena is a private copy (nothing is actually shared).
        Hosts deduplicate on the identity: one snapshot, one charge."""
        if self._mm is None:
            return None
        return id(self._base), -(-self._base.view.size // WASM_PAGE) * WASM_PAGE

    def snapshot_arena(self) -> bytes:
        with self._lock:
            return self._arena[:self._brk].tobytes()

    def restore_arena(self, data: bytes, brk: int) -> None:
        """Full-copy restore (the pre-CoW baseline, kept for comparison and
        for restores without a bound :class:`ArenaBase`)."""
        with self._lock:
            self.brk(max(brk, len(data)))
            self._arena[:len(data)] = np.frombuffer(data, np.uint8)
            self._mark_dirty(0, len(data))
            self._brk = brk
