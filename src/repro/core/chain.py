"""Chained-call helpers (the paper's chain/await loops, Listing 1 pattern)."""
from __future__ import annotations

from typing import Iterable, List, Sequence


def chain(api, name: str, inputs: Sequence[bytes]) -> List[int]:
    """Spawn one chained call per input; returns the call IDs (input order)."""
    if hasattr(api, "chain_call_many"):
        return api.chain_call_many(name, list(inputs))
    return [api.chain_call(name, inp) for inp in inputs]


def await_all(api, call_ids: Iterable[int]) -> List[int]:
    """Block until every chained call finishes; returns their codes."""
    ids = list(call_ids)
    if hasattr(api, "await_all"):
        return api.await_all(ids)
    return [api.await_call(cid) for cid in ids]


def outputs(api, call_ids: Iterable[int]) -> List[bytes]:
    return [api.get_call_output(cid) for cid in call_ids]
