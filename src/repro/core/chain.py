"""Chained-call helpers (the paper's chain/await loops, Listing 1 pattern)."""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

from repro.overload import DEADLINE_RC


def chain(api, name: str, inputs: Sequence[bytes],
          deadline=None) -> List[int]:
    """Spawn one chained call per input; returns the call IDs (input order).

    ``deadline`` (a float budget in seconds or an ``overload.Deadline``)
    bounds the children end-to-end; omitted, they inherit the calling
    function's remaining deadline budget."""
    if hasattr(api, "chain_call_many"):
        return api.chain_call_many(name, list(inputs), deadline=deadline)
    return [api.chain_call(name, inp, deadline=deadline) for inp in inputs]


def await_all(api, call_ids: Iterable[int]) -> List[int]:
    """Block until every chained call finishes; returns their codes."""
    ids = list(call_ids)
    if hasattr(api, "await_all"):
        return api.await_all(ids)
    return [api.await_call(cid) for cid in ids]


def outputs(api, call_ids: Iterable[int]) -> List[bytes]:
    return [api.get_call_output(cid) for cid in call_ids]


def scatter_gather(api, name: str, inputs: Sequence[bytes], *,
                   retries: int = 1, deadline=None) -> List[Tuple[int, bytes]]:
    """Fan out one call per input and gather ``(return_code, output)`` pairs
    in input order, re-chaining failed children up to ``retries`` times.

    This is the *application-level* retry above the runtime's own
    re-execution: the runtime requeues calls lost to host failure (with
    attempt fencing keeping their state effects exactly-once), while this
    helper re-submits calls that **settled as failed** — e.g. shed by a
    degraded cluster or out of runtime retry budget.  A re-chained child is
    a fresh call with a fresh fence, so re-running it is safe by the same
    exactly-once argument.  Failures that persist through the budget are
    returned, not raised: per-input isolation, the caller decides.

    Deadline interplay: ``deadline`` bounds every child (first attempt and
    retries alike — the retries share the original absolute expiry, they do
    not restart the clock).  A child that settled with ``DEADLINE_RC`` is
    **not** re-chained: its end-to-end budget is spent, and re-submitting
    work that is already too late only deepens an overload.  Shed children
    (``SHED_RC``) stay retryable — a later wave may find room."""
    inputs = [bytes(i) for i in inputs]
    ids = chain(api, name, inputs, deadline=deadline)
    codes = await_all(api, ids)
    pending = [i for i, rc in enumerate(codes)
               if rc != 0 and rc != DEADLINE_RC]
    for _ in range(retries):
        if not pending:
            break
        retry_ids = chain(api, name, [inputs[i] for i in pending],
                          deadline=deadline)
        retry_codes = await_all(api, retry_ids)
        still = []
        for i, cid, rc in zip(pending, retry_ids, retry_codes):
            ids[i], codes[i] = cid, rc
            if rc != 0 and rc != DEADLINE_RC:
                still.append(i)
        pending = still
    return [(codes[i], api.get_call_output(ids[i])) for i in range(len(ids))]
