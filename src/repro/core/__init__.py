"""FAASM core: Faaslets, host interface, Proto-Faaslets, scheduler, runtime."""
from repro.core.faaslet import (CONTAINER_OVERHEAD_BYTES,
                                FAASLET_OVERHEAD_BYTES, ArenaBase, Faaslet,
                                FaasletMemoryFault, ResourceLimitExceeded)
from repro.core.host_interface import CallCancelled, FaasmAPI, StateKeyError
from repro.core.proto import ExecutableCache, ProtoFaaslet
from repro.core.runtime import (BatchTimeout, Call, CompletionLatch,
                                FaasmRuntime, FunctionDef, Host)
from repro.core.scheduler import LocalScheduler
from repro.core.chain import await_all, chain, outputs
from repro.core.vfs import VirtualFS

__all__ = [
    "ArenaBase", "Faaslet", "FaasletMemoryFault", "ResourceLimitExceeded",
    "FaasmAPI", "CallCancelled",
    "StateKeyError", "ExecutableCache", "ProtoFaaslet", "Call",
    "BatchTimeout", "CompletionLatch", "FaasmRuntime",
    "FunctionDef", "Host", "LocalScheduler", "await_all", "chain", "outputs",
    "VirtualFS", "FAASLET_OVERHEAD_BYTES", "CONTAINER_OVERHEAD_BYTES",
]
