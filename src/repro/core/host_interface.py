"""The Faaslet host interface — the full Table-2 API surface.

One ``FaasmAPI`` instance is bound per (Faaslet, call).  It is the *only* way
a function interacts with the outside world, and the single place where the
isolation invariants are enforced:

  * state access goes through shared regions (zero-copy, ``faaslet`` mode) or
    private copies (``container`` data-shipping baseline);
  * every byte moved to/from the global tier is charged against the Faaslet's
    network budget (traffic-shaping analogue) and the host's transfer metrics;
  * the filesystem is read-global / write-local with unforgeable handles
    (WASI capability style);
  * gettime is a per-call monotonic clock, getrandom draws host entropy.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.faaslet import Faaslet, FaasletMemoryFault


class StateKeyError(KeyError):
    pass


class CallCancelled(RuntimeError):
    """The call's speculative counterpart already settled: this execution is
    cooperatively cancelled at the next host-interface checkpoint (chain,
    await, state pull/push) so its executor slot frees instead of running a
    discarded computation to completion."""


class DeadlineExceeded(CallCancelled):
    """The call's end-to-end deadline expired mid-execution: same
    cooperative unwind as a cancel (next host-interface or kernel
    checkpoint), but the runtime settles the call with
    ``overload.DEADLINE_RC`` so waiters can tell a deadline from a
    speculative loss.  The attempt fence keeps any state effects the
    interrupted attempt already pushed exactly-once."""


class FaasmAPI:
    def __init__(self, faaslet: Faaslet, host, runtime, call):
        self.faaslet = faaslet
        self.host = host
        self.runtime = runtime
        self.call = call
        self._t0 = time.monotonic_ns()
        self._fds: Dict[int, dict] = {}
        self._fd_counter = itertools.count(3)
        self._dl_handles: Dict[int, str] = {}
        self._dl_counter = itertools.count(1)
        self._local_locked = {}
        # attempt fencing: per-key 1-based sequence of this attempt's delta
        # pushes.  A FaasmAPI is built fresh per physical execution, so a
        # re-executed attempt restarts its sequence — identical pushes from
        # identical (deterministic) re-runs carry identical (id, seq) pairs
        # and the global tier admits each effect exactly once.
        self._push_seq: Dict[str, int] = {}
        self._dirtied: set = set()
        # Snapshot the epoch at attempt start: a zombie attempt (host declared
        # dead by the heartbeat monitor while it was merely slow) must keep
        # pushing under its *own*, by-then superseded epoch — reading the
        # shared Call object live would let it impersonate the re-execution.
        self._fence_epoch = getattr(call, "fence_epoch", 0)

    def _fence(self, key: str) -> Optional[tuple]:
        """Fence token ``(call_id, epoch, seq)`` for the next delta push of
        ``key``, or ``None`` for unfenced contexts (init code)."""
        epoch = self._fence_epoch
        if not epoch:
            return None
        seq = self._push_seq.get(key, 0) + 1
        self._push_seq[key] = seq
        return (self.call.fence_id, epoch, seq)

    def dirtied_keys(self):
        """State keys this call wrote locally (host-side cleanup of
        un-pushed deltas when the call fails)."""
        return tuple(self._dirtied)

    # ------------------------------------------------------------------ calls --

    def check_cancelled(self) -> None:
        """Cooperative cancellation point: raise if this call was cancelled
        (its speculative twin already settled) or its end-to-end deadline
        expired.  Called automatically at chain/await and state pull/push
        boundaries, and from kernel dispatch via ``cancellation.checkpoint``.
        Deadline-less calls pay one pointer compare for the deadline arm."""
        ev = getattr(self.call, "cancel_event", None)
        if ev is not None and ev.is_set():
            raise CallCancelled(
                f"call {self.call.id} cancelled (speculative twin settled)")
        dl = getattr(self.call, "deadline", None)
        if dl is not None and dl.expired():
            raise DeadlineExceeded(
                f"call {self.call.id} exceeded its deadline "
                f"({dl.budget_s * 1e3:.1f} ms budget)")

    def read_call_input(self) -> bytes:
        return self.call.input

    def write_call_output(self, out_data: bytes) -> None:
        self.call.output = bytes(out_data)

    def chain_call(self, name: str, args: bytes = b"",
                   deadline=None) -> int:
        """Chain a child call.  ``deadline`` (a float budget in seconds or a
        ``repro.overload.Deadline``) stamps a tighter expiry; omitted, the
        child inherits this call's remaining deadline budget."""
        self.check_cancelled()
        self.faaslet.usage.charge_net(n_out=len(args))
        return self.runtime.invoke(name, bytes(args), parent=self.call,
                                   deadline=deadline)

    def chain_call_many(self, name: str, args_list,
                        state_hint: Optional[List[str]] = None,
                        deadline=None) -> List[int]:
        """Batch chain: one submission for the whole fan-out (ordered IDs).

        ``state_hint`` names the state keys the batch touches so placement
        can prefer hosts already holding warm replicas of them.
        ``deadline`` is as in :meth:`chain_call`: explicit budget, else the
        children inherit the parent call's remaining deadline."""
        self.check_cancelled()
        args_list = [bytes(a) for a in args_list]
        for a in args_list:
            self.faaslet.usage.charge_net(n_out=len(a))
        return self.runtime.invoke_many(name, args_list, parent=self.call,
                                        state_hint=state_hint,
                                        deadline=deadline)

    def await_call(self, call_id: int, timeout: Optional[float] = None) -> int:
        self.check_cancelled()
        return self.runtime.wait(call_id, timeout=timeout)

    def await_all(self, call_ids,
                  timeout: Optional[float] = None) -> List[int]:
        """Block on one shared latch until every chained call finishes."""
        self.check_cancelled()
        return self.runtime.wait_all(call_ids, timeout=timeout)

    def get_call_output(self, call_id: int) -> bytes:
        out = self.runtime.output(call_id)
        self.faaslet.usage.charge_net(n_in=len(out))
        return out

    # ------------------------------------------------------------------ state --

    def _local(self):
        return self.host.local_tier_for(self.faaslet)

    def get_state(self, key: str, *, writable: bool = True) -> np.ndarray:
        """Pointer (numpy view) to the state value — maps a shared region.

        ``faaslet`` isolation: the view aliases the host-shared replica buffer
        (zero-copy).  ``container`` isolation: a private copy (data shipping).
        """
        lt = self._local()
        if not lt.has(key) and not self.runtime.global_tier.exists(key):
            raise StateKeyError(key)
        lt.pull(key)
        replica = lt.replica(key)
        if self.host.isolation == "container":
            self.faaslet.usage.charge_net(n_in=replica.buf.size)
            return replica.buf.copy()
        region = self.faaslet.region_for(key)
        if region is None or region.backing is not replica.buf:
            region = self.faaslet.map_shared_region(key, replica.buf,
                                                    writable=writable)
        if writable:
            # A writable mapping may mutate the shared replica behind the
            # api (e.g. VectorAsync's HOGWILD add): track the key so a
            # failed call's un-pushed deltas are discarded, not leaked into
            # later calls on this host (``discard_unpushed`` no-ops when
            # the replica has no dirty chunks).
            self._dirtied.add(key)
        return self.faaslet.read(region.base, region.size)

    def get_state_offset(self, key: str, offset: int, length: int,
                         *, writable: bool = True) -> np.ndarray:
        lt = self._local()
        lt.pull_range(key, offset, length)
        replica = lt.replica(key)
        if self.host.isolation == "container":
            self.faaslet.usage.charge_net(n_in=length)
            return replica.buf[offset:offset + length].copy()
        region = self.faaslet.region_for(key)
        if region is None or region.backing is not replica.buf:
            region = self.faaslet.map_shared_region(key, replica.buf,
                                                    writable=writable)
        return self.faaslet.read(region.base + offset, length)

    def set_state(self, key: str, value: bytes) -> None:
        value = bytes(value)
        lt = self._local()
        r = lt.replica(key, size=len(value))
        r.lock.acquire_write()
        try:
            r.buf[:len(value)] = np.frombuffer(value, np.uint8)
            r.full = True
            r.present_chunks = set(range(self.runtime.global_tier.n_chunks(key)
                                         if self.runtime.global_tier.exists(key)
                                         else 1))
        finally:
            r.lock.release_write()
        lt.mark_dirty(key, 0, len(value))
        self._dirtied.add(key)

    def set_state_offset(self, key: str, value: bytes, offset: int) -> None:
        value = bytes(value)
        lt = self._local()
        r = lt.replica(key, size=offset + len(value))
        r.lock.acquire_write()
        try:
            r.buf[offset:offset + len(value)] = np.frombuffer(value, np.uint8)
        finally:
            r.lock.release_write()
        lt.mark_dirty(key, offset, len(value))
        self._dirtied.add(key)

    def push_state(self, key: str) -> None:
        self.check_cancelled()
        n = self._local().push(key)
        self.faaslet.usage.charge_net(n_out=n)
        self._dirtied.discard(key)

    def push_state_partial(self, key: str) -> None:
        """Push only dirty chunks (what VectorAsync.push() uses)."""
        self.check_cancelled()
        n = self._local().push_dirty(key)
        self.faaslet.usage.charge_net(n_out=n)
        self._dirtied.discard(key)

    def push_state_delta(self, key: str, dtype=np.float32,
                         wire: str = "auto") -> None:
        """Accumulating push: global += local − base (cross-host HOGWILD).

        ``wire="auto"`` (default) lets the key's adaptive ``WirePolicy``
        pick the codec from observed delta magnitude/density and residual
        norm; ``"int8"`` forces the fused ``kernels/state_push`` quantised
        frame (int8 payload + per-row scales, ~¼ of the f32 bytes, with
        per-replica error feedback) and ``"exact"`` the f32 delta frame.
        The network budget is charged the wire bytes actually moved, not
        the value bytes."""
        self.check_cancelled()
        n = self._local().push_delta(key, dtype=dtype, wire=wire,
                                     fence=self._fence(key))
        self.faaslet.usage.charge_net(n_out=n)
        self._dirtied.discard(key)               # pushed (or fenced off)

    # -- device residency (DeviceReplica plane; transfers are intra-host) -----

    def state_to_device(self, key: str, dtype=np.float32,
                        track_delta: bool = False):
        """Materialise the replica as a JAX device array (H2D, no global-tier
        traffic).  With ``track_delta``, arms a device-native ``push_delta``
        by snapshotting the device base at this sync point."""
        self.check_cancelled()
        return self._local().to_device(key, dtype=dtype,
                                       track_delta=track_delta)

    def state_update_device(self, key: str, value) -> None:
        """Install a device-computed value as the replica's device copy."""
        self.check_cancelled()
        self._local().update_device(key, value)
        self._dirtied.add(key)

    def state_from_device(self, key: str) -> int:
        """Sync the device value back into the shared host replica (D2H)."""
        self.check_cancelled()
        n = self._local().from_device(key)
        self._dirtied.add(key)
        return n

    def pull_state(self, key: str, track_delta: bool = False,
                   wire: Optional[str] = None) -> None:
        """Replicate (or refresh) the value locally.  A warm replica
        refreshes through the wire fabric: only the retained delta ships
        (``wire="int8"`` ≈ ¼ of the f32 re-pull bytes; ``None``/"auto" lets
        the key's ``WirePolicy`` decide), with a full-pull fallback when
        the replica's base predates the retained window."""
        self.check_cancelled()
        moved = self._local().pull(key, wire=wire)
        if track_delta:
            # arm-only: the replica (and its base) is shared with co-located
            # faaslets — force-stamping here would absorb their pending
            # HOGWILD writes into the base and lose them (see snapshot_base)
            self._local().snapshot_base(key, force=False)
        self.faaslet.usage.charge_net(n_in=moved)

    def subscribe_state(self, key: str) -> None:
        """Subscribe the host's replica to the key's push fan-out: peer
        wire frames are applied in place as they land, so the warm replica
        converges without this function (or any later call on this host)
        paying a re-pull.  The initial sync pull is charged to the network
        budget like any other pull."""
        self.check_cancelled()
        moved = self._local().subscribe(key)
        self.faaslet.usage.charge_net(n_in=moved)

    def unsubscribe_state(self, key: Optional[str] = None) -> None:
        self._local().unsubscribe(key)

    def pull_state_chunk(self, key: str, chunk_idx: int) -> None:
        self.check_cancelled()
        moved = self._local().pull_chunk(key, chunk_idx)
        self.faaslet.usage.charge_net(n_in=moved)

    def append_state(self, key: str, value: bytes) -> None:
        self.runtime.global_tier.append(key, bytes(value), host=self.host.id)
        self.faaslet.usage.charge_net(n_out=len(value))

    # -- locks ----------------------------------------------------------------

    def lock_state_read(self, key: str):
        self._local().replica(key, size=max(1, self.runtime.global_tier.size(key)
                                            if self.runtime.global_tier.exists(key)
                                            else 1)).lock.acquire_read()

    def unlock_state_read(self, key: str):
        self._local()._replicas[key].lock.release_read()

    def lock_state_write(self, key: str):
        self._local().replica(key, size=max(1, self.runtime.global_tier.size(key)
                                            if self.runtime.global_tier.exists(key)
                                            else 1)).lock.acquire_write()

    def unlock_state_write(self, key: str):
        self._local()._replicas[key].lock.release_write()

    def lock_state_global_read(self, key: str):
        self.runtime.global_tier.lock(key).acquire_read()

    def unlock_state_global_read(self, key: str):
        self.runtime.global_tier.lock(key).release_read()

    def lock_state_global_write(self, key: str):
        self.runtime.global_tier.lock(key).acquire_write()

    def unlock_state_global_write(self, key: str):
        self.runtime.global_tier.lock(key).release_write()

    # ------------------------------------------------------------------ dynlink --

    def dlopen(self, name: str) -> int:
        if not self.runtime.has_module(name):
            raise FileNotFoundError(f"no module {name!r} uploaded")
        h = next(self._dl_counter)
        self._dl_handles[h] = name
        return h

    def dlsym(self, handle: int, symbol: str) -> Callable:
        name = self._dl_handles[handle]
        return self.runtime.module_symbol(name, symbol)

    def dlclose(self, handle: int) -> int:
        self._dl_handles.pop(handle, None)
        return 0

    # ------------------------------------------------------------------ memory --

    def mmap(self, length: int) -> int:
        return self.faaslet.mmap(length)

    def brk(self, new_brk: int) -> int:
        return self.faaslet.brk(new_brk)

    def sbrk(self, delta: int) -> int:
        return self.faaslet.sbrk(delta)

    # ------------------------------------------------------------------ network --

    def socket(self) -> int:
        fd = next(self._fd_counter)
        self._fds[fd] = {"kind": "socket", "peer": None, "rx": []}
        return fd

    def connect(self, fd: int, address: str) -> int:
        sock = self._fds.get(fd)
        if sock is None or sock["kind"] != "socket":
            raise OSError("bad socket fd")
        if address.startswith("unix:"):
            raise OSError("AF_UNIX not permitted")          # §3.2 networking
        sock["peer"] = address
        return 0

    def send(self, fd: int, data: bytes) -> int:
        sock = self._fds[fd]
        if sock["peer"] is None:
            raise OSError("not connected")
        self.faaslet.usage.charge_net(n_out=len(data))      # traffic shaping
        self.runtime.deliver_network(self.host.id, sock["peer"], bytes(data))
        return len(data)

    def recv(self, fd: int, max_len: int) -> bytes:
        sock = self._fds[fd]
        data = self.runtime.receive_network(self.host.id, sock["peer"], max_len)
        self.faaslet.usage.charge_net(n_in=len(data))
        return data

    # ------------------------------------------------------------------ file I/O --

    def open(self, path: str, mode: str = "r") -> int:
        vfs = self.runtime.vfs
        if "w" not in mode and not vfs.exists(self.host.id, path):
            raise FileNotFoundError(path)
        fd = next(self._fd_counter)
        self._fds[fd] = {"kind": "file", "path": path, "pos": 0, "mode": mode}
        return fd

    def read(self, fd: int, length: int) -> bytes:
        f = self._fds[fd]
        data = self.runtime.vfs.read(self.host.id, f["path"])
        out = data[f["pos"]:f["pos"] + length]
        f["pos"] += len(out)
        return out

    def write(self, fd: int, data: bytes) -> int:
        f = self._fds[fd]
        if "w" not in f["mode"] and "a" not in f["mode"]:
            raise PermissionError("fd not writable")
        self.runtime.vfs.write_local(self.host.id, f["path"], bytes(data),
                                     append=("a" in f["mode"] or f["pos"] > 0))
        f["pos"] += len(data)
        return len(data)

    def stat(self, path: str) -> dict:
        vfs = self.runtime.vfs
        if not vfs.exists(self.host.id, path):
            raise FileNotFoundError(path)
        return {"size": len(vfs.read(self.host.id, path))}

    def dup(self, fd: int) -> int:
        new = next(self._fd_counter)
        self._fds[new] = dict(self._fds[fd])
        return new

    def close(self, fd: int) -> int:
        self._fds.pop(fd, None)
        return 0

    # ------------------------------------------------------------------ misc --

    def gettime(self) -> int:
        """Per-call monotonic clock (ns since call start)."""
        return time.monotonic_ns() - self._t0

    def getrandom(self, n: int) -> bytes:
        return os.urandom(n)
