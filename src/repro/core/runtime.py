"""FAASM runtime: hosts, calls, chaining, fault tolerance (Faasm §5).

A :class:`FaasmRuntime` manages a cluster of :class:`Host` instances (each a
runtime instance with its own local tier, local scheduler, Faaslet pool and
executor threads).  Functions are uploaded once (validation → codegen →
Proto-Faaslet generation, §3.4/§5.2) and then invoked/chained from anywhere.

Isolation modes (the paper's §6 comparison, same application code):
  * ``faaslet``   — co-located functions share the host local tier zero-copy;
                    cold starts restore Proto-Faaslets.
  * ``container`` — the Knative-like baseline: every Faaslet gets a *private*
                    tier (state is copied in/out — data shipping), cold starts
                    re-run init code, per-instance memory overhead is
                    container-sized.

Fault tolerance: heartbeat-based failure detection, re-execution of calls
lost on dead hosts, speculative re-execution of stragglers (work sharing),
elastic add/remove of hosts.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
import zlib
from collections import defaultdict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro import cancellation, faults
from repro import overload as oload
from repro.analysis.sanitizer import make_mutex
from repro.core.faaslet import (CONTAINER_OVERHEAD_BYTES,
                                FAASLET_OVERHEAD_BYTES, Faaslet)
from repro.core.host_interface import (CallCancelled, DeadlineExceeded,
                                       FaasmAPI)
from repro.core.proto import ExecutableCache, ProtoFaaslet
from repro.core.scheduler import LocalScheduler
from repro.core.vfs import VirtualFS
from repro.state import wire as _wire_mod
from repro.state.kv import GlobalTier
from repro.state.local import LocalTier
from repro.telemetry import clock as tclock
from repro.telemetry import metrics as tmetrics

_call_ids = itertools.count(1)

# Telemetry hook state, installed by repro.telemetry.enable(); every hook
# site below is guarded by one pointer compare — zero ring writes disarmed
# (asserted by scripts/check_jax_pin.py).
_TEL = None

try:
    import resource as _resource
    _PAGE_SIZE = _resource.getpagesize()
except ImportError:  # pragma: no cover - CPython always ships resource on linux
    _PAGE_SIZE = 4096


def _proc_rss_bytes() -> Optional[int]:
    """The process's real resident set size from ``/proc/self/statm``
    (field 2, in pages), or ``None`` where procfs is unavailable — callers
    fall back to the tier/Faaslet bookkeeping estimate."""
    try:
        with open("/proc/self/statm") as fh:
            return int(fh.read().split()[1]) * _PAGE_SIZE
    except (OSError, IndexError, ValueError):
        return None


@dataclass
class FunctionDef:
    """An uploaded function: the 'WebAssembly module' analogue."""

    name: str
    fn: Callable[[FaasmAPI], int]               # returns a status code
    init_fn: Optional[Callable[[FaasmAPI], Any]] = None
    memory_limit: int = 64 * 65536
    cpu_budget_ns: Optional[int] = None
    net_budget: Optional[int] = None
    # dequeue shed floor: a deadlined call whose remaining budget is below
    # this when it reaches the front of a host queue is shed (DEADLINE_RC)
    # instead of burning an executor slot on work that can't finish in time.
    # 0.0 defers to OverloadPolicy.deadline_floor_s.
    deadline_floor_s: float = 0.0


@dataclass
class Call:
    id: int
    fn: str
    input: bytes
    status: str = "pending"                      # pending|running|done|failed
    output: bytes = b""
    return_code: int = -1
    host: Optional[str] = None
    parent: Optional[int] = None
    attempts: int = 0
    cold_start: bool = False
    t_submit: float = 0.0
    t_start: float = 0.0
    t_end: float = 0.0
    error: str = ""
    twin_id: Optional[int] = None                # speculative re-execution
    primary_id: Optional[int] = None             # set on twins: who to adopt into
    # end-to-end deadline (repro.overload.Deadline), inherited by chained
    # children.  None — the overwhelmingly common case — keeps every
    # deadline hook site at one pointer compare.
    deadline: Optional[oload.Deadline] = None
    # attempt fencing (exactly-once state effects): every physical execution
    # of this logical call — first dispatch, requeue after host loss, or a
    # speculative twin — carries a distinct epoch drawn from the *primary*
    # call's counter.  The global tier rejects delta pushes from superseded
    # or sealed epochs, so re-execution can't double-apply state.
    fence_epoch: int = 0                         # epoch of the current attempt
    _epoch_counter: int = 0                      # allocator (primaries only)
    event: threading.Event = field(default_factory=threading.Event)
    # cooperative cancel: set when this execution's speculative counterpart
    # already settled; checked by FaasmAPI at chain/await/state points
    cancel_event: threading.Event = field(default_factory=threading.Event)
    _cb_lock: threading.Lock = field(default_factory=threading.Lock,
                                     repr=False)
    _callbacks: List[Callable[["Call"], None]] = field(default_factory=list,
                                                       repr=False)

    @property
    def latency(self) -> float:
        return (self.t_end or tclock.now()) - self.t_submit

    @property
    def queue_wait(self) -> float:
        """Submit → start of the winning attempt, on the telemetry clock
        (all three stamps come from ``repro.telemetry.clock``, so the
        difference is well-defined by construction)."""
        if not self.t_start:
            return 0.0
        return max(self.t_start - self.t_submit, 0.0)

    @property
    def exec_wall(self) -> float:
        """Start → settle of the current/last attempt (running calls
        report elapsed-so-far)."""
        if not self.t_start:
            return 0.0
        return (self.t_end or tclock.now()) - self.t_start

    @property
    def fence_id(self) -> str:
        """Logical-call identity for attempt fencing: a speculative twin
        writes state under its primary's id, so both race for one fence."""
        base = self.id if self.primary_id is None else self.primary_id
        return f"c{base}"

    def alloc_epoch(self) -> int:
        """Next attempt epoch.  Call on the *primary* only — twins draw
        their epochs from the primary's counter (shared fence)."""
        with self._cb_lock:
            self._epoch_counter += 1
            return self._epoch_counter

    def add_done_callback(self, cb: Callable[["Call"], None]) -> None:
        """Run ``cb(call)`` once the call completes (immediately if done)."""
        with self._cb_lock:
            if not self.event.is_set():
                self._callbacks.append(cb)
                return
        cb(self)

    def _settle(self, mutate: Callable[["Call"], None]) -> bool:
        """Atomically apply the final result fields and mark the call done.

        Only the first settle wins: a late completion (e.g. a straggler whose
        speculative twin already adopted its result into us) must not
        overwrite what waiters have observed.  Returns False if already done.
        """
        with self._cb_lock:
            if self.event.is_set():
                return False
            mutate(self)
            self.event.set()
            cbs, self._callbacks = self._callbacks, []
        for cb in cbs:
            cb(self)
        return True


class Host:
    """One FAASM runtime instance (one server / TPU host)."""

    def __init__(self, host_id: str, runtime: "FaasmRuntime", *,
                 capacity: int = 8, isolation: str = "faaslet",
                 reclaim: str = "auto",
                 reclaim_rss_bytes: int = 256 << 20,
                 max_queue_depth: Optional[int] = None):
        self.id = host_id
        self.runtime = runtime
        self.capacity = capacity
        # bounded admission: at most capacity + max_queue_depth calls may be
        # in flight (running + queued); submit() beyond that raises
        # overload.QueueFull for the dispatcher to spill or shed.  None
        # keeps the queue unbounded (today's behaviour).
        self.max_queue_depth = max_queue_depth
        self.isolation = isolation
        # CoW page-reclaim policy for the §5.2 post-call reset: "always"
        # madvises every dirty page back (lowest RSS, next call refaults),
        # "never" re-stamps in place (hot Faaslets stay refault-free), and
        # "auto" reclaims only when host RSS exceeds ``reclaim_rss_bytes``
        # (the warm pool is LIFO, so the Faaslet being reset is the hot one).
        # "auto" pressure reads the process's real RSS growth since this
        # host came up (/proc/self/statm), falling back to the tier+Faaslet
        # bookkeeping estimate where procfs is unavailable — the baseline
        # delta keeps the interpreter's own footprint (jax alone dwarfs the
        # default threshold) out of the signal.
        self.reclaim = reclaim
        self.reclaim_rss_bytes = reclaim_rss_bytes
        self._rss_baseline = _proc_rss_bytes()
        self.local_tier = LocalTier(host_id, runtime.global_tier)
        self._container_tiers: Dict[int, LocalTier] = {}
        self._warm: Dict[str, List[Faaslet]] = defaultdict(list)
        self._user_state: Dict[int, Any] = {}
        self._mutex = make_mutex("host", f"host:{host_id}")
        self._inflight = 0
        self.alive = True
        self.pool = ThreadPoolExecutor(max_workers=capacity,
                                       thread_name_prefix=f"host-{host_id}")
        self.heartbeat = time.monotonic()
        # metrics
        self.cold_starts = 0
        self.warm_hits = 0
        self.resets = 0                  # §5.2 post-call resets performed
        self.reset_pages = 0             # dirty pages re-stamped across resets
        self.reclaimed_pages = 0         # dirty pages madvise'd back (CoW path)
        self.retained_pages = 0          # dirty pages re-stamped, kept resident
        self.cancelled_execs = 0         # speculative losers stopped early
        self.rejected_submits = 0        # bounded-queue admission refusals
        self.init_seconds: List[float] = []
        self.billable_byte_seconds = 0.0
        self.calls_done = 0

    # -- capacity / liveness -----------------------------------------------------

    def has_capacity(self) -> bool:
        with self._mutex:
            return self.alive and self._inflight < self.capacity

    def has_room(self) -> bool:
        """Would :meth:`submit` admit a call right now?  Unlike
        ``has_capacity`` (free executor slot), this is the bounded-queue
        admission bound: running + queued below capacity + max_queue_depth.
        Always True for unbounded hosts."""
        with self._mutex:
            if not self.alive:
                return False
            if self.max_queue_depth is None:
                return True
            return self._inflight < self.capacity + self.max_queue_depth

    def queue_depth(self) -> int:
        """Calls admitted but not yet running (executor backlog)."""
        with self._mutex:
            return max(0, self._inflight - self.capacity)

    def beat(self):
        self.heartbeat = time.monotonic()

    # -- tiers -------------------------------------------------------------------

    def local_tier_for(self, faaslet: Faaslet) -> LocalTier:
        if self.isolation == "container":
            with self._mutex:
                t = self._container_tiers.get(faaslet.id)
                if t is None:
                    t = LocalTier(f"{self.id}/c{faaslet.id}",
                                  self.runtime.global_tier)
                    # container pulls are charged to the host for metrics
                    t.host_id = self.id
                    self._container_tiers[faaslet.id] = t
                return t
        return self.local_tier

    def memory_bytes(self) -> int:
        """Host resident footprint: shared tier + per-instance overheads.
        CoW bases are charged once per host, not once per Faaslet."""
        with self._mutex:
            warm = [f for fl in self._warm.values() for f in fl]
            per_inst = sum(f.memory_bytes() for f in warm)
            bases = dict(fp for fp in (f.base_footprint() for f in warm)
                         if fp is not None)
            per_inst += sum(bases.values())
            if self.isolation == "container":
                per_inst += sum(t.memory_bytes()
                                for t in self._container_tiers.values())
                per_inst += CONTAINER_OVERHEAD_BYTES * max(
                    1, sum(len(fl) for fl in self._warm.values()))
            return self.local_tier.memory_bytes() + per_inst

    # -- execution -------------------------------------------------------------

    def submit(self, call: Call):
        # chaos hook: an armed queue-flood rule makes this admission behave
        # as if the bounded queue were full (outside the mutex — the armed
        # path may sleep, and lock-blocking forbids that under a lock)
        flooded = faults.point("queue-flood", call=call.id, host=self.id)
        with self._mutex:
            if not self.alive:
                raise RuntimeError(f"host {self.id} is down")
            if flooded or (self.max_queue_depth is not None
                           and self._inflight >=
                           self.capacity + self.max_queue_depth):
                self.rejected_submits += 1
                raise oload.QueueFull(
                    f"host {self.id} admission queue full "
                    f"({self._inflight} in flight)")
            # Claim the call for this host *before* it reaches the pool:
            # if the host dies while the call is still queued (never ran),
            # ``_requeue_lost`` must still find and re-dispatch it.
            call.host = self.id
            self._inflight += 1
        self.pool.submit(self._run_guarded, call)

    def _run_guarded(self, call: Call):
        try:
            self._run(call)
        except faults.HostCrash:
            # injected fail-stop: the call is NOT settled — the host dies
            # and its in-flight work (this call included) is requeued
            # elsewhere with a fresh fence epoch, exactly like an external
            # ``fail_host``.  Fencing makes the re-execution exactly-once.
            self.runtime.fail_host(self.id)
        except Exception as e:                    # defensive: never lose a call
            self.runtime._finish_call(call, rc=1, status="failed",
                                      error=f"host crash: {e!r}")
        finally:
            tel = _TEL
            if tel is not None:
                tel.clear_ctx()                  # executor thread is reused
            with self._mutex:
                self._inflight -= 1

    def _acquire_faaslet(self, fdef: FunctionDef):
        with self._mutex:
            pool = self._warm[fdef.name]
            if pool:
                self.warm_hits += 1
                return pool.pop(), False
        # cold start
        t0 = tclock.now()
        proto = self.runtime.proto_for(fdef.name, host=self.id)
        if proto is not None and self.isolation == "faaslet":
            f, user_state = proto.restore(self.id)
            self._user_state[f.id] = user_state
        else:
            f = Faaslet(fdef.name, self.id, memory_limit=fdef.memory_limit,
                        cpu_budget_ns=fdef.cpu_budget_ns,
                        net_budget=fdef.net_budget)
            if fdef.init_fn is not None:          # container path re-inits
                api = FaasmAPI(f, self, self.runtime, _InitCall())
                self._user_state[f.id] = fdef.init_fn(api)
        dt = tclock.now() - t0
        with self._mutex:
            self.cold_starts += 1
            self.init_seconds.append(dt)
        return f, True

    def user_state(self, faaslet: Faaslet) -> Any:
        return self._user_state.get(faaslet.id)

    def _run(self, call: Call):
        self.beat()
        rt = self.runtime
        fdef = rt.functions[call.fn]
        dl = call.deadline
        if dl is not None:
            # dequeue shed: a call that waited out (most of) its budget in
            # the queue is settled DEADLINE_RC here instead of occupying an
            # executor slot it can't finish in.  The skew point lets chaos
            # runs evaporate the budget between queue and check.
            faults.point("deadline-clock-skew", call=call.id, host=self.id)
            floor = fdef.deadline_floor_s
            ovl = rt.overload
            if floor <= 0.0 and ovl is not None:
                floor = ovl.deadline_floor_s
            if dl.remaining() <= floor:
                rt._count_overload("deadline_total")
                rt._finish_call(call, rc=oload.DEADLINE_RC, status="deadline",
                                error="deadline expired before execution")
                return
        call.host = self.id
        call.status = "running"
        call.t_start = tclock.now()
        # attempt identity: if the runtime supersedes this epoch mid-flight
        # (host declared dead, call requeued), this attempt is a zombie and
        # must not settle the call — see the guard before _finish_call below
        my_epoch = call.fence_epoch
        tel = _TEL
        if tel is not None:
            # trace context for everything this attempt does on this
            # thread (wire frames, fault hits, kernel work): twins and
            # retries share the primary's fence with distinct epochs, so
            # their spans group as siblings of one logical call
            tel.set_ctx(call=call.id, fence=call.fence_id,
                        epoch=call.fence_epoch, host=self.id)
            tel.record("call.queue", "call", call.t_submit, call.t_start,
                       fn=call.fn, attempt=call.attempts)
        faaslet, cold = self._acquire_faaslet(fdef)
        call.cold_start = cold
        if tel is not None:
            # restore = proto arena bind (cold) or warm-pool pop (~0)
            tel.record("call.restore", "call", call.t_start, tclock.now(),
                       fn=call.fn, cold=cold)
        api = FaasmAPI(faaslet, self, rt, call)
        t0 = tclock.now()
        faults.point("slow-host", call=call.id, host=self.id)
        # arm the time-sliced cancel checkpoint: kernel dispatch wrappers
        # call it, so pure-compute loops between host-interface calls also
        # honour cancel_event within a bounded slice.  The checkpoint also
        # beats the host heartbeat, so a long kernel loop doesn't read as a
        # dead host to a short ``heartbeat_timeout``.
        cancellation.install(api.check_cancelled, beat=self.beat,
                             budget=dl.remaining if dl is not None else None)
        try:
            ret = fdef.fn(api)
            rc = int(ret) if ret is not None else 0
            status = "done" if rc == 0 else "failed"
            error = ""
        except faults.HostCrash:
            # injected fail-stop: the whole host dies with the call mid-
            # flight — no settling, no cleanup; _run_guarded turns this
            # into a host failure + requeue, like an external fail_host
            raise
        except DeadlineExceeded as e:
            # end-to-end deadline hit mid-execution: same cooperative
            # unwind as a cancel, distinct return code for waiters.  The
            # cleanup below discards un-pushed deltas; already-pushed ones
            # stay exactly-once under the attempt fence.
            rt._count_overload("deadline_total")
            rc, status, error = oload.DEADLINE_RC, "deadline", repr(e)
        except CallCancelled as e:
            # speculative counterpart already settled: stop quietly and free
            # the executor slot (the result everyone sees was adopted already)
            rc, status, error = 1, "cancelled", repr(e)
        except Exception as e:
            rc, status, error = 1, "failed", repr(e)
        finally:
            cancellation.clear()                 # executor thread is reused
        t_end = tclock.now()
        if tel is not None:
            tel.record("call.exec", "call", t0, t_end, fn=call.fn,
                       status=status, rc=rc, cold=cold)
        dur = t_end - t0
        faaslet.usage.charge_cpu(int(dur * 1e9))
        faaslet.calls_served += 1

        # billable memory (GB·s attribution, §6.1 "billable memory")
        overhead = (CONTAINER_OVERHEAD_BYTES if self.isolation == "container"
                    else FAASLET_OVERHEAD_BYTES)
        priv = faaslet.memory_bytes() - FAASLET_OVERHEAD_BYTES + overhead
        if self.isolation == "container":
            priv += self.local_tier_for(faaslet).memory_bytes()
        with self._mutex:
            self.billable_byte_seconds += dur * priv
            self.calls_done += 1
            if status == "cancelled":
                self.cancelled_execs += 1

        # failed call in container mode: drop the private tier (and any
        # half-written replica) so a retry re-pulls clean state
        if self.isolation == "container" and status != "done":
            with self._mutex:
                self._container_tiers.pop(faaslet.id, None)
        # failed call in faaslet mode: the host tier is shared, so it can't
        # be dropped wholesale — instead resync any key this call dirtied
        # but never pushed back to global truth, so a half-written delta
        # doesn't leak into the next call's view (or a later push)
        if self.isolation == "faaslet" and status != "done":
            for k in api.dirtied_keys():
                self.local_tier.discard_unpushed(k)

        # §5.2: reset from Proto-Faaslet so no private data leaks across
        # calls — O(dirty pages) when the Faaslet carries a CoW base
        proto = rt.proto_for(call.fn, host=self.id, transfer=False)
        if proto is not None and self.isolation == "faaslet":
            t0_reset = tclock.now()
            if faaslet.has_base():
                reclaimed0 = faaslet.reclaimed_pages
                retained0 = faaslet.retained_pages
                pressure = False
                if self.reclaim == "auto":
                    # the warm pool is LIFO (this Faaslet is appended last
                    # and popped first), so a returning Faaslet is the HOT
                    # one — keep it refault-free unless host RSS actually
                    # crossed the threshold.  Real RSS growth since host
                    # init (procfs) is the ground truth; the bookkeeping
                    # estimate (memory_bytes() counts only pooled Faaslets,
                    # so add the one being reset — its dirty pages are
                    # exactly what reclaim would return) is the fallback.
                    rss = _proc_rss_bytes()
                    if rss is not None and self._rss_baseline is not None:
                        pressure = (rss - self._rss_baseline
                                    >= self.reclaim_rss_bytes)
                    else:
                        pressure = (self.memory_bytes()
                                    + faaslet.memory_bytes()
                                    >= self.reclaim_rss_bytes)
                pages = faaslet.reset_from_base(reclaim=self.reclaim,
                                                pressure=pressure)
                reclaimed = faaslet.reclaimed_pages - reclaimed0
                retained = faaslet.retained_pages - retained0
            else:
                faaslet.restore_arena(proto.arena, proto.brk)
                pages = len(faaslet.dirty_pages)
                faaslet.clear_dirty()
                reclaimed = retained = 0
            with self._mutex:
                self.resets += 1
                self.reset_pages += pages
                self.reclaimed_pages += reclaimed
                self.retained_pages += retained
            if tel is not None:
                tel.record("call.reset", "call", t0_reset, tclock.now(),
                           pages=pages, reclaimed=reclaimed,
                           retained=retained)
        with self._mutex:
            if self.alive:
                self._warm[call.fn].append(faaslet)
        self.beat()
        if my_epoch and (call.fence_epoch != my_epoch
                         or rt.global_tier.fence_is_dead(call.fence_id,
                                                         my_epoch)):
            # Zombie attempt: the runtime gave up on this epoch (heartbeat
            # false positive / fail_host requeue) while the body was still
            # running.  Any push made after the supersede was fence-rejected,
            # so settling ``done`` here would report success for effects that
            # never landed — the re-dispatched epoch owns the settle.  The
            # supersede-before-redispatch ordering in _requeue_lost makes
            # this check sound: a push that was admitted implies the epoch
            # was live at push time, and an epoch still live *here* (after
            # the last push) was live for every push.
            return
        self.runtime._finish_call(call, rc=rc, status=status, error=error,
                                  t_end=t_end)

    # -- failure / drain ---------------------------------------------------------

    def fail(self):
        """Simulate host loss: local tier and warm pool are gone."""
        with self._mutex:
            self.alive = False
            self._warm.clear()
            self._container_tiers.clear()
        self.local_tier.drop()
        self.pool.shutdown(wait=False, cancel_futures=True)

    def drain(self):
        with self._mutex:
            self.alive = False
        self.pool.shutdown(wait=True)


class _InitCall:
    """Placeholder call context for init-code execution."""
    id = 0
    input = b""
    output = b""


class CompletionLatch:
    """Counts down once per completed call; waiters block on a single event.

    ``wait_all`` registers one latch across N calls instead of N sequential
    ``Event.wait`` rounds, so a thousand-call fan-out wakes its waiter once.
    """

    def __init__(self, n: int):
        self._lock = threading.Lock()
        self._remaining = n
        self._event = threading.Event()
        if n <= 0:
            self._event.set()

    def count_down(self, _call: Optional[Call] = None) -> None:
        with self._lock:
            self._remaining -= 1
            if self._remaining <= 0:
                self._event.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)


class BatchTimeout(TimeoutError):
    """A ``wait_all`` deadline passed with part of the batch outstanding.

    Carries the split as structured payload so a partial fan-out timeout is
    debuggable without tracing: ``pending`` is the ids still in flight (in
    batch order) and ``done`` maps each completed id to its return code."""

    def __init__(self, pending: List[int], done: Dict[int, int],
                 timeout: Optional[float]):
        self.pending = pending
        self.done = done
        self.timeout = timeout
        super().__init__(
            f"{len(pending)}/{len(pending) + len(done)} calls still "
            f"outstanding after {timeout}s: {pending}")


class FaasmRuntime:
    def __init__(self, n_hosts: int = 2, *, isolation: str = "faaslet",
                 use_proto: bool = True, capacity: int = 8,
                 chunk_size: int = 1 << 20,
                 straggler_timeout: Optional[float] = None,
                 heartbeat_timeout: Optional[float] = None,
                 reclaim: str = "auto",
                 max_retries: int = 2, backoff: float = 0.005,
                 overload: Optional[oload.OverloadPolicy] = None):
        # heartbeat_timeout: when set, the background monitor declares hosts
        # silent for that long (with calls in flight) dead and requeues their
        # work.  Opt-in: a host only beats at call boundaries (and at kernel
        # cancellation checkpoints), so any timeout shorter than a legitimate
        # call would hard-fail a healthy host.
        # max_retries: re-execution budget per call beyond the first attempt
        # (host loss or dispatch failure); backoff: base of the exponential
        # re-dispatch delay (attempt n sleeps backoff * 2^(n-1), capped).
        # overload: arms the overload control plane (bounded host queues,
        # default deadlines, retry budget, per-host circuit breakers — see
        # repro.overload.OverloadPolicy).  None, the default, leaves every
        # overload hook disarmed at one pointer compare.
        assert isolation in ("faaslet", "container")
        assert reclaim in ("auto", "always", "never")
        assert max_retries >= 0 and backoff >= 0.0
        self.isolation = isolation
        self.reclaim = reclaim
        self.use_proto = use_proto and isolation == "faaslet"
        self.global_tier = GlobalTier(chunk_size=chunk_size)
        self.vfs = VirtualFS(self.global_tier)
        self.exec_cache = ExecutableCache()
        self.functions: Dict[str, FunctionDef] = {}
        self._protos: Dict[str, ProtoFaaslet] = {}       # host-side proto cache
        self._modules: Dict[str, Dict[str, Callable]] = {}
        self.hosts: Dict[str, Host] = {}
        self.schedulers: Dict[str, LocalScheduler] = {}
        self._calls: Dict[int, Call] = {}
        self._active: set = set()                # ids of not-yet-completed calls
        self._rr = itertools.count()
        self._mutex = make_mutex("runtime")
        # virtual-socket mailboxes: bounded so a flooding sender backpressures
        # instead of growing an invisible unbounded backlog (bounded-queue
        # lint rule; depth is the factory default)
        self._net: Dict[tuple, queue.Queue] = defaultdict(oload.bounded_queue)
        self.straggler_timeout = straggler_timeout
        self.heartbeat_timeout = heartbeat_timeout
        self.max_retries = max_retries
        self.backoff = backoff
        self.max_attempts = max_retries + 1
        # overload control plane (all None/zero when disarmed)
        self.overload = overload
        self._retry_budget = overload.retry_budget if overload else None
        self._breakers: Optional[Dict[str, oload.CircuitBreaker]] = (
            {} if overload is not None and overload.breaker is not None
            else None)
        self.shed_total = 0              # admission refusals settled SHED_RC
        self.deadline_total = 0          # calls settled DEADLINE_RC
        self.spill_total = 0             # admissions spilled to a peer
        # one registry per runtime: hot paths keep their lock-local
        # counters; this collector snapshots them into gauges at scrape
        # time (metrics_text / cold_start_stats / benchmarks all read it)
        self.metrics = tmetrics.Registry()
        self._init_pub: Dict[str, int] = {}      # init_seconds scrape cursors
        self.metrics.register_collector(self._publish_metrics)
        for i in range(n_hosts):
            self.add_host(capacity=capacity)
        # Background monitor: straggler speculation + heartbeat failure
        # detection fire from here, so no waiter ever has to spin-poll.
        self._monitor_cv = threading.Condition()
        self._monitor_stop = False
        self._monitor_thread = threading.Thread(
            target=self._monitor_loop, name="faasm-monitor", daemon=True)
        self._monitor_thread.start()

    # -- cluster elasticity ------------------------------------------------------

    def add_host(self, capacity: int = 8) -> str:
        ovl = self.overload
        with self._mutex:
            hid = f"host{len(self.hosts)}"
            while hid in self.hosts:
                hid += "x"
            h = Host(hid, self, capacity=capacity, isolation=self.isolation,
                     reclaim=self.reclaim,
                     max_queue_depth=(ovl.max_queue_depth
                                      if ovl is not None else None))
            self.hosts[hid] = h
            self.schedulers[hid] = LocalScheduler(h, self)
            if self._breakers is not None:
                self._breakers[hid] = ovl.breaker()
            return hid

    def remove_host(self, host_id: str, drain: bool = True) -> None:
        h = self.hosts[host_id]
        if drain:
            h.drain()
        else:
            h.fail()
        self.schedulers[host_id].deregister_warm(host_id)

    def alive_hosts(self) -> List[Host]:
        return [h for h in self.hosts.values() if h.alive]

    # -- upload service (§3.4 + §5.2) -----------------------------------------------

    def upload(self, fdef: FunctionDef) -> None:
        """Validate, 'code-generate', and build the Proto-Faaslet."""
        if not callable(fdef.fn):
            raise TypeError("function body must be callable")
        self.functions[fdef.name] = fdef
        if self.use_proto:
            host = next(iter(self.alive_hosts()))
            f = Faaslet(fdef.name, host.id, memory_limit=fdef.memory_limit)
            api = FaasmAPI(f, host, self, _InitCall())
            user_state = fdef.init_fn(api) if fdef.init_fn else None
            proto = ProtoFaaslet.capture(f, user_state)
            # store in the global tier => restorable on any host (cross-host)
            self.global_tier.set(f"proto/{fdef.name}", proto.serialize(),
                                 host="upload")

    def proto_for(self, fn: str, *, host: str,
                  transfer: bool = True) -> Optional[ProtoFaaslet]:
        if not self.use_proto:
            return None
        with self._mutex:
            p = self._protos.get(fn)
        if p is None:
            key = f"proto/{fn}"
            if not self.global_tier.exists(key):
                return None
            data = (self.global_tier.get(key, host=host) if transfer
                    else self.global_tier.get(key, host="cache"))
            p = ProtoFaaslet.deserialize(data)
            with self._mutex:
                self._protos[fn] = p
        return p

    # -- modules (dlopen) --------------------------------------------------------

    def register_module(self, name: str, symbols: Dict[str, Callable]) -> None:
        self._modules[name] = dict(symbols)

    def has_module(self, name: str) -> bool:
        return name in self._modules

    def module_symbol(self, name: str, symbol: str) -> Callable:
        return self._modules[name][symbol]

    # -- invocation --------------------------------------------------------------

    def invoke(self, fn: str, input_data: bytes = b"",
               parent: Optional[Call] = None,
               deadline: Optional[Any] = None) -> int:
        return self.invoke_many(fn, [input_data], parent=parent,
                                deadline=deadline)[0]

    def _resolve_deadline(self, deadline, parent: Optional[Call]):
        """Deadline for a new batch: explicit (a Deadline, or a float budget
        in seconds) > inherited from the parent (same absolute expiry, so
        children get exactly the remaining budget) > the overload policy's
        default.  None everywhere — the common case — stays None."""
        if deadline is not None:
            if isinstance(deadline, oload.Deadline):
                return deadline
            return oload.Deadline.after(float(deadline))
        if parent is not None and parent.deadline is not None:
            return parent.deadline
        ovl = self.overload
        if ovl is not None and ovl.default_deadline_s:
            return oload.Deadline.after(ovl.default_deadline_s)
        return None

    def invoke_many(self, fn: str, inputs, parent: Optional[Call] = None,
                    state_hint: Optional[List[Any]] = None,
                    deadline: Optional[Any] = None) -> List[int]:
        """Submit one call per input in a single batch; returns all call IDs.

        The IDs come back in input order — pair with :meth:`wait_all` for
        thousand-call fan-outs without per-call round trips.

        ``state_hint`` optionally names the state keys the batch will touch:
        placement then prefers warm hosts whose local tier already holds
        those keys (Cloudburst-style locality awareness) before
        round-robining, avoiding a redundant global-tier pull per host.
        Two shapes are accepted: a flat list of keys shared by the whole
        batch (``["k"]``), or one entry *per call* — a key, a list of keys,
        or ``None`` (``[["a"], ["b"], None, ...]``, same length as
        ``inputs``).  Per-call hints rendezvous each call to the holder of
        **its own** key, so a fan-out over disjoint keys shards across the
        holder set instead of piling onto whichever host won the batch vote.

        ``deadline`` stamps an end-to-end expiry on every call in the batch:
        an :class:`repro.overload.Deadline`, or a float budget in seconds.
        Omitted, chained children inherit their parent's deadline and
        top-level calls take the overload policy's default (if armed).
        Expired work settles with ``overload.DEADLINE_RC`` at admission,
        dequeue, or the next mid-execution checkpoint.
        """
        if fn not in self.functions:
            raise KeyError(f"function {fn!r} not uploaded")
        pid = parent.id if parent is not None else None
        dl = self._resolve_deadline(deadline, parent)
        calls = []
        with self._mutex:
            for inp in inputs:
                call = Call(id=next(_call_ids), fn=fn, input=bytes(inp),
                            parent=pid, t_submit=tclock.now(), deadline=dl)
                self._calls[call.id] = call
                self._active.add(call.id)
                calls.append(call)
        self._dispatch_batch(calls, state_hint=state_hint)
        self._kick_monitor()
        return [c.id for c in calls]

    # -- overload control plane helpers ---------------------------------------

    def _count_overload(self, counter: str) -> None:
        with self._mutex:
            setattr(self, counter, getattr(self, counter) + 1)

    def _breaker_allows(self, host_id: str) -> bool:
        """Scheduler-side breaker consult.  Disarmed: one pointer compare."""
        brs = self._breakers
        if brs is None:
            return True
        br = brs.get(host_id)
        return br is None or br.allow()

    def _admit_expired(self, call: Call) -> bool:
        """Admission-time deadline gate: settle already-expired work with
        DEADLINE_RC before it touches a host queue.  True = rejected."""
        dl = call.deadline
        if dl is None or not dl.expired():
            return False
        self._count_overload("deadline_total")
        self._finish_call(call, rc=oload.DEADLINE_RC, status="deadline",
                          error="deadline expired before admission")
        return True

    def _spill_or_shed(self, call: Call, tried: set) -> None:
        """A bounded host queue refused ``call``: spill down the rendezvous
        ranking to the first peer with room (admission policy permitting),
        else settle fast with SHED_RC.  Shed calls never wait — failing in
        microseconds is the point."""
        ovl = self.overload
        mode = ovl.admission.on_full(call) if ovl is not None else "spill"
        if mode == "spill":
            peers = [h for h in self.alive_hosts()
                     if h.id not in tried and h.has_room()
                     and self._breaker_allows(h.id)]
            # rendezvous order (crc32 max wins) keeps the spill target for
            # a given call stable regardless of which host refused it first
            peers.sort(key=lambda h: zlib.crc32(f"{call.id}@{h.id}".encode()),
                       reverse=True)
            for h in peers:
                try:
                    self._assign_epoch(call)
                    h.submit(call)
                    self._count_overload("spill_total")
                    return
                except oload.QueueFull:
                    tried.add(h.id)
                except Exception:
                    tried.add(h.id)
        self._count_overload("shed_total")
        self._finish_call(call, rc=oload.SHED_RC, status="shed",
                          error="admission queue full, no peer had room")

    @staticmethod
    def _rank_holders(state_hint: List[str], holders: List[Host]) -> List[Host]:
        """Order replica holders for a batch: consistent-hash pinning.

        Each hint key is pinned to one holder by rendezvous hashing
        (``crc32(key@host)`` max wins), so the same key lands on the same
        holder batch after batch — its replica stays hot there instead of
        being re-warmed round-robin across the holder set.  Holders are
        ranked by how many of the batch's keys pin to them (tie-broken by
        the hash itself, keeping the order deterministic)."""
        votes = {h.id: 0 for h in holders}
        for k in state_hint:
            win = max(holders,
                      key=lambda h: zlib.crc32(f"{k}@{h.id}".encode()))
            votes[win.id] += 1
        return sorted(
            holders,
            key=lambda h: (votes[h.id],
                           zlib.crc32(f"{state_hint[0]}@{h.id}".encode())),
            reverse=True)

    def _dispatch_batch(self, calls: List[Call],
                        state_hint: Optional[List[Any]] = None) -> None:
        """Place a homogeneous batch with one warm-set resolution.

        Single calls keep the full Omega placement; for a fan-out the warm
        host set is read once and the batch round-robins across it, so
        thousand-call waves don't pay a placement lookup per call.  When the
        batch declares the state keys it touches (``state_hint``), warm
        hosts already holding replicas of those keys are preferred: the
        keys are **pinned** to holders by consistent hashing (rendezvous —
        stable across batches, so a key's replica stays hot on one host)
        and each call goes to the first pinned holder with capacity
        (``has_capacity`` is re-read per call, so an over-capacity batch
        spills down the pinned ranking instead of queueing blindly).

        A *per-call* hint (one entry per call — key, key list, or ``None``)
        pins each call by **its own** keys' rendezvous ranking rather than
        the batch vote, so fan-outs over disjoint keys shard across the
        holder set — call i chasing ``"a"`` lands where ``"a"``'s replica
        is hot even while call j chasing ``"b"`` lands elsewhere.  Only
        when nobody holds anything does the batch fall back to
        round-robining the warm pool."""
        if not calls:
            return
        if len(calls) == 1 and not state_hint:
            self._dispatch(calls[0])
            return
        fn = calls[0].fn
        alive = self.alive_hosts()
        if not alive:
            for c in calls:
                self._finish_call(c, status="failed", error="no alive hosts")
            return
        # breaker-aware entry choice: a cold batch registers its warm set on
        # the entry host, so picking a tripped host here would park the whole
        # fan-out behind an open breaker (fail open when every breaker is)
        candidates = alive
        if self._breakers is not None:
            allowed = [h for h in alive if self._breaker_allows(h.id)]
            if allowed:
                candidates = allowed
        entry = candidates[next(self._rr) % len(candidates)]
        sched = self.schedulers[entry.id]
        pool = [self.hosts[h] for h in sched.warm_hosts(fn)
                if h in self.hosts and self.hosts[h].alive]
        if not pool:
            sched.register_warm(fn)          # batch cold-starts on the entry
            pool = [entry]
        # batch-aware warm-set growth: a fan-out bigger than the pool's free
        # executor capacity cold-starts additional alive hosts (registering
        # them warm) instead of piling the whole batch behind a handful of
        # busy executors — without this the warm set never grows past the
        # first entry host and a 6-host cluster serves fan-outs at the
        # concurrency of one
        def free_slots():
            return sum(max(0, h.capacity - h._inflight) for h in pool)
        if len(calls) > free_slots():
            in_pool = {h.id for h in pool}
            for h in candidates:
                if h.id not in in_pool:
                    self.schedulers[h.id].register_warm(fn)
                    pool.append(h)
                    in_pool.add(h.id)
                    if len(calls) <= free_slots():
                        break
        # circuit breakers: open hosts leave the candidate pool; if every
        # candidate is open, fail open and keep the pool (refusing all
        # placement would turn a breaker trip into a total outage)
        if self._breakers is not None:
            allowed = [h for h in pool if self._breaker_allows(h.id)]
            if allowed:
                pool = allowed
        # hint shape: flat list = one key set for the whole batch; any
        # list/tuple/None entry = per-call hints, one entry per call
        per_call = None
        flat_hint: List[str] = []
        if state_hint:
            if any(isinstance(h, (list, tuple)) or h is None
                   for h in state_hint):
                per_call = [([h] if isinstance(h, str) else list(h or []))
                            for h in state_hint]
                flat_hint = [k for ks in per_call for k in ks]
            else:
                flat_hint = list(state_hint)
        pinned = None
        holders: List[Host] = []
        if flat_hint:
            holders = [h for h in pool
                       if any(h.local_tier.has(k) for k in flat_hint)]
            if holders and per_call is None:
                pinned = self._rank_holders(flat_hint, holders)
        rank_cache: dict = {}
        n = len(pool)
        for i, c in enumerate(calls):
            if self._admit_expired(c):
                continue
            c.attempts += 1
            self._assign_epoch(c)
            ranked = pinned
            if per_call is not None and holders:
                keys = tuple(per_call[i]) if i < len(per_call) else ()
                if keys:
                    ranked = rank_cache.get(keys)
                    if ranked is None:
                        # prefer hosts already holding *this call's* keys;
                        # a cold key still rendezvous-pins among the batch
                        # holders so it warms on one stable host
                        own = [h for h in holders
                               if any(h.local_tier.has(k) for k in keys)]
                        ranked = self._rank_holders(list(keys), own or holders)
                        rank_cache[keys] = ranked
                else:
                    ranked = None
            if ranked is not None:
                # first pinned holder with capacity; when every holder is
                # saturated, round-robin the queueing across the holder set
                # (locality kept) instead of piling on the top-ranked one
                target = next((h for h in ranked if h.has_capacity()),
                              ranked[i % len(ranked)])
            else:
                target = pool[i % n]
            try:
                target.submit(c)
            except oload.QueueFull:
                self._spill_or_shed(c, {target.id})
            except Exception:
                self._dispatch(c)            # full path: re-place or fail

    def _assign_epoch(self, call: Call) -> None:
        """Stamp this physical dispatch with a fresh fence epoch, always
        drawn from the primary call's allocator (twins share the fence)."""
        owner = call
        if call.primary_id is not None:
            owner = self._calls.get(call.primary_id, call)
        call.fence_epoch = owner.alloc_epoch()

    def _retry_backoff(self, attempts: int) -> None:
        """Exponential re-dispatch delay: attempt n waits backoff·2^(n-1),
        capped at 250 ms so a lost host never stalls recovery for long."""
        if self.backoff > 0.0 and attempts > 0:
            time.sleep(min(self.backoff * (2 ** (attempts - 1)), 0.25))

    def _dispatch(self, call: Call) -> None:
        if self._admit_expired(call):
            return
        alive = self.alive_hosts()
        if not alive:
            self._finish_call(call, status="failed", error="no alive hosts")
            return
        # round-robin entry point, then Omega placement (§5.1)
        entry = alive[next(self._rr) % len(alive)]
        target = self.schedulers[entry.id].place(call)
        if not target.alive:
            target = entry
        if not self._breaker_allows(target.id):
            # open breaker: reroute to any closed/half-open host; if every
            # breaker is open, fail open and keep the placement
            rerouted = next((h for h in alive if h.id != target.id
                             and self._breaker_allows(h.id)), None)
            if rerouted is not None:
                target = rerouted
        call.attempts += 1
        self._assign_epoch(call)
        try:
            target.submit(call)
        except oload.QueueFull:
            self._spill_or_shed(call, {target.id})
        except Exception as e:
            # target died between placement and submit: retry elsewhere, and
            # never leave the call pending (a waiter would hang forever)
            rb = self._retry_budget
            if call.attempts < self.max_attempts and \
                    (rb is None or rb.try_spend()):
                self._retry_backoff(call.attempts)
                self._dispatch(call)
            else:
                self._finish_call(call, status="failed",
                                  error=f"dispatch failed: {e!r}")

    def wait(self, call_id: int, timeout: Optional[float] = None) -> int:
        """Block until the call completes.  Event-driven: latency is bounded
        by the work itself, not by a polling granularity."""
        call = self._calls[call_id]
        if not call.event.wait(timeout=timeout):
            raise TimeoutError(f"call {call_id} timed out")
        return call.return_code

    def wait_all(self, call_ids, timeout: Optional[float] = None) -> List[int]:
        """Wait for a batch of calls on one shared completion latch.

        Returns the calls' return codes in the order given; per-call failures
        are isolated (a failed call yields its nonzero code, others still
        complete).  On timeout raises :class:`BatchTimeout`, whose
        ``pending``/``done`` payload names exactly which calls are still
        outstanding and what the rest returned."""
        ids = list(call_ids)
        calls = [self._calls[cid] for cid in ids]
        latch = CompletionLatch(len(calls))
        for c in calls:
            c.add_done_callback(latch.count_down)
        if not latch.wait(timeout):
            pending = [c.id for c in calls if not c.event.is_set()]
            if pending:
                done = {c.id: c.return_code for c in calls
                        if c.event.is_set()}
                raise BatchTimeout(pending, done, timeout)
        return [c.return_code for c in calls]

    # -- completion (the single exit path for every call) ---------------------

    def _finish_call(self, call: Call, *, rc: Optional[int] = None,
                     status: str = "failed", error: str = "",
                     t_end: Optional[float] = None) -> None:
        """Settle ``call`` exactly once: write the final result fields, fire
        its event + callbacks, and adopt a winning twin's result into its
        primary.  Late completions (straggler finishing after its twin was
        adopted) are no-ops."""
        def mutate(c: Call) -> None:
            if rc is not None:
                c.return_code = rc
            c.status = status
            if error:
                c.error = error
            c.t_end = t_end if t_end is not None else tclock.now()

        first = call._settle(mutate)
        tel = _TEL
        if tel is not None and first:
            tel.instant("call.settle", "call", call=call.id,
                        fence=call.fence_id, epoch=call.fence_epoch,
                        host=call.host, status=call.status,
                        queue_wait=call.queue_wait,
                        exec_wall=call.exec_wall)
        with self._mutex:
            self._active.discard(call.id)
        # overload plane feedback (both hooks are one pointer compare when
        # disarmed): successes refill the retry budget, and every attributable
        # outcome feeds the executing host's circuit breaker.  Shed/deadline
        # settles say nothing about host health and are excluded.
        if first:
            rb = self._retry_budget
            if rb is not None and call.status == "done":
                rb.on_success()
            brs = self._breakers
            if brs is not None and call.host is not None \
                    and call.status in ("done", "failed"):
                br = brs.get(call.host)
                if br is not None:
                    br.record(call.status == "done")
        # exactly-once: the winning settle seals the call's fence, so any
        # still-running attempt (a speculative loser, a zombie on a host
        # declared dead) gets its remaining pushes rejected by the tier
        if first and call.status == "done" and call.fence_epoch:
            self.global_tier.fence_seal(call.fence_id, call.fence_epoch)
        # speculation cleanup: the first 'done' of a speculative pair cancels
        # the counterpart, so the straggler stops at its next host-interface
        # checkpoint instead of running to completion in an executor slot
        if first and call.status == "done":
            other_id = call.twin_id if call.twin_id is not None \
                else call.primary_id
            other = self._calls.get(other_id) if other_id is not None else None
            if other is not None:
                other.cancel_event.set()
        if call.primary_id is not None and call.status == "done":
            primary = self._calls.get(call.primary_id)
            if primary is not None:
                def adopt(p: Call) -> None:
                    p.output = call.output
                    p.return_code = call.return_code
                    p.status = "done"
                    p.t_end = call.t_end

                primary._settle(adopt)
                with self._mutex:
                    self._active.discard(primary.id)

    def output(self, call_id: int) -> bytes:
        return self._calls[call_id].output

    def call(self, call_id: int) -> Call:
        return self._calls[call_id]

    # -- fault tolerance -----------------------------------------------------------

    def fail_host(self, host_id: str) -> None:
        """Kill a host; in-flight calls are re-executed elsewhere."""
        h = self.hosts[host_id]
        h.fail()
        brs = self._breakers
        if brs is not None and host_id in brs:
            brs[host_id].trip()          # dead host: breaker opens outright
        self.schedulers[host_id].deregister_warm(host_id)
        self._requeue_lost(host_id)

    def _requeue_lost(self, host_id: str) -> None:
        with self._mutex:
            lost = [c for c in self._calls.values()
                    if c.host == host_id and not c.event.is_set()]
        rb = self._retry_budget
        for c in lost:
            if c.attempts >= self.max_attempts:
                self._finish_call(
                    c, status="failed",
                    error=f"host {host_id} lost, retries exhausted")
            elif rb is not None and not rb.try_spend():
                # retry budget dry: a fault storm must not amplify into a
                # retry storm — settle failed immediately, no backoff loop
                self._finish_call(
                    c, status="failed",
                    error=f"host {host_id} lost, retry budget exhausted")
            else:
                # fence off the lost attempt BEFORE re-dispatching: any
                # straggling push from the dead host's epoch (e.g. a frame
                # delayed on the wire) must lose to the re-execution
                if c.fence_epoch:
                    self.global_tier.fence_supersede(c.fence_id,
                                                     c.fence_epoch)
                c.status = "pending"
                c.host = None
                self._retry_backoff(c.attempts)
                self._dispatch(c)

    def _speculate(self, call: Call) -> bool:
        """Straggler mitigation: duplicate the call; first completion wins."""
        others = [h for h in self.alive_hosts()
                  if h.id != call.host and h.has_capacity()]
        if not others:
            return False
        twin = Call(id=next(_call_ids), fn=call.fn, input=call.input,
                    parent=call.parent, t_submit=tclock.now())
        twin.attempts = call.attempts
        twin.primary_id = call.id
        # the twin writes state under the primary's fence with its own
        # epoch: whichever attempt settles first seals the fence, and the
        # loser's in-flight pushes are dropped instead of double-applied
        twin.fence_epoch = call.alloc_epoch()
        with self._mutex:
            self._calls[twin.id] = twin
            self._active.add(twin.id)
        call.twin_id = twin.id
        others[0].submit(twin)
        return True

    def monitor_once(self, timeout: Optional[float] = None) -> List[str]:
        """Heartbeat sweep: declare silent hosts dead, requeue their calls."""
        timeout = timeout if timeout is not None else self.heartbeat_timeout
        if timeout is None:
            return []
        now = time.monotonic()
        dead = []
        for h in list(self.hosts.values()):
            if h.alive and now - h.heartbeat > timeout and \
                    h._inflight > 0:
                h.fail()
                self.schedulers[h.id].deregister_warm(h.id)
                self._requeue_lost(h.id)
                dead.append(h.id)
        return dead

    # -- background monitor (event-driven lifecycle, no waiter spinning) -------

    def _kick_monitor(self) -> None:
        with self._monitor_cv:
            self._monitor_cv.notify_all()

    def _monitor_interval(self) -> float:
        iv = 0.25
        if self.heartbeat_timeout:
            iv = min(iv, self.heartbeat_timeout / 4)
        if self.straggler_timeout:
            iv = min(iv, self.straggler_timeout / 4)
        return max(iv, 0.01)

    def _monitor_loop(self) -> None:
        while True:
            with self._mutex:
                idle = not self._active
            with self._monitor_cv:
                if self._monitor_stop:
                    return
                self._monitor_cv.wait(0.5 if idle else self._monitor_interval())
                if self._monitor_stop:
                    return
            try:
                self._monitor_sweep()
            except Exception:                    # never let the monitor die
                pass

    def _monitor_sweep(self) -> None:
        self.monitor_once()
        with self._mutex:
            active = [self._calls[cid] for cid in self._active
                      if cid in self._calls]
        # calls stranded on hosts that died without a requeue (e.g. a direct
        # Host.fail) are re-dispatched here
        stranded_hosts = set()
        for c in active:
            if c.host is not None and not c.event.is_set():
                h = self.hosts.get(c.host)
                if h is not None and not h.alive:
                    stranded_hosts.add(c.host)
        for hid in stranded_hosts:
            self._requeue_lost(hid)
        # straggler speculation: duplicate long-running calls (twins adopt
        # their result into the primary on completion)
        if self.straggler_timeout:
            now = tclock.now()
            for c in active:
                if (c.twin_id is None and c.primary_id is None
                        and c.status == "running" and not c.event.is_set()
                        and now - c.t_start > self.straggler_timeout):
                    self._speculate(c)

    # -- virtual networking (host interface sockets) ----------------------------------

    def deliver_network(self, src: str, dst: str, data: bytes) -> None:
        self._net[(dst, src)].put(data)

    def receive_network(self, host: str, peer: str, max_len: int) -> bytes:
        try:
            data = self._net[(host, peer)].get(timeout=1.0)
        except queue.Empty:
            return b""
        return data[:max_len]

    # -- metrics --------------------------------------------------------------------

    def billable_gb_seconds(self) -> float:
        return sum(h.billable_byte_seconds for h in self.hosts.values()) / 1e9

    def transfer_bytes(self) -> int:
        return self.global_tier.total_transfer()

    def _publish_metrics(self, reg: tmetrics.Registry) -> None:
        """Scrape-time collector: snapshot the fabric's lock-local counters
        into registry gauges.  Runs on every ``collect()`` (metrics_text,
        snapshot, cold_start_stats, the serve /metrics endpoint) — never on
        a hot path."""
        hosts = list(self.hosts.values())
        g = reg.gauge

        def _sum(attr):
            return sum(getattr(h, attr) for h in hosts)

        g("faasm_host_cold_starts_total",
          "proto-Faaslet restores from scratch").set(_sum("cold_starts"))
        g("faasm_host_warm_hits_total",
          "calls served from the warm pool").set(_sum("warm_hits"))
        g("faasm_host_resets_total",
          "§5.2 post-call dirty resets").set(_sum("resets"))
        g("faasm_host_reset_pages",
          "dirty pages re-stamped across resets").set(_sum("reset_pages"))
        g("faasm_host_reclaimed_pages",
          "dirty pages madvised back (CoW)").set(_sum("reclaimed_pages"))
        g("faasm_host_retained_pages",
          "dirty pages re-stamped, kept resident").set(_sum("retained_pages"))
        g("faasm_host_cancelled_execs_total",
          "speculative losers stopped early").set(_sum("cancelled_execs"))
        g("faasm_runtime_calls_done_total").set(_sum("calls_done"))
        g("faasm_host_billable_byte_seconds",
          "§6.1 billable memory integral").set(_sum("billable_byte_seconds"))
        with self._mutex:
            occupancy = sum(
                sum(len(fl) for fl in h._warm.values()) for h in hosts)
        g("faasm_host_warm_pool_count",
          "Faaslets resident in warm pools").set(occupancy)
        # init times: feed only the not-yet-scraped tail of each host's
        # init_seconds into the histogram (collectors run repeatedly)
        hist = reg.histogram("faasm_host_init_ms",
                             "proto restore + module init wall time")
        for h in hosts:
            seen = self._init_pub.get(h.id, 0)
            tail = h.init_seconds[seen:]
            self._init_pub[h.id] = seen + len(tail)
            for s in tail:
                hist.observe(1e3 * s)

        gt = self.global_tier
        g("faasm_tier_net_bytes",
          "wire bytes moved through the global tier").set(gt.total_transfer())
        g("faasm_tier_copied_bytes",
          "bytes served host-local (zero-copy path)").set(gt.total_copied())
        g("faasm_tier_broadcast_bytes",
          "wire bytes fanned out to subscribers").set(gt.total_broadcast())
        g("faasm_tier_fence_rejections_total",
          "pushes refused by the attempt fence").set(gt.fence_rejections)

        tiers = [h.local_tier for h in hosts]
        for h in hosts:
            with h._mutex:
                tiers.extend(h._container_tiers.values())
        g("faasm_wire_codec_fallbacks_total",
          "int8 encodes rescued by the exact wire").set(
              sum(t.codec_fallbacks for t in tiers))
        g("faasm_wire_policy_flips_total",
          "damped WirePolicy wire switches").set(
              sum(t.policy_flips() for t in tiers))

        # wire cost model (docs/observability.md "Wire cost-model gauges"):
        # disarmed (the default) publishes nothing — one None check
        cost = _wire_mod._COST
        if cost is not None:
            snap = cost.snapshot()
            g("faasm_wire_cost_samples_total",
              "encode/transfer observations folded into the model").set(
                  cost.samples)
            for wire_name, buckets in snap.items():
                for bucket, (enc_ns, rest_ns) in buckets.items():
                    g(f"faasm_wire_cost_{wire_name}_b{bucket}_encode_us",
                      "EWMA encode cost at 2^b value bytes").set(
                          enc_ns / 1e3)
                    g(f"faasm_wire_cost_{wire_name}_b{bucket}_rest_us",
                      "EWMA non-encode push cost at 2^b value bytes").set(
                          rest_ns / 1e3)

        # overload control plane (docs/observability.md "Overload metrics")
        with self._mutex:
            shed, dl_n, spill = (self.shed_total, self.deadline_total,
                                 self.spill_total)
        g("faasm_overload_shed_total",
          "calls refused at admission (SHED_RC)").set(shed)
        g("faasm_overload_deadline_total",
          "calls settled DEADLINE_RC (admission/dequeue/mid-exec)").set(dl_n)
        g("faasm_overload_spill_total",
          "full-queue admissions spilled to a peer").set(spill)
        g("faasm_overload_rejected_submits_total",
          "bounded-queue refusals at Host.submit").set(
              _sum("rejected_submits"))
        g("faasm_overload_queue_depth_count",
          "calls queued beyond running capacity, cluster-wide").set(
              sum(h.queue_depth() for h in hosts))
        rb = self._retry_budget
        if rb is not None:
            g("faasm_overload_retry_budget_ratio",
              "retry token bucket fullness").set(rb.fill_ratio())
            g("faasm_overload_retry_denied_total",
              "retries refused by the exhausted budget").set(rb.denied_total)
        brs = self._breakers
        if brs is not None:
            g("faasm_overload_breaker_open_total",
              "circuit-breaker trips across hosts").set(
                  sum(b.opened_total for b in brs.values()))
        g("faasm_overload_bcast_coalesced_total",
          "broadcast frames collapsed to a newer same-key frame").set(
              gt.bcast_coalesced)
        g("faasm_overload_bcast_dropped_total",
          "subscribers dropped to pull-repair by queue overflow").set(
              gt.bcast_dropped)

        plan = faults.active()
        if plan is not None:
            g("faasm_faults_hits_total",
              "fault rules triggered by the armed plan").set(plan.fired())

    def metrics_text(self) -> str:
        """Prometheus text exposition of this runtime's registry (scrapes
        the collector first) — same body the serve ``--metrics-port``
        endpoint returns."""
        return self.metrics.render_text()

    def cold_start_stats(self) -> dict:
        """Cold-start/reset statistics, read through the metrics registry
        (one source of truth with metrics_text and the benchmarks).
        Counts are exact; init_p99_ms is the registry histogram's
        log-bucketed percentile (≤ ~2.2 % relative error)."""
        self.metrics.collect()
        m = self.metrics.get

        def _g(name):
            inst = m(name)
            return int(inst.value) if inst is not None else 0

        hist = m("faasm_host_init_ms")
        return {
            "cold_starts": _g("faasm_host_cold_starts_total"),
            "warm_hits": _g("faasm_host_warm_hits_total"),
            "init_mean_ms": (hist.sum / hist.count
                             if hist is not None and hist.count else 0.0),
            "init_p99_ms": (hist.percentile(0.99)
                            if hist is not None and hist.count else 0.0),
            "resets": _g("faasm_host_resets_total"),
            "reset_pages": _g("faasm_host_reset_pages"),
            "reclaimed_pages": _g("faasm_host_reclaimed_pages"),
            "retained_pages": _g("faasm_host_retained_pages"),
        }

    def shutdown(self) -> None:
        with self._monitor_cv:
            self._monitor_stop = True
            self._monitor_cv.notify_all()
        self._monitor_thread.join(timeout=5.0)
        for h in self.hosts.values():
            if h.alive:
                h.drain()
        self.global_tier.close()         # stop the broadcast pump threads
