"""Read-global / write-local virtual filesystem (Faasm §3.1).

Global files live in the global tier under ``fs::<path>`` (the object store);
writes land in a host-local overlay — functions can read shared library/model
files and write scratch output without filesystem isolation machinery
(no chroot / layered FS, per the paper).
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Dict

from repro.state.kv import GlobalTier

_PREFIX = "fs::"


class VirtualFS:
    def __init__(self, global_tier: GlobalTier):
        self.global_tier = global_tier
        self._local: Dict[str, Dict[str, bytearray]] = defaultdict(dict)
        self._mutex = threading.RLock()

    def put_global(self, path: str, data: bytes) -> None:
        """Upload a file to the global object store (admin/upload service)."""
        self.global_tier.set(_PREFIX + path, bytes(data), host="upload")

    def exists(self, host_id: str, path: str) -> bool:
        with self._mutex:
            if path in self._local[host_id]:
                return True
        return self.global_tier.exists(_PREFIX + path)

    def read(self, host_id: str, path: str) -> bytes:
        with self._mutex:
            local = self._local[host_id].get(path)
            if local is not None:
                return bytes(local)
        return self.global_tier.get(_PREFIX + path, host=host_id)

    def write_local(self, host_id: str, path: str, data: bytes,
                    append: bool = False) -> None:
        with self._mutex:
            files = self._local[host_id]
            if append and path in files:
                files[path].extend(data)
            else:
                files[path] = bytearray(data)

    def drop_local(self, host_id: str) -> None:
        with self._mutex:
            self._local.pop(host_id, None)
