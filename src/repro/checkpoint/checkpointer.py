"""Fault-tolerant checkpointing: async, atomic, elastic-restore-friendly.

Layout per step::

    <dir>/step_<N>.tmp/ …writing… -> atomic rename -> <dir>/step_<N>/
        manifest.json      (tree structure, shapes, dtypes, step)
        arrays.npz         (flat leaf arrays, host layout)

Writes happen on a background thread (training continues); the manifest is
written last and the directory renamed atomically, so a crash mid-write never
corrupts the latest checkpoint.  Restore targets any mesh: leaves are host
arrays re-sharded by ``device_put`` under the new sharding rules
(``distributed/elastic.py``) — elastic scaling from the same checkpoint.

The runtime's global state tier checkpoints through the same path
(``save_global_tier`` / ``restore_global_tier``).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

# dtypes numpy can savez/load natively; others round-trip as bit views
_NUMPY_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
                 "int8", "uint64", "uint32", "uint16", "uint8", "bool",
                 "complex64", "complex128"}


def _flatten_with_paths(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return items, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # -- save -------------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = False,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot a pytree (params/opt state/cache).  Async by default."""
        items, _ = _flatten_with_paths(tree)

        def to_savable(leaf):
            a = np.asarray(leaf)
            if a.dtype.name not in _NUMPY_NATIVE:     # bf16/f8 via bit view
                return a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
            return a

        host_arrays = {f"leaf_{i}": to_savable(leaf)
                       for i, (_, leaf) in enumerate(items)}
        manifest = {
            "step": step,
            "paths": [p for p, _ in items],
            "dtypes": [str(np.asarray(l).dtype) for _, l in items],
            "shapes": [list(np.asarray(l).shape) for _, l in items],
            "extra": extra or {},
            "time": time.time(),
        }
        self.wait()

        def _write():
            try:
                tmp = os.path.join(self.directory, f"step_{step}.tmp")
                final = os.path.join(self.directory, f"step_{step}")
                os.makedirs(tmp, exist_ok=True)
                np.savez(os.path.join(tmp, "arrays.npz"), **host_arrays)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)                      # atomic commit
                self._gc()
            except BaseException as e:                     # surfaced on wait()
                self._last_error = e

        if blocking:
            _write()
            if self._last_error:
                raise self._last_error
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error:
            err, self._last_error = self._last_error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------------

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: Optional[int] = None
                ) -> Tuple[Any, int, Dict[str, Any]]:
        """Restore into the structure of ``tree_like`` (shapes must match)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(len(manifest["paths"]))]
        flat, treedef = jax.tree_util.tree_flatten(tree_like)
        if len(flat) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, target structure has "
                f"{len(flat)}")
        def from_saved(l, t, dtype_name):
            a = np.asarray(l)
            if dtype_name not in _NUMPY_NATIVE:       # restore bit view
                import ml_dtypes
                a = a.view(np.dtype(getattr(ml_dtypes, dtype_name)))
            if hasattr(t, "dtype") and a.dtype.name in _NUMPY_NATIVE and \
                    np.asarray(t).dtype.name in _NUMPY_NATIVE:
                a = a.astype(np.asarray(t).dtype)
            return a

        restored = [from_saved(l, t, d) for l, t, d in
                    zip(leaves, flat, manifest["dtypes"])]
        return (jax.tree_util.tree_unflatten(treedef, restored), step,
                manifest["extra"])


# -- global-tier (runtime state) checkpointing ----------------------------------------

def save_global_tier(global_tier, directory: str, tag: str = "state") -> str:
    """Checkpoint every state key of the runtime's global tier."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"{tag}.tmp.npz")
    final = os.path.join(directory, f"{tag}.npz")
    arrays = {}
    for i, key in enumerate(global_tier.keys()):
        arrays[f"k{i}"] = np.frombuffer(
            global_tier.get(key, host="ckpt"), np.uint8)
        arrays[f"n{i}"] = np.frombuffer(key.encode(), np.uint8)
    np.savez(tmp, **arrays)
    os.replace(tmp, final)
    return final


def restore_global_tier(global_tier, directory: str, tag: str = "state") -> int:
    data = np.load(os.path.join(directory, f"{tag}.npz"))
    n = 0
    i = 0
    while f"k{i}" in data:
        key = bytes(data[f"n{i}"]).decode()
        global_tier.set(key, bytes(data[f"k{i}"]), host="ckpt")
        n += 1
        i += 1
    return n
