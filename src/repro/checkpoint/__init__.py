from repro.checkpoint.checkpointer import (Checkpointer, restore_global_tier,
                                           save_global_tier)

__all__ = ["Checkpointer", "save_global_tier", "restore_global_tier"]
