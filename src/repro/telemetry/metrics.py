"""Named counters / gauges / log-bucketed histograms, one registry.

The registry is the single source of truth the scattered per-object
counters publish into: hot paths keep their cheap lock-local integers
(``GlobalTier`` stripe counters, ``Host.cold_starts``,
``LocalTier.codec_fallbacks``, ``WirePolicy.flips`` …) and a registered
**collector** snapshots them into gauges at scrape time — the Prometheus
client-library pattern, so reading metrics costs the hot path nothing.

Naming convention (enforced here *and* statically by the faasmlint
``metric-naming`` rule): ``faasm_<subsystem>_<name>_<unit>`` with the
unit suffix drawn from :data:`UNITS` — e.g. ``faasm_tier_copied_bytes``,
``faasm_serve_request_ms``, ``faasm_host_cold_starts_total``.

Histograms are HDR-style log-bucketed: bucket boundaries grow by
:data:`GROWTH` (2^(1/16) ≈ 4.4 % per bucket), so ``percentile`` answers
p50/p90/p99/p999 with bounded *relative* error (≤ ~2.2 %, the geometric
half-bucket) at O(1) memory per decade regardless of sample count.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Callable, Dict, List, Optional, Union

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "UNITS", "registry",
    "serve_http", "valid_name",
]

UNITS = ("seconds", "ms", "us", "ns", "bytes", "pages", "total", "count",
         "ratio", "rps")
_NAME_RE = re.compile(
    r"^faasm(_[a-z0-9]+)+_(" + "|".join(UNITS) + r")$")

GROWTH = 2.0 ** (1.0 / 16.0)     # per-bucket growth: ~4.4% relative width
_LOG_GROWTH = math.log(GROWTH)


def valid_name(name: str) -> bool:
    return _NAME_RE.match(name) is not None


def _check_name(name: str) -> str:
    if not valid_name(name):
        raise ValueError(
            f"metric name {name!r} violates the convention "
            f"faasm_<subsystem>_<name>_<unit> (unit one of {UNITS})")
    return name


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("name", "help", "_mu", "_value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._mu = threading.Lock()
        self._value = 0.0

    def inc(self, n: Union[int, float] = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        with self._mu:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Point-in-time value (set, not accumulated)."""

    __slots__ = ("name", "help", "_value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0

    def set(self, v: Union[int, float]) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Log-bucketed distribution with exact count/sum/min/max.

    Non-positive observations land in a dedicated zero bucket (values
    below :data:`GROWTH`'s resolution are indistinguishable from zero on
    a relative-error scale anyway)."""

    __slots__ = ("name", "help", "_mu", "_buckets", "_zero",
                 "count", "sum", "min", "max")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._mu = threading.Lock()
        self._buckets: Dict[int, int] = {}
        self._zero = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: Union[int, float]) -> None:
        v = float(v)
        with self._mu:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            if v <= 0.0:
                self._zero += 1
            else:
                idx = int(math.floor(math.log(v) / _LOG_GROWTH))
                self._buckets[idx] = self._buckets.get(idx, 0) + 1

    def percentile(self, p: float) -> float:
        """Value at quantile ``p`` in [0, 1]; geometric bucket midpoint,
        so relative error is bounded by the half-bucket (~2.2 %)."""
        with self._mu:
            if self.count == 0:
                return 0.0
            rank = p * (self.count - 1)
            seen = self._zero
            if rank < seen:
                return 0.0
            for idx in sorted(self._buckets):
                seen += self._buckets[idx]
                if rank < seen:
                    lo = GROWTH ** idx
                    return min(max(lo * math.sqrt(GROWTH), self.min),
                               self.max)
            return self.max

    def quantiles(self) -> Dict[str, float]:
        return {"0.5": self.percentile(0.50), "0.9": self.percentile(0.90),
                "0.99": self.percentile(0.99),
                "0.999": self.percentile(0.999)}


class Registry:
    """Get-or-create registry of named instruments + scrape collectors."""

    def __init__(self):
        self._mu = threading.Lock()
        self._metrics: Dict[str, Union[Counter, Gauge, Histogram]] = {}
        self._collectors: List[Callable[["Registry"], None]] = []

    def _get(self, cls, name: str, help: str):
        _check_name(name)
        with self._mu:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help)
            elif type(m) is not cls:
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, wanted {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def get(self, name: str):
        with self._mu:
            return self._metrics.get(name)

    def register_collector(self, fn: Callable[["Registry"], None]) -> None:
        """``fn(registry)`` runs at every scrape — snapshot your hot-path
        counters into gauges there, not on the hot path."""
        with self._mu:
            if fn not in self._collectors:
                self._collectors.append(fn)

    def unregister_collector(self, fn: Callable[["Registry"], None]) -> None:
        with self._mu:
            if fn in self._collectors:
                self._collectors.remove(fn)

    def collect(self) -> None:
        with self._mu:
            collectors = list(self._collectors)
        for fn in collectors:
            fn(self)

    def snapshot(self) -> Dict[str, float]:
        """Scrape to a flat dict (histograms contribute their quantiles,
        count and sum) — what benchmarks and stats readers consume."""
        self.collect()
        out: Dict[str, float] = {}
        with self._mu:
            metrics = sorted(self._metrics.items())
        for name, m in metrics:
            if isinstance(m, Histogram):
                out[f"{name}_count"] = float(m.count)
                out[f"{name}_sum"] = m.sum
                for q, v in m.quantiles().items():
                    out[f"{name}{{quantile={q}}}"] = v
            else:
                out[name] = m.value
        return out

    def render_text(self) -> str:
        """Prometheus text exposition (histograms as summaries)."""
        self.collect()
        lines: List[str] = []
        with self._mu:
            metrics = sorted(self._metrics.items())
        for name, m in metrics:
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {m.value:g}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {m.value:g}")
            else:
                lines.append(f"# TYPE {name} summary")
                for q, v in m.quantiles().items():
                    lines.append(f'{name}{{quantile="{q}"}} {v:g}')
                lines.append(f"{name}_sum {m.sum:g}")
                lines.append(f"{name}_count {m.count}")
        return "\n".join(lines) + "\n"


_DEFAULT = Registry()


def registry() -> Registry:
    """The process-wide default registry (serve/train instruments live
    here; a :class:`FaasmRuntime` keeps its own and chains to this)."""
    return _DEFAULT


def serve_http(reg: Registry, port: int, host: str = "127.0.0.1"):
    """Expose ``reg.render_text()`` over HTTP (any GET path) in a daemon
    thread — the ``serve --metrics-port`` backend.  Returns the server;
    call ``.shutdown()`` to stop."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self):                          # noqa: N802 (stdlib API)
            body = reg.render_text().encode()
            self.send_response(200)
            self.send_header("Content-Type", "text/plain; version=0.0.4")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):              # quiet
            pass

    srv = ThreadingHTTPServer((host, port), _Handler)
    threading.Thread(target=srv.serve_forever, name="faasm-metrics",
                     daemon=True).start()
    return srv
