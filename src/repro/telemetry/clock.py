"""The single monotonic clock the runtime stamps time with.

Every timestamp in the system — ``Call.t_submit``/``t_start``/``t_end``,
span boundaries, cold-start init timing, the serve/train step timers —
comes from this module, so deltas taken across stamping sites are always
differences on **one** clock.  Before this existed the three ``Call``
stamps were taken by three independent ``time.perf_counter()`` call sites
scattered through ``runtime.py``; that happened to share a clock by
accident, and nothing could assert it.  The faasmlint ``metric-naming``
rule now flags direct ``perf_counter`` use in data-plane modules so the
accident can't silently regress.

Two granularities, same underlying clock (``perf_counter`` /
``perf_counter_ns`` share a time base by definition):

* :func:`now` — float seconds, for coarse lifecycle stamps and span
  boundaries.
* :func:`now_ns` — integer nanoseconds, for fine durations (codec
  encode/decode cost) where float rounding at large magnitudes matters.
"""
from __future__ import annotations

import time

__all__ = ["now", "now_ns"]


def now() -> float:
    """Monotonic seconds (float).  The only sanctioned wall-time source
    for data-plane stamps."""
    return time.perf_counter()


def now_ns() -> int:
    """Monotonic nanoseconds (int), same time base as :func:`now`."""
    return time.perf_counter_ns()
