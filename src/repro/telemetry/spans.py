"""Per-call span tracing: lock-free per-thread rings drained by a collector.

Opt-in and **zero overhead when disabled**, following the sanitizer/faults
discipline: every hook site in the runtime and state fabric is guarded by
a module-global ``if _TEL is not None`` — one pointer compare per event in
the disarmed steady state, no wrapper frames, zero ring-buffer writes
(``scripts/check_jax_pin.py`` asserts the compile-out).

Architecture
------------

* **Writers** record :class:`Span` objects into a per-thread ring buffer
  (:class:`_Ring`).  A ring has exactly one writer — its owning thread —
  so writes take no lock (the GIL serialises the list ops); a full ring
  drops the oldest span and counts it in ``dropped``.  Ring writes are
  therefore safe anywhere, **including under stripe/key locks** (the hot
  wire-frame sites run inside them).
* **The collector** (:meth:`Tracer.drain`) swaps every ring's buffer out
  and accumulates the spans centrally.  Draining walks shared state and
  is *not* safe under fabric locks — the sanitizer's
  ``telemetry-under-lock`` check (installed here as ``_SAN_GUARD``)
  reports any drain/export reached while a stripe or key lock is held.

Trace context
-------------

``Host._run`` installs the executing attempt's identity —
``(call_id, fence_id, fence_epoch, host)`` — as thread-local context;
spans recorded on that thread (wire frames pushed from inside the user
function, fault-point hits, kernel work) inherit it.  Because a
speculative twin, a retry after host loss, and a zombie attempt all carry
the **primary's** ``fence_id`` with distinct epochs, their spans land as
siblings of one logical call in the export: group by ``fence``, order by
``epoch``.

Import-light on purpose (stdlib only): ``repro.core``/``repro.state``
hold a ``_TEL`` slot this module installs into; it must never import
them back at top level (:func:`_install` does, lazily).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Tuple

from repro.telemetry import clock

__all__ = [
    "Span", "Tracer", "disable", "enable", "enabled", "tracer",
]

_RING_CAPACITY = 8192            # spans per thread before drop-oldest
_COLLECTED_CAP = 1 << 20         # collector hard cap (runaway guard)

# Sanitizer hook: repro.analysis.sanitizer._install points this at its
# drain guard; Tracer.drain calls it so a collector drain under a
# stripe/key lock is reported.  None when the sanitizer is disabled.
_SAN_GUARD = None


class Span:
    """One recorded interval (or instant, ``t0 == t1``) on one thread."""

    __slots__ = ("name", "cat", "t0", "t1", "call", "fence", "epoch",
                 "host", "thread", "tags")

    def __init__(self, name: str, cat: str, t0: float, t1: float,
                 call: Optional[int], fence: Optional[str],
                 epoch: Optional[int], host: Optional[str],
                 thread: str, tags: Optional[Dict[str, Any]]):
        self.name = name
        self.cat = cat              # call | wire | fault | serve | train
        self.t0 = t0                # clock.now() seconds
        self.t1 = t1
        self.call = call            # physical attempt (Call.id)
        self.fence = fence          # logical call (Call.fence_id)
        self.epoch = epoch          # attempt epoch under that fence
        self.host = host
        self.thread = thread
        self.tags = tags

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, {self.dur * 1e3:.3f}ms, "
                f"call={self.call}, fence={self.fence}, epoch={self.epoch}, "
                f"host={self.host}, tags={self.tags})")


class _Ring:
    """Fixed-capacity single-writer ring.  The owning thread appends;
    the collector swaps the buffer out wholesale.  No locks: one writer
    per ring plus the GIL makes the append/swap races benign (a span
    appended concurrently with a swap lands in the next drain)."""

    __slots__ = ("buf", "head", "dropped")

    def __init__(self):
        self.buf: List[Span] = []
        self.head = 0
        self.dropped = 0

    def push(self, span: Span) -> None:
        buf = self.buf
        if len(buf) < _RING_CAPACITY:
            buf.append(span)
        else:
            buf[self.head] = span
            self.head = (self.head + 1) % _RING_CAPACITY
            self.dropped += 1

    def swap(self) -> List[Span]:
        out, self.buf, self.head = self.buf, [], 0
        # restore drain order for a wrapped ring: oldest surviving first
        if self.dropped and out:
            h = self.dropped % _RING_CAPACITY
            out = out[h:] + out[:h]
        return out


class _Ctx:
    __slots__ = ("call", "fence", "epoch", "host")

    def __init__(self):
        self.call: Optional[int] = None
        self.fence: Optional[str] = None
        self.epoch: Optional[int] = None
        self.host: Optional[str] = None


class Tracer:
    """The armed tracing state: ring registry + collector + counters."""

    def __init__(self):
        self._mu = threading.Lock()          # ring registry + collected list
        self._tls = threading.local()
        self._rings: Dict[int, Tuple[str, _Ring]] = {}
        self._collected: List[Span] = []
        self.writes = 0                      # total ring-buffer writes ever
        self.dropped = 0                     # spans lost to full rings

    # -- clock (re-exported so hook sites hold one object) ------------------

    @staticmethod
    def now() -> float:
        return clock.now()

    @staticmethod
    def now_ns() -> int:
        return clock.now_ns()

    # -- trace context -------------------------------------------------------

    def set_ctx(self, call: int, fence: str, epoch: int, host: str) -> None:
        ctx = getattr(self._tls, "ctx", None)
        if ctx is None:
            ctx = self._tls.ctx = _Ctx()
        ctx.call, ctx.fence, ctx.epoch, ctx.host = call, fence, epoch, host

    def clear_ctx(self) -> None:
        ctx = getattr(self._tls, "ctx", None)
        if ctx is not None:
            ctx.call = ctx.fence = ctx.epoch = ctx.host = None

    def _ctx(self) -> Optional[_Ctx]:
        return getattr(self._tls, "ctx", None)

    # -- recording (any thread, any lock context) ---------------------------

    def _ring(self) -> _Ring:
        r = getattr(self._tls, "ring", None)
        if r is None:
            r = self._tls.ring = _Ring()
            t = threading.current_thread()
            with self._mu:
                self._rings[t.ident or id(t)] = (t.name, r)
        return r

    def record(self, name: str, cat: str, t0: float, t1: float, *,
               call: Optional[int] = None, fence: Optional[str] = None,
               epoch: Optional[int] = None, host: Optional[str] = None,
               **tags: Any) -> None:
        """Record a finished interval.  Identity fields left ``None`` are
        filled from the thread's trace context (if any)."""
        ctx = self._ctx()
        if ctx is not None:
            if call is None:
                call = ctx.call
            if fence is None:
                fence = ctx.fence
            if epoch is None:
                epoch = ctx.epoch
            if host is None:
                host = ctx.host
        self.writes += 1
        self._ring().push(Span(
            name, cat, t0, t1, call, fence, epoch, host,
            threading.current_thread().name, tags or None))

    def instant(self, name: str, cat: str, **tags: Any) -> None:
        t = clock.now()
        self.record(name, cat, t, t, **tags)

    # -- collector (never call under a stripe/key lock) ---------------------

    def drain(self) -> List[Span]:
        """Swap every ring out and absorb the spans centrally.  Returns
        the newly drained spans (the full set is :meth:`spans`)."""
        guard = _SAN_GUARD
        if guard is not None:
            guard()
        with self._mu:
            rings = list(self._rings.values())
        fresh: List[Span] = []
        for _name, ring in rings:
            fresh.extend(ring.swap())
            self.dropped += ring.dropped
            ring.dropped = 0
        fresh.sort(key=lambda s: s.t0)
        with self._mu:
            room = _COLLECTED_CAP - len(self._collected)
            self._collected.extend(fresh[:max(room, 0)])
        return fresh

    def spans(self) -> List[Span]:
        """Everything collected so far (drains first)."""
        self.drain()
        with self._mu:
            return list(self._collected)

    def take(self) -> List[Span]:
        """Drain and return all collected spans, clearing the collector."""
        self.drain()
        with self._mu:
            out, self._collected = self._collected, []
            return out


# -- module API --------------------------------------------------------------

_active: Optional[Tracer] = None


def enabled() -> bool:
    return _active is not None


def tracer() -> Optional[Tracer]:
    return _active


def _install(t: Optional[Tracer]) -> None:
    """(Un)install the tracer into the instrumented modules' ``_TEL``
    slots.  Imports live here, lazily, to keep this module import-light."""
    from repro import faults
    from repro.core import runtime
    from repro.state import kv, local
    runtime._TEL = t
    kv._TEL = t
    local._TEL = t
    faults._TEL = t


def enable() -> Tracer:
    """Arm tracing (idempotent).  Hook sites go live immediately; spans
    from calls already in flight pick up mid-lifecycle."""
    global _active
    if _active is None:
        _active = Tracer()
        _install(_active)
    return _active


def disable() -> None:
    global _active
    if _active is None:
        return
    _active = None
    _install(None)
