"""Chrome/Perfetto ``trace_event`` export for collected spans.

Open the output at https://ui.perfetto.dev (or ``chrome://tracing``):
rows ("threads") are hosts — every span lands on the row of the host its
attempt ran on — and wire frames crossing tiers are drawn as async
arrows (flow events) from the pushing span to each applying span, bound
by the frame's ``key@version`` identity.  Span ``args`` carry the trace
context (``call``/``fence``/``epoch``) plus the site tags (wire kind,
bytes, encode/decode ns, version transition, fault point …), so one
logical call's twin/retry/zombie attempts are visually siblings: same
``fence``, different ``epoch``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.telemetry import spans as _spans

__all__ = ["chrome_trace_events", "export_chrome"]

_PID = 1
# span names whose frames *produce* a wire flow vs *consume* one
_FLOW_SRC = ("wire.push",)
_FLOW_DST = ("wire.bcast", "wire.pull")


def _flow_id(span: _spans.Span) -> Optional[str]:
    tags = span.tags or {}
    key, version = tags.get("key"), tags.get("version")
    if key is None or version is None:
        return None
    return f"{key}@{version}"


def chrome_trace_events(span_list: List[_spans.Span]) -> List[Dict[str, Any]]:
    """Render spans to ``trace_event`` dicts (the ``traceEvents`` array)."""
    events: List[Dict[str, Any]] = []
    tids: Dict[str, int] = {}

    def tid_for(span: _spans.Span) -> int:
        row = span.host if span.host is not None else f"thread:{span.thread}"
        tid = tids.get(row)
        if tid is None:
            tid = tids[row] = len(tids) + 1
            events.append({"name": "thread_name", "ph": "M", "pid": _PID,
                           "tid": tid, "args": {"name": row}})
        return tid

    flow_seq: Dict[str, int] = {}
    for s in span_list:
        tid = tid_for(s)
        args: Dict[str, Any] = {}
        if s.call is not None:
            args["call"] = s.call
        if s.fence is not None:
            args["fence"] = s.fence
        if s.epoch is not None:
            args["epoch"] = s.epoch
        if s.tags:
            args.update(s.tags)
        ts = s.t0 * 1e6
        if s.t1 <= s.t0:
            events.append({"name": s.name, "cat": s.cat, "ph": "i",
                           "ts": ts, "pid": _PID, "tid": tid, "s": "t",
                           "args": args})
        else:
            events.append({"name": s.name, "cat": s.cat, "ph": "X",
                           "ts": ts, "dur": (s.t1 - s.t0) * 1e6,
                           "pid": _PID, "tid": tid, "args": args})
        fid = _flow_id(s)
        if fid is not None:
            if s.name in _FLOW_SRC:
                flow_seq[fid] = 1
                events.append({"name": "wire-frame", "cat": "wire",
                               "ph": "s", "id": fid, "ts": ts + 1e-3,
                               "pid": _PID, "tid": tid})
            elif s.name in _FLOW_DST and flow_seq.get(fid):
                events.append({"name": "wire-frame", "cat": "wire",
                               "ph": "f", "bp": "e", "id": fid, "ts": ts,
                               "pid": _PID, "tid": tid})
    return events


def export_chrome(path: str,
                  span_list: Optional[List[_spans.Span]] = None) -> int:
    """Write a Chrome/Perfetto JSON trace; returns the event count.

    ``span_list`` defaults to everything the active tracer has collected
    (drains first — never call while holding a stripe/key lock)."""
    if span_list is None:
        t = _spans.tracer()
        span_list = t.spans() if t is not None else []
    events = chrome_trace_events(span_list)
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return len(events)
