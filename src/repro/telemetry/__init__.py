"""Telemetry plane: spans, metrics, exports — zero overhead when off.

Three parts, one discipline (see ``docs/observability.md``):

* :mod:`repro.telemetry.clock` — the single monotonic clock every
  data-plane timestamp comes from.
* :mod:`repro.telemetry.spans` — per-call span tracing into per-thread
  ring buffers; armed via :func:`enable` (one pointer compare per hook
  site when disarmed, compile-out asserted by ``scripts/check_jax_pin``).
* :mod:`repro.telemetry.metrics` — the named counter/gauge/histogram
  registry that the scattered hot-path counters publish into.
* :mod:`repro.telemetry.trace` — Chrome/Perfetto ``trace_event`` export.
"""
from repro.telemetry import clock, metrics, spans, trace      # noqa: F401
from repro.telemetry.spans import (Tracer, disable, enable,   # noqa: F401
                                   enabled, tracer)

__all__ = [
    "Tracer", "clock", "disable", "enable", "enabled", "metrics",
    "spans", "trace", "tracer",
]
