"""Decoder-only transformer: dense, MoE and VLM families.

Layers are stored *stacked* (leading L axis on every leaf) and executed with
``lax.scan`` so even the 61-layer / 1T-param kimi-k2 config lowers to compact
HLO.  MoE archs with ``first_k_dense`` leading dense layers keep those layers
unrolled (param structure differs) and scan the homogeneous MoE remainder.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.execution import ExecConfig
from repro.models import layers as L
from repro.models.attention import (attn_apply_decode, attn_apply_full,
                                    attn_apply_prefill, attn_init)
from repro.models.moe import moe_apply, moe_init


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def dense_block_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    ks = jax.random.split(key, 2)
    return {"ln1": L.norm_init(cfg), "attn": attn_init(ks[0], cfg),
            "ln2": L.norm_init(cfg), "mlp": L.mlp_init(ks[1], cfg, d_ff)}


def moe_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {"ln1": L.norm_init(cfg), "attn": attn_init(ks[0], cfg),
            "ln2": L.norm_init(cfg), "moe": moe_init(ks[1], cfg)}


def _ffn(lp, cfg, ec, h):
    """Second half of a block: returns (delta, aux)."""
    x = L.norm_apply(lp["ln2"], cfg, h)
    if "moe" in lp:
        y, aux = moe_apply(lp["moe"], cfg, ec, x)
        return y, aux
    return L.mlp_apply(lp["mlp"], cfg, x), jnp.zeros((), jnp.float32)


def block_full(lp, cfg: ModelConfig, ec: ExecConfig, h, positions=None):
    h = h + attn_apply_full(lp["attn"], cfg, ec,
                            L.norm_apply(lp["ln1"], cfg, h), positions=positions)
    delta, aux = _ffn(lp, cfg, ec, h)
    return h + delta, aux


def block_prefill(lp, cfg, ec, h, ck, cv, positions=None):
    a, ck, cv = attn_apply_prefill(lp["attn"], cfg, ec,
                                   L.norm_apply(lp["ln1"], cfg, h), ck, cv,
                                   positions=positions)
    h = h + a
    delta, _ = _ffn(lp, cfg, ec, h)
    return h + delta, ck, cv


def block_decode(lp, cfg, ec, h, ck, cv, index):
    a, ck, cv = attn_apply_decode(lp["attn"], cfg, ec,
                                  L.norm_apply(lp["ln1"], cfg, h), ck, cv, index)
    h = h + a
    delta, _ = _ffn(lp, cfg, ec, h)
    return h + delta, ck, cv


def _maybe_remat(fn, ec: ExecConfig):
    if ec.remat == "none":
        return fn
    if ec.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    params = L.embed_init(ks[0], cfg)
    n_first = cfg.first_k_dense if cfg.n_experts else 0
    first = []
    for i in range(n_first):
        first.append(dense_block_init(jax.random.fold_in(ks[1], i), cfg,
                                      d_ff=cfg.dense_d_ff or cfg.d_ff))
    if first:
        params["first_layers"] = first
    n_scan = cfg.n_layers - n_first
    layer_init = (functools.partial(moe_block_init, cfg=cfg) if cfg.n_experts
                  else functools.partial(dense_block_init, cfg=cfg))
    params["layers"] = jax.vmap(lambda k: layer_init(k))(
        jax.random.split(ks[2], n_scan))
    params["final_norm"] = L.norm_init(cfg)
    return params


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, tokens, image_embeds=None):
    h = L.embed_apply(params, cfg, tokens)
    if cfg.family == "vlm":
        assert image_embeds is not None, "vlm needs stubbed patch embeddings"
        h = jnp.concatenate([image_embeds.astype(h.dtype), h], axis=1)
    return h


def forward_hidden(params, cfg: ModelConfig, ec: ExecConfig, tokens,
                   image_embeds=None, train: bool = True):
    """Returns (h (B, S_total, d) post-final-norm, aux_loss)."""
    h = _embed_inputs(params, cfg, tokens, image_embeds)
    S = h.shape[1]
    positions = jnp.arange(S) if cfg.use_rope else None
    aux = jnp.zeros((), jnp.float32)
    for lp in params.get("first_layers", []):
        h2, a = block_full(lp, cfg, ec, h, positions)
        h, aux = h2, aux + a

    def body(carry, lp):
        h, aux = carry
        if train and ec.shard_activations:
            h = L.seq_shard_constraint(h)
        h2, a = block_full(lp, cfg, ec, h, positions)
        return (h2, aux + a), None

    if train:
        body = _maybe_remat(body, ec)
    if ec.scan_layers:
        (h, aux), _ = jax.lax.scan(body, (h, aux), params["layers"])
    else:
        n = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
        for i in range(n):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            (h, aux), _ = body((h, aux), lp)
    return L.norm_apply(params["final_norm"], cfg, h), aux


def forward_train(params, cfg: ModelConfig, ec: ExecConfig, batch):
    """batch: tokens/targets/mask (+image_embeds).  Returns (loss, metrics)."""
    h, aux = forward_hidden(params, cfg, ec, batch["tokens"],
                            batch.get("image_embeds"), train=True)
    if cfg.family == "vlm":
        h = h[:, cfg.n_image_tokens:]            # loss only over text positions
    loss = L.chunked_loss(params, cfg, h, batch["targets"], batch["mask"],
                          ec.loss_chunk)
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux}


def forward_logits(params, cfg: ModelConfig, ec: ExecConfig, tokens,
                   image_embeds=None):
    h, _ = forward_hidden(params, cfg, ec, tokens, image_embeds, train=False)
    return L.logits_apply(params, cfg, h, f32=ec.logits_f32)


# ---------------------------------------------------------------------------
# KV-cache serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    n_first = cfg.first_k_dense if cfg.n_experts else 0
    n_scan = cfg.n_layers - n_first
    kv = lambda n: jnp.zeros((n, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                             L.dt(cfg.dtype))
    cache = {"k": kv(n_scan), "v": kv(n_scan)}
    if n_first:
        cache["first_k"] = kv(n_first)
        cache["first_v"] = kv(n_first)
    return cache


def prefill(params, cfg: ModelConfig, ec: ExecConfig, tokens, cache,
            image_embeds=None):
    """Left-aligned prefill.  Returns (last-token logits, cache, seq_len)."""
    cache = dict(cache)
    h = _embed_inputs(params, cfg, tokens, image_embeds)
    S = h.shape[1]
    positions = jnp.arange(S) if cfg.use_rope else None
    for i, lp in enumerate(params.get("first_layers", [])):
        h, ck, cv = block_prefill(lp, cfg, ec, h, cache["first_k"][i],
                                  cache["first_v"][i], positions)
        cache["first_k"] = cache["first_k"].at[i].set(ck)
        cache["first_v"] = cache["first_v"].at[i].set(cv)

    def body(h, xs):
        lp, ck, cv = xs
        if ec.shard_activations:
            h = L.seq_shard_constraint(h)
        h, ck, cv = block_prefill(lp, cfg, ec, h, ck, cv, positions)
        return h, (ck, cv)

    h, (ck, cv) = jax.lax.scan(body, h, (params["layers"], cache["k"], cache["v"]))
    cache = dict(cache, k=ck, v=cv)
    h = L.norm_apply(params["final_norm"], cfg, h)
    logits = L.logits_apply(params, cfg, h[:, -1:], f32=ec.logits_f32)[:, 0]
    return logits, cache, S


def decode_step(params, cfg: ModelConfig, ec: ExecConfig, token, cache, index):
    """One serve step.  token: (B,) int32; index: (B,) position of this token.

    Returns (logits (B, V), new cache)."""
    cache = dict(cache)
    h = L.embed_apply(params, cfg, token[:, None])
    for i, lp in enumerate(params.get("first_layers", [])):
        h, ck, cv = block_decode(lp, cfg, ec, h, cache["first_k"][i],
                                 cache["first_v"][i], index)
        cache["first_k"] = cache["first_k"].at[i].set(ck)
        cache["first_v"] = cache["first_v"].at[i].set(cv)

    def body(h, xs):
        lp, ck, cv = xs
        if ec.shard_activations:
            h = L.seq_shard_constraint(h)
        h, ck, cv = block_decode(lp, cfg, ec, h, ck, cv, index)
        return h, (ck, cv)

    h, (ck, cv) = jax.lax.scan(body, h, (params["layers"], cache["k"], cache["v"]))
    cache = dict(cache, k=ck, v=cv)
    h = L.norm_apply(params["final_norm"], cfg, h)
    logits = L.logits_apply(params, cfg, h, f32=ec.logits_f32)[:, 0]
    return logits, cache
