from repro.models.execution import ExecConfig, DEFAULT_EXEC
from repro.models.model import Model, build_model

__all__ = ["ExecConfig", "DEFAULT_EXEC", "Model", "build_model"]
