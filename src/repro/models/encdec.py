"""Encoder/decoder transformer backbone (Whisper-style).

The audio conv frontend is a stub per the assignment: inputs are precomputed
frame embeddings (B, n_frames, d_model).  Positions are fixed sinusoidal for
both stacks (the released model uses learned decoder positions capped at 448;
sinusoidal keeps the assigned 32k-decode shape well-defined — noted in
DESIGN.md).  Decoder serve state: per-layer self-attention KV cache plus a
per-request cross-attention KV cache computed once at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.execution import ExecConfig
from repro.models import layers as L
from repro.models.attention import (attn_apply_decode, attn_apply_full,
                                    attn_apply_prefill, attn_init,
                                    cross_attn_apply, cross_attn_precompute)
from repro.kernels.decode_attention import decode_attention
from repro.models.transformer import _maybe_remat, dense_block_init


def dec_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {"ln1": L.norm_init(cfg), "self_attn": attn_init(ks[0], cfg),
            "ln2": L.norm_init(cfg), "cross_attn": attn_init(ks[1], cfg),
            "ln3": L.norm_init(cfg), "mlp": L.mlp_init(ks[2], cfg)}


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    params = L.embed_init(ks[0], cfg)
    params["encoder"] = {
        "layers": jax.vmap(lambda k: dense_block_init(k, cfg))(
            jax.random.split(ks[1], cfg.n_enc_layers)),
        "ln_post": L.norm_init(cfg),
    }
    params["layers"] = jax.vmap(lambda k: dec_block_init(k, cfg))(
        jax.random.split(ks[2], cfg.n_layers))
    params["final_norm"] = L.norm_init(cfg)
    return params


def encode(params, cfg: ModelConfig, ec: ExecConfig, frames, train=False):
    """frames: (B, F, d) stubbed conv-frontend output."""
    h = frames.astype(L.dt(cfg.dtype))
    h = h + L.sinusoidal_positions(h.shape[1], cfg.d_model).astype(h.dtype)

    def body(h, lp):
        a = attn_apply_full(lp["attn"], cfg, ec, L.norm_apply(lp["ln1"], cfg, h),
                            causal=False)
        h = h + a
        h = h + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln2"], cfg, h))
        return h, None

    if train:
        body = _maybe_remat(body, ec)
    h, _ = jax.lax.scan(body, h, params["encoder"]["layers"])
    return L.norm_apply(params["encoder"]["ln_post"], cfg, h)


def _dec_block_full(lp, cfg, ec, h, enc_out):
    a = attn_apply_full(lp["self_attn"], cfg, ec,
                        L.norm_apply(lp["ln1"], cfg, h), causal=True)
    h = h + a
    ck, cv = cross_attn_precompute(lp["cross_attn"], cfg, enc_out)
    h = h + cross_attn_apply(lp["cross_attn"], cfg, ec,
                             L.norm_apply(lp["ln2"], cfg, h), ck, cv)
    h = h + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln3"], cfg, h))
    return h


def forward_hidden(params, cfg: ModelConfig, ec: ExecConfig, tokens,
                   frames=None, train: bool = True):
    enc_out = encode(params, cfg, ec, frames, train=train)
    h = L.embed_apply(params, cfg, tokens)
    h = h + L.sinusoidal_positions(h.shape[1], cfg.d_model).astype(h.dtype)

    def body(h, lp):
        if ec.shard_activations:
            h = L.seq_shard_constraint(h)
        return _dec_block_full(lp, cfg, ec, h, enc_out), None

    if train:
        body = _maybe_remat(body, ec)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return L.norm_apply(params["final_norm"], cfg, h), jnp.zeros((), jnp.float32)


def forward_train(params, cfg: ModelConfig, ec: ExecConfig, batch):
    h, aux = forward_hidden(params, cfg, ec, batch["tokens"],
                            batch.get("frames"), train=True)
    loss = L.chunked_loss(params, cfg, h, batch["targets"], batch["mask"],
                          ec.loss_chunk)
    return loss + aux, {"loss": loss, "aux_loss": aux}


def forward_logits(params, cfg: ModelConfig, ec: ExecConfig, tokens,
                   frames=None):
    h, _ = forward_hidden(params, cfg, ec, tokens, frames, train=False)
    return L.logits_apply(params, cfg, h, f32=ec.logits_f32)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    Ln = cfg.n_layers
    kv = lambda s: jnp.zeros((Ln, batch, s, cfg.n_kv_heads, cfg.head_dim),
                             L.dt(cfg.dtype))
    return {"k": kv(max_len), "v": kv(max_len),
            "ck": kv(cfg.n_frames), "cv": kv(cfg.n_frames)}


def prefill(params, cfg: ModelConfig, ec: ExecConfig, tokens, cache,
            frames=None):
    cache = dict(cache)
    enc_out = encode(params, cfg, ec, frames)
    h = L.embed_apply(params, cfg, tokens)
    B, S = tokens.shape
    h = h + L.sinusoidal_positions(S, cfg.d_model).astype(h.dtype)

    def body(h, xs):
        lp, sk, sv = xs
        if ec.shard_activations:
            h = L.seq_shard_constraint(h)
        a, sk, sv = attn_apply_prefill(lp["self_attn"], cfg, ec,
                                       L.norm_apply(lp["ln1"], cfg, h), sk, sv)
        h = h + a
        ck, cv = cross_attn_precompute(lp["cross_attn"], cfg, enc_out)
        h = h + cross_attn_apply(lp["cross_attn"], cfg, ec,
                                 L.norm_apply(lp["ln2"], cfg, h), ck, cv)
        h = h + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln3"], cfg, h))
        return h, (sk, sv, ck.astype(sk.dtype), cv.astype(sv.dtype))

    h, (sk, sv, ck, cv) = jax.lax.scan(body, h,
                                       (params["layers"], cache["k"], cache["v"]))
    cache.update(k=sk, v=sv, ck=ck, cv=cv)
    h = L.norm_apply(params["final_norm"], cfg, h)
    logits = L.logits_apply(params, cfg, h[:, -1:], f32=ec.logits_f32)[:, 0]
    return logits, cache, S


def decode_step(params, cfg: ModelConfig, ec: ExecConfig, token, cache, index):
    cache = dict(cache)
    B = token.shape[0]
    h = L.embed_apply(params, cfg, token[:, None])
    # position embedding for the new token at per-sequence positions
    max_len = cache["k"].shape[2]
    pos_table = L.sinusoidal_positions(max_len, cfg.d_model)
    h = h + pos_table[index][:, None].astype(h.dtype)
    F = cfg.n_frames
    flen = jnp.full((B,), F, jnp.int32)

    def body(h, xs):
        lp, sk, sv, ck, cv = xs
        a, sk, sv = attn_apply_decode(lp["self_attn"], cfg, ec,
                                      L.norm_apply(lp["ln1"], cfg, h), sk, sv,
                                      index)
        h = h + a
        x = L.norm_apply(lp["ln2"], cfg, h)
        q = (x @ lp["cross_attn"]["wq"])
        if cfg.qkv_bias:
            q = q + lp["cross_attn"]["bq"]
        q = q.reshape(B, cfg.n_heads, cfg.head_dim)
        y = decode_attention(q, ck.astype(q.dtype), cv.astype(q.dtype), flen,
                             backend=ec.backend)
        y = y.reshape(B, 1, cfg.q_dim) @ lp["cross_attn"]["wo"]
        if cfg.o_bias:
            y = y + lp["cross_attn"]["bo"]
        h = h + y
        h = h + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln3"], cfg, h))
        return h, (sk, sv)

    h, (sk, sv) = jax.lax.scan(body, h, (params["layers"], cache["k"],
                                         cache["v"], cache["ck"], cache["cv"]))
    cache.update(k=sk, v=sv)
    h = L.norm_apply(params["final_norm"], cfg, h)
    logits = L.logits_apply(params, cfg, h, f32=ec.logits_f32)[:, 0]
    return logits, cache
