"""GQA attention layer: init, full-sequence apply, prefill and decode modes.

Dispatches to the flash-attention / decode-attention kernel packages.
KV caches are (B, S_max, K, D) per layer; decode writes the new token's K/V at
per-sequence positions via scatter (sequences in a serving batch have
different lengths — the Faasm serving runtime batches unrelated requests).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.execution import ExecConfig
from repro.models.layers import dt, rms_head_norm, rope_apply, trunc_normal
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.decode_attention import decode_attention


def attn_init(key, cfg: ModelConfig):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    pdt = dt(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": trunc_normal(ks[0], (d, qd), std, pdt),
        "wk": trunc_normal(ks[1], (d, kvd), std, pdt),
        "wv": trunc_normal(ks[2], (d, kvd), std, pdt),
        "wo": trunc_normal(ks[3], (qd, d), qd ** -0.5, pdt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), pdt)
        p["bk"] = jnp.zeros((kvd,), pdt)
        p["bv"] = jnp.zeros((kvd,), pdt)
    if cfg.o_bias:
        p["bo"] = jnp.zeros((d,), pdt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), pdt)
        p["k_norm"] = jnp.ones((cfg.head_dim,), pdt)
    return p


def _project_qkv(p, cfg: ModelConfig, x, positions):
    """x: (B, S, d) -> q (B,S,H,D), k/v (B,S,K,D) with rope + qk-norm applied."""
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    if cfg.use_rope and positions is not None:
        q = rope_apply(q, positions, cfg.rope_theta)
        k = rope_apply(k, positions, cfg.rope_theta)
    return q, k, v


def _out_proj(p, y, B, S, cfg):
    out = y.reshape(B, S, cfg.q_dim) @ p["wo"]
    if cfg.o_bias:
        out = out + p["bo"]
    return out


def attn_apply_full(p, cfg: ModelConfig, ec: ExecConfig, x, *,
                    positions=None, causal=True) -> jnp.ndarray:
    """Full-sequence attention (training / encoder).  x: (B, S, d)."""
    B, S, _ = x.shape
    if positions is None and cfg.use_rope:
        positions = jnp.arange(S)
    q, k, v = _project_qkv(p, cfg, x, positions)
    if not ec.flash_for_prefill:
        y = attention_ref(q, k, v, causal=causal)
    elif causal and ec.attn_buckets > 1 and S % ec.attn_buckets == 0:
        # causal q-bucketing: queries in bucket i only ever see keys in
        # [0, (i+1)·S/nb) — skip the strictly-upper KV blocks entirely.
        # Work factor (nb+1)/(2·nb) of full-rectangle attention.
        nb = ec.attn_buckets
        bs = S // nb
        parts = []
        for i in range(nb):
            parts.append(flash_attention(
                q[:, i * bs:(i + 1) * bs], k[:, :(i + 1) * bs],
                v[:, :(i + 1) * bs], causal=True, q_offset=i * bs,
                backend=ec.backend, block_k=min(ec.attn_block_k, (i + 1) * bs)))
        y = jnp.concatenate(parts, axis=1)
    else:
        y = flash_attention(q, k, v, causal=causal, backend=ec.backend,
                            block_k=ec.attn_block_k)
    return _out_proj(p, y, B, S, cfg)


def attn_apply_prefill(p, cfg: ModelConfig, ec: ExecConfig, x, cache_k, cache_v,
                       *, positions=None) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Prefill: causal attention + write K/V into the cache prefix.

    cache_k/v: (B, S_max, K, D) zero-initialised.  Returns (out, k_cache, v_cache).
    """
    B, S, _ = x.shape
    if positions is None and cfg.use_rope:
        positions = jnp.arange(S)
    q, k, v = _project_qkv(p, cfg, x, positions)
    y = flash_attention(q, k, v, causal=True, backend=ec.backend,
                        block_k=ec.attn_block_k)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, 0, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, 0, 0, 0))
    return _out_proj(p, y, B, S, cfg), cache_k, cache_v


def attn_apply_decode(p, cfg: ModelConfig, ec: ExecConfig, x, cache_k, cache_v,
                      index) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step.  x: (B, 1, d); index: (B,) position of the new token.

    Returns (out (B,1,d), new cache_k, new cache_v)."""
    B = x.shape[0]
    positions = index[:, None] if cfg.use_rope else None      # (B, 1)
    q, k, v = _project_qkv(p, cfg, x, positions)
    batch_ix = jnp.arange(B)
    cache_k = cache_k.at[batch_ix, index].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[batch_ix, index].set(v[:, 0].astype(cache_v.dtype))
    lengths = index + 1
    y = decode_attention(q[:, 0], cache_k.astype(q.dtype),
                         cache_v.astype(q.dtype), lengths,
                         backend=ec.backend)
    return _out_proj(p, y[:, None], B, 1, cfg), cache_k, cache_v


# ---------------------------------------------------------------------------
# Cross-attention (encoder/decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg: ModelConfig):
    return attn_init(key, cfg)


def cross_attn_precompute(p, cfg: ModelConfig, enc_out):
    """Compute K/V over encoder output once per request.  enc_out: (B, F, d)."""
    B, F, _ = enc_out.shape
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(B, F, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, F, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def cross_attn_apply(p, cfg: ModelConfig, ec: ExecConfig, x, ck, cv):
    """Decoder cross-attention (no masking).  x: (B, S, d); ck/cv: (B, F, K, D)."""
    B, S, _ = x.shape
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    y = flash_attention(q, ck.astype(q.dtype), cv.astype(q.dtype), causal=False,
                        backend=ec.backend, block_k=ec.attn_block_k)
    return _out_proj(p, y, B, S, cfg)
