"""Unified model facade: family dispatch, loss, serving and input specs.

``build_model(cfg, ec)`` returns a :class:`Model` whose methods are pure
functions of (params, inputs) — suitable for jit/pjit, ``jax.eval_shape`` and
the multi-pod dry-run (``input_specs`` produces ShapeDtypeStruct stand-ins
for every model input, with no device allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.execution import ExecConfig, DEFAULT_EXEC
from repro.models import encdec, ssm_stack, transformer
from repro.models import layers as L

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": ssm_stack,
    "hybrid": ssm_stack,
    "encdec": encdec,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    ec: ExecConfig

    @property
    def _mod(self):
        return _FAMILY_MODULES[self.cfg.family]

    # -- construction ----------------------------------------------------------
    def init(self, rng):
        return self._mod.init_params(rng, self.cfg)

    def init_shapes(self, rng=None):
        """Parameter ShapeDtypeStructs without allocating (for the dry-run)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, rng)

    # -- training ----------------------------------------------------------------
    def loss(self, params, batch):
        """(loss, metrics) for a train batch."""
        return self._mod.forward_train(params, self.cfg, self.ec, batch)

    def logits(self, params, tokens, extra=None):
        if self.cfg.family == "encdec":
            return self._mod.forward_logits(params, self.cfg, self.ec, tokens,
                                            extra)
        if self.cfg.family == "vlm":
            return self._mod.forward_logits(params, self.cfg, self.ec, tokens,
                                            extra)
        return self._mod.forward_logits(params, self.cfg, self.ec, tokens)

    # -- serving -----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        return self._mod.init_cache(self.cfg, batch, max_len)

    def prefill(self, params, tokens, cache, extra=None):
        """Returns (last-token logits, cache, prefix_len)."""
        if self.cfg.family == "encdec":
            return self._mod.prefill(params, self.cfg, self.ec, tokens, cache,
                                     frames=extra)
        if self.cfg.family == "vlm":
            return self._mod.prefill(params, self.cfg, self.ec, tokens, cache,
                                     image_embeds=extra)
        return self._mod.prefill(params, self.cfg, self.ec, tokens, cache)

    def decode_step(self, params, token, cache, index):
        """One serve step: (logits (B,V), new cache)."""
        return self._mod.decode_step(params, self.cfg, self.ec, token, cache,
                                     index)

    # -- dry-run input specs --------------------------------------------------------
    def cache_specs(self, batch: int, max_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every input of the step this shape
        lowers (train_step for "train", prefill/serve for the others)."""
        cfg = self.cfg
        GB, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        f = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct

        def text_len():
            if cfg.family == "vlm":
                return S - cfg.n_image_tokens
            return S

        if shape.kind == "train":
            St = text_len()
            specs = {"tokens": sds((GB, St), i32),
                     "targets": sds((GB, St), i32),
                     "mask": sds((GB, St), jnp.float32)}
            if cfg.family == "vlm":
                specs["image_embeds"] = sds((GB, cfg.n_image_tokens, cfg.d_model), f)
            if cfg.family == "encdec":
                specs["frames"] = sds((GB, cfg.n_frames, cfg.d_model), f)
            return specs

        if shape.kind == "prefill":
            St = text_len()
            specs = {"tokens": sds((GB, St), i32)}
            if cfg.family == "vlm":
                specs["image_embeds"] = sds((GB, cfg.n_image_tokens, cfg.d_model), f)
            if cfg.family == "encdec":
                specs["frames"] = sds((GB, cfg.n_frames, cfg.d_model), f)
            specs["cache"] = self.cache_specs(GB, S)
            return specs

        # decode: one new token against a cache of seq_len
        return {"token": sds((GB,), i32),
                "index": sds((GB,), i32),
                "cache": self.cache_specs(GB, S)}


def build_model(cfg: ModelConfig, ec: Optional[ExecConfig] = None) -> Model:
    return Model(cfg=cfg, ec=ec or DEFAULT_EXEC)
