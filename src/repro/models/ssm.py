"""Mamba2 (SSD) block: fused in-projection, causal conv, SSD scan, gated norm.

Full-sequence apply dispatches to ``kernels/ssd_scan`` (Pallas on TPU, chunked
XLA elsewhere); the decode step is a pure-jnp O(H·P·N) state update.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.execution import ExecConfig
from repro.models.layers import dt, trunc_normal
from repro.kernels.ssd_scan import ssd, ssd_step


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    H, P = cfg.ssm_nheads, cfg.ssm_headdim
    conv_ch = d_in + 2 * G * N
    proj = 2 * d_in + 2 * G * N + H          # [z, x, B, C, dt]
    return d_in, G, N, H, P, conv_ch, proj


def mamba_init(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in, G, N, H, P, conv_ch, proj = _dims(cfg)
    pdt = dt(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    A = jax.random.uniform(ks[2], (H,), jnp.float32, 1.0, 16.0)
    return {
        "w_in": trunc_normal(ks[0], (d, proj), d ** -0.5, pdt),
        "conv_w": trunc_normal(ks[1], (cfg.ssm_conv, conv_ch), 0.1, pdt),
        "conv_b": jnp.zeros((conv_ch,), pdt),
        "A_log": jnp.log(A),                                   # A = -exp(A_log)
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), pdt),
        "w_out": trunc_normal(ks[3], (d_in, d), d_in ** -0.5, pdt),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    d_in, G, N, H, P, conv_ch, proj = _dims(cfg)
    z = zxbcdt[..., :d_in]
    conv_in = zxbcdt[..., d_in:d_in + conv_ch]
    dt_raw = zxbcdt[..., d_in + conv_ch:]
    return z, conv_in, dt_raw


def _split_conv(cfg: ModelConfig, conv_out):
    d_in, G, N, H, P, conv_ch, proj = _dims(cfg)
    xc = conv_out[..., :d_in]
    Bc = conv_out[..., d_in:d_in + G * N]
    Cc = conv_out[..., d_in + G * N:]
    return xc, Bc, Cc


def _gated_norm(p, cfg: ModelConfig, y, z):
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    ms = (gf * gf).mean(-1, keepdims=True)
    out = gf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["norm_scale"].astype(jnp.float32)
    return out.astype(y.dtype)


def _causal_conv_full(p, x):
    """Depthwise causal conv.  x: (B, S, C) -> (B, S, C)."""
    W = p["conv_w"].shape[0]
    C = x.shape[-1]
    kernel = p["conv_w"].astype(x.dtype)[:, None, :]           # (W, 1, C)
    y = jax.lax.conv_general_dilated(
        x, kernel, window_strides=(1,), padding=[(W - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=C)
    return y + p["conv_b"].astype(x.dtype)


def mamba_apply_full(p, cfg: ModelConfig, ec: ExecConfig, x, *,
                     initial_state=None, return_state: bool = False):
    """x: (B, S, d).  Returns y or (y, (conv_state, ssm_state))."""
    B, S, d = x.shape
    d_in, G, N, H, P, conv_ch, proj = _dims(cfg)
    zxbcdt = x @ p["w_in"]
    z, conv_in, dt_raw = _split_proj(cfg, zxbcdt)
    conv_out = jax.nn.silu(_causal_conv_full(p, conv_in))
    xc, Bc, Cc = _split_conv(cfg, conv_out)

    x_h = xc.reshape(B, S, H, P)
    Bg = Bc.reshape(B, S, G, N)
    Cg = Cc.reshape(B, S, G, N)
    dts = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, final_state = ssd(x_h, dts, A, Bg, Cg, p["D"], chunk=cfg.ssm_chunk,
                         initial_state=initial_state, backend=ec.backend)
    y = y.reshape(B, S, d_in)
    out = _gated_norm(p, cfg, y, z) @ p["w_out"]
    if return_state:
        W = cfg.ssm_conv
        tail = conv_in[:, -(W - 1):, :] if S >= W - 1 else jnp.pad(
            conv_in, ((0, 0), (W - 1 - S, 0), (0, 0)))
        return out, (tail.astype(dt(cfg.dtype)), final_state)
    return out


def mamba_init_state(cfg: ModelConfig, batch: int):
    d_in, G, N, H, P, conv_ch, proj = _dims(cfg)
    return (jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dt(cfg.dtype)),
            jnp.zeros((batch, H, P, N), jnp.float32))


def mamba_step(p, cfg: ModelConfig, state, x_t):
    """One decode step.  x_t: (B, d); state = (conv_state, ssm_state)."""
    conv_state, ssm_state = state
    B, d = x_t.shape
    d_in, G, N, H, P, conv_ch, proj = _dims(cfg)
    zxbcdt = x_t @ p["w_in"]
    z, conv_in_t, dt_raw = _split_proj(cfg, zxbcdt)

    window = jnp.concatenate(
        [conv_state, conv_in_t[:, None, :].astype(conv_state.dtype)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(x_t.dtype)
    new_conv_state = window[:, 1:, :]

    xc, Bc, Cc = _split_conv(cfg, conv_out)
    x_h = xc.reshape(B, H, P)
    Bg = Bc.reshape(B, G, N)
    Cg = Cc.reshape(B, G, N)
    dts = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, new_ssm = ssd_step(ssm_state, x_h, dts, A, Bg, Cg, p["D"])
    y = y.reshape(B, d_in)
    out = _gated_norm(p, cfg, y, z) @ p["w_out"]
    return out, (new_conv_state, new_ssm)
