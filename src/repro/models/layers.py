"""Shared neural-net layers: norms, RoPE, MLPs, embeddings, chunked loss."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dt(cfg_dtype: str):
    return jnp.dtype(cfg_dtype)


def trunc_normal(key, shape, std: float, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), dt(cfg.param_dtype))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), dt(cfg.param_dtype))
    return p


def norm_apply(p, cfg: ModelConfig, x):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mean = xf.mean(-1, keepdims=True)
        var = ((xf - mean) ** 2).mean(-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps: float):
    """Per-head RMSNorm over the last (head_dim) axis — Qwen3 qk_norm."""
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (GPT-NeoX rotate-half convention)
# ---------------------------------------------------------------------------

def rope_apply(x, positions, theta: float):
    """x: (B, S, H, D); positions: (S,) or (B, S) absolute positions."""
    B, S, H, D = x.shape
    half = D // 2
    inv_freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = positions.astype(jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]                                   # (1, S)
    ang = pos[..., None] * inv_freq                           # (B?, S, half)
    cos = jnp.cos(ang)[:, :, None, :]                         # (B?, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def seq_shard_constraint(h, wide: bool = False):
    """Activation-sharding constraint for the residual stream inside layer
    scans.  Without it GSPMD is free to pick a replicated sharding for the
    scan carry (observed: the whole batch landing on every chip).

    ``wide=False`` (attention archs): batch over (pod, data), sequence over
    model (Megatron-SP) — cuts per-layer saved-residual memory by the model
    axis.  ``wide=True`` (SSM/hybrid): batch over every axis that divides
    (pure DP).  No-op outside a mesh context or when dims don't divide."""
    try:
        from jax._src import mesh as _mesh_lib
        mesh = _mesh_lib.thread_resources.env.physical_mesh
        if mesh is None or mesh.empty:
            mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not getattr(mesh, "shape_tuple", ()):
            return h
        ax = dict(mesh.shape_tuple)
        if h.ndim != 3:
            return h
        b_axes = []
        rem = h.shape[0]
        batch_pool = ("pod", "data", "model") if wide else ("pod", "data")
        for a in batch_pool:
            if a in ax and rem % ax[a] == 0:
                rem //= ax[a]
                b_axes.append(a)
        seq_ax = None
        if (not wide and "model" in ax and "model" not in b_axes
                and h.shape[1] % ax["model"] == 0):
            seq_ax = "model"
        from jax.sharding import PartitionSpec
        spec = PartitionSpec(tuple(b_axes) if b_axes else None, seq_ax, None)
        return jax.lax.with_sharding_constraint(h, spec)
    except Exception:
        return h


def sinusoidal_positions(n: int, d: int):
    """Whisper-style fixed sinusoidal embeddings (n, d)."""
    half = d // 2
    inv = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = jnp.arange(n)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    pdt = dt(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    std_in, std_out = d ** -0.5, f ** -0.5
    if cfg.mlp_act == "silu":
        p = {"w_gate": trunc_normal(ks[0], (d, f), std_in, pdt),
             "w_up": trunc_normal(ks[1], (d, f), std_in, pdt),
             "w_down": trunc_normal(ks[2], (f, d), std_out, pdt)}
    else:
        p = {"w_up": trunc_normal(ks[0], (d, f), std_in, pdt),
             "w_down": trunc_normal(ks[1], (f, d), std_out, pdt)}
    if cfg.mlp_bias:
        p["b_up"] = jnp.zeros((f,), pdt)
        p["b_down"] = jnp.zeros((d,), pdt)
    return p


def mlp_apply(p, cfg: ModelConfig, x):
    if cfg.mlp_act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = x @ p["w_up"]
        if "b_up" in p:
            h = h + p["b_up"]
        h = jax.nn.gelu(h)
    y = h @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return y


# ---------------------------------------------------------------------------
# Embedding / unembedding with chunked fused loss
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig):
    pdt = dt(cfg.param_dtype)
    p = {"embed": trunc_normal(key, (cfg.vocab_size, cfg.d_model), 0.02, pdt)}
    if not cfg.tie_embeddings:
        p["unembed"] = trunc_normal(jax.random.fold_in(key, 1),
                                    (cfg.d_model, cfg.vocab_size),
                                    cfg.d_model ** -0.5, pdt)
    return p


def embed_apply(p, cfg: ModelConfig, tokens):
    return p["embed"][tokens].astype(dt(cfg.dtype))


def unembed_matrix(p, cfg: ModelConfig):
    return p["embed"].T if cfg.tie_embeddings else p["unembed"]


def logits_apply(p, cfg: ModelConfig, h, f32: bool = True):
    w = unembed_matrix(p, cfg)
    logits = h @ w.astype(h.dtype)
    return logits.astype(jnp.float32) if f32 else logits


def softmax_xent(logits, targets, mask):
    """Mean masked cross-entropy.  logits: (..., V) f32; targets int; mask {0,1}."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum(), mask.sum()


def chunked_loss(p, cfg: ModelConfig, h, targets, mask, chunk: int):
    """Fused unembed + cross-entropy over sequence chunks.

    Avoids materialising the full (B, S, V) logit tensor — the chunk of logits
    lives only inside one scan step (then is recomputed in the backward pass
    under remat).  h: (B, S, d); targets/mask: (B, S).
    """
    B, S, d = h.shape
    if chunk <= 0 or S <= chunk or S % chunk != 0:
        logits = logits_apply(p, cfg, h)
        nll, denom = softmax_xent(logits, targets, mask)
        return nll / jnp.maximum(denom, 1.0)
    n = S // chunk
    hs = jnp.moveaxis(h.reshape(B, n, chunk, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, n, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)

    def body(carry, xs):
        h_c, t_c, m_c = xs
        logits = logits_apply(p, cfg, h_c)
        nll, denom = softmax_xent(logits, t_c, m_c)
        return (carry[0] + nll, carry[1] + denom), None

    body = jax.checkpoint(body)
    (nll, denom), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                   (hs, ts, ms))
    return nll / jnp.maximum(denom, 1.0)
