"""Mixture-of-experts layer (DeepSeek-style fine-grained: shared + routed top-k).

Two dispatch implementations, selected by ``ExecConfig.moe_impl``:

* ``einsum`` — GShard-style grouped capacity dispatch with one-hot einsums.
  GSPMD-native (experts shard over the ``model`` mesh axis; the partitioner
  inserts the all-to-alls).  Dispatch-einsum FLOPs overhead ≈ group·cf/(3·d_ff)
  — kept small via ``moe_group_size``; visible in the roofline's
  MODEL_FLOPS/HLO_FLOPs ratio and attacked in §Perf.
* ``sorted`` — dropless sort-by-expert + grouped matmul (``kernels/moe_gmm``,
  ragged_dot on XLA).  No capacity padding, no dispatch FLOPs; used by the
  beyond-paper EP path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.execution import ExecConfig
from repro.models.layers import dt, trunc_normal
from repro.kernels.moe_gmm import gmm


def moe_init(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    pdt = dt(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    std_in, std_out = d ** -0.5, f ** -0.5
    p = {
        "router": trunc_normal(ks[0], (d, E), std_in, jnp.float32),
        "w_gate": trunc_normal(ks[1], (E, d, f), std_in, pdt),
        "w_up": trunc_normal(ks[2], (E, d, f), std_in, pdt),
        "w_down": trunc_normal(ks[3], (E, f, d), std_out, pdt),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": trunc_normal(kss[0], (d, fs), std_in, pdt),
            "w_up": trunc_normal(kss[1], (d, fs), std_in, pdt),
            "w_down": trunc_normal(kss[2], (fs, d), fs ** -0.5, pdt),
        }
    return p


def router_topk(p, cfg: ModelConfig, x2d) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k routing.  x2d: (T, d).  Returns (gates (T,k) f32, idx (T,k) i32, aux)."""
    logits = (x2d.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)       # renorm
    # Switch-style load-balance auxiliary loss.
    E = cfg.n_experts
    me = probs.mean(axis=0)                                                # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / idx.size)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)
    return gates, idx.astype(jnp.int32), aux


def _expert_ffn_dense(p, x_ecd):
    """x: (..., E, C, d) -> gated FFN with per-expert weights."""
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", x_ecd, p["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", x_ecd, p["w_up"])
    return jnp.einsum("gecf,efd->gecd", h, p["w_down"])


def shared_expert_apply(p, x):
    s = p["shared"]
    h = jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_up"])
    return h @ s["w_down"]


def moe_apply(p, cfg: ModelConfig, ec: ExecConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).

    Decode steps (S == 1) always take the dropless sorted path: a serving
    token must never be capacity-dropped."""
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    gates, idx, aux = router_topk(p, cfg, x2d)
    impl = ec.moe_decode_impl if S == 1 else ec.moe_impl
    if impl == "sorted":
        y2d = _moe_sorted(p, cfg, x2d, gates, idx)
    else:
        y2d = _moe_einsum(p, cfg, ec, x2d, gates, idx)
    if cfg.n_shared_experts:
        y2d = y2d + shared_expert_apply(p, x2d)
    return y2d.reshape(B, S, d), aux


def _moe_einsum(p, cfg: ModelConfig, ec: ExecConfig, x2d, gates, idx):
    """GShard grouped capacity dispatch (one-hot einsums)."""
    T, d = x2d.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    Sg = min(ec.moe_group_size, T)
    T_pad = ((T + Sg - 1) // Sg) * Sg
    if T_pad != T:
        x2d = jnp.pad(x2d, ((0, T_pad - T), (0, 0)))
        gates = jnp.pad(gates, ((0, T_pad - T), (0, 0)))
        idx = jnp.pad(idx, ((0, T_pad - T), (0, 0)))
    Gg = T_pad // Sg
    cf = ec.moe_capacity_override or cfg.capacity_factor
    C = max(1, int(k * Sg * cf / E))

    oh = jax.nn.one_hot(idx.reshape(Gg, Sg, k), E, dtype=jnp.float32)
    # slot-major priority: all slot-0 choices first, then slot-1, ...
    ohf = oh.transpose(0, 2, 1, 3).reshape(Gg, k * Sg, E)
    cum = jnp.cumsum(ohf, axis=1) - ohf                      # exclusive
    pos = jnp.sum(cum * ohf, axis=-1)                         # (Gg, k*Sg)
    keep = (pos < C).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32)        # (Gg, k*Sg, C)
    disp_f = ohf[..., None] * pos_oh[:, :, None, :] * keep[..., None, None]
    # fold the k slots back onto tokens: (Gg, k, Sg, E, C) -> sum over k
    disp = disp_f.reshape(Gg, k, Sg, E, C).sum(axis=1)        # 0/1 (Gg,Sg,E,C)
    gates_f = gates.reshape(Gg, Sg, k).transpose(0, 2, 1).reshape(Gg, k * Sg)
    comb_f = disp_f * gates_f[..., None, None]
    comb = comb_f.reshape(Gg, k, Sg, E, C).sum(axis=1)        # (Gg,Sg,E,C)

    xg = x2d.reshape(Gg, Sg, d)
    cdt = xg.dtype
    expert_in = jnp.einsum("gsec,gsd->gecd", disp.astype(cdt), xg)
    expert_out = _expert_ffn_dense(p, expert_in)
    y = jnp.einsum("gsec,gecd->gsd", comb.astype(cdt), expert_out)
    return y.reshape(T_pad, d)[:T]


def _moe_sorted(p, cfg: ModelConfig, x2d, gates, idx):
    """Dropless sorted dispatch + grouped matmul (single-shard layout)."""
    T, d = x2d.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    flat_e = idx.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(flat_e)
    tok = order // k                                          # source token per row
    xs = x2d[tok]                                             # (T*k, d)
    group_sizes = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)

    h = jax.nn.silu(gmm(xs, p["w_gate"], group_sizes)) * \
        gmm(xs, p["w_up"], group_sizes)
    out = gmm(h.astype(xs.dtype), p["w_down"], group_sizes)   # (T*k, d)

    w = gates.reshape(-1)[order].astype(out.dtype)
    y = jnp.zeros((T, d), out.dtype).at[tok].add(out * w[:, None])
    return y
