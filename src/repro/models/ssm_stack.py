"""Attention-free Mamba2 stack (mamba2-130m) and Zamba2-style hybrid.

The hybrid applies a single *shared* transformer block (weights tied across
all applications — the Zamba2 parameter-sharing trick) before every
``attn_every`` Mamba2 layers.  Layers are organised as static **groups**
(shared block + inner ``lax.scan`` over that group's stacked Mamba layers):
no ``lax.cond`` in the hot path, so both the lowered program and the roofline
accounting pay for attention exactly n_groups times.  Serving state:
per-layer (conv_state, ssm_state); the hybrid adds one KV cache per shared-
block application.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.execution import ExecConfig
from repro.models import layers as L
from repro.models.attention import (attn_apply_decode, attn_apply_full,
                                    attn_apply_prefill, attn_init)
from repro.models.ssm import (mamba_apply_full, mamba_init, mamba_init_state,
                              mamba_step)
from repro.models.transformer import (_maybe_remat, block_decode, block_full,
                                      block_prefill, dense_block_init)


def n_attn_apps(cfg: ModelConfig) -> int:
    return math.ceil(cfg.n_layers / cfg.attn_every) if cfg.attn_every else 0


def _groups(cfg: ModelConfig):
    """Static (start, end) layer ranges, one group per shared-attn application."""
    if not cfg.attn_every:
        return [(0, cfg.n_layers)]
    k = cfg.attn_every
    return [(i, min(i + k, cfg.n_layers)) for i in range(0, cfg.n_layers, k)]


def _slice_layers(layers, a: int, b: int):
    return jax.tree.map(lambda x: x[a:b], layers)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    params = L.embed_init(ks[0], cfg)

    def layer_init(k):
        return {"ln": L.norm_init(cfg), "mamba": mamba_init(k, cfg)}

    params["layers"] = jax.vmap(layer_init)(jax.random.split(ks[1], cfg.n_layers))
    if cfg.family == "hybrid":
        params["shared_block"] = dense_block_init(ks[2], cfg)
    params["final_norm"] = L.norm_init(cfg)
    return params


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill base)
# ---------------------------------------------------------------------------

def _mamba_block_full(lp, cfg, ec, h, return_state=False):
    x = L.norm_apply(lp["ln"], cfg, h)
    if return_state:
        y, state = mamba_apply_full(lp["mamba"], cfg, ec, x, return_state=True)
        return h + y, state
    return h + mamba_apply_full(lp["mamba"], cfg, ec, x)


def forward_hidden(params, cfg: ModelConfig, ec: ExecConfig, tokens,
                   image_embeds=None, train: bool = True):
    h = L.embed_apply(params, cfg, tokens)
    S = h.shape[1]
    positions = jnp.arange(S)
    shared = params.get("shared_block")

    def body(carry, lp):
        h, = carry
        if ec.shard_activations:
            h = L.seq_shard_constraint(h, wide=True)
        h = _mamba_block_full(lp, cfg, ec, h)
        return (h,), None

    if train:
        body = _maybe_remat(body, ec)
    for (a, b) in _groups(cfg):
        if shared is not None:
            if ec.shard_activations:
                h = L.seq_shard_constraint(h, wide=True)
            hb = functools.partial(block_full, shared, cfg, ec,
                                   positions=positions)
            if train:
                h = _maybe_remat(lambda hh: hb(hh)[0], ec)(h)
            else:
                h = hb(h)[0]
        (h,), _ = jax.lax.scan(body, (h,), _slice_layers(params["layers"], a, b))
    return L.norm_apply(params["final_norm"], cfg, h), jnp.zeros((), jnp.float32)


def forward_train(params, cfg: ModelConfig, ec: ExecConfig, batch):
    h, aux = forward_hidden(params, cfg, ec, batch["tokens"], train=True)
    loss = L.chunked_loss(params, cfg, h, batch["targets"], batch["mask"],
                          ec.loss_chunk)
    return loss + aux, {"loss": loss, "aux_loss": aux}


def forward_logits(params, cfg: ModelConfig, ec: ExecConfig, tokens,
                   image_embeds=None):
    h, _ = forward_hidden(params, cfg, ec, tokens, train=False)
    return L.logits_apply(params, cfg, h, f32=ec.logits_f32)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    conv0, ssm0 = mamba_init_state(cfg, batch)
    Ln = cfg.n_layers
    cache = {
        "conv": jnp.broadcast_to(conv0, (Ln,) + conv0.shape).copy(),
        "ssm": jnp.broadcast_to(ssm0, (Ln,) + ssm0.shape).copy(),
    }
    if cfg.family == "hybrid":
        A = n_attn_apps(cfg)
        kv = lambda: jnp.zeros(
            (A, batch, max_len, cfg.n_kv_heads, cfg.head_dim), L.dt(cfg.dtype))
        cache["k"] = kv()
        cache["v"] = kv()
    return cache


def prefill(params, cfg: ModelConfig, ec: ExecConfig, tokens, cache,
            image_embeds=None):
    cache = dict(cache)
    h = L.embed_apply(params, cfg, tokens)
    B, S = tokens.shape
    positions = jnp.arange(S)
    shared = params.get("shared_block")

    def body(h, lp):
        if ec.shard_activations:
            h = L.seq_shard_constraint(h, wide=True)
        h, state = _mamba_block_full(lp, cfg, ec, h, return_state=True)
        return h, state

    convs, ssms, new_k, new_v = [], [], [], []
    for g, (a, b) in enumerate(_groups(cfg)):
        if shared is not None:
            h, ck, cv = block_prefill(shared, cfg, ec, h, cache["k"][g],
                                      cache["v"][g], positions)
            new_k.append(ck)
            new_v.append(cv)
        h, (conv_g, ssm_g) = jax.lax.scan(
            body, h, _slice_layers(params["layers"], a, b))
        convs.append(conv_g)
        ssms.append(ssm_g)
    cache["conv"] = jnp.concatenate(convs, axis=0)
    cache["ssm"] = jnp.concatenate(ssms, axis=0)
    if shared is not None:
        cache["k"] = jnp.stack(new_k, axis=0)
        cache["v"] = jnp.stack(new_v, axis=0)
    h = L.norm_apply(params["final_norm"], cfg, h)
    logits = L.logits_apply(params, cfg, h[:, -1:], f32=ec.logits_f32)[:, 0]
    return logits, cache, S


def decode_step(params, cfg: ModelConfig, ec: ExecConfig, token, cache, index):
    cache = dict(cache)
    h = L.embed_apply(params, cfg, token[:, None])
    shared = params.get("shared_block")

    def body(h, xs):
        lp, conv, ssm = xs
        x = L.norm_apply(lp["ln"], cfg, h[:, 0])
        y, (conv, ssm) = mamba_step(lp["mamba"], cfg, (conv, ssm), x)
        h = h + y[:, None]
        return h, (conv, ssm)

    convs, ssms, new_k, new_v = [], [], [], []
    for g, (a, b) in enumerate(_groups(cfg)):
        if shared is not None:
            h, ck, cv = block_decode(shared, cfg, ec, h, cache["k"][g],
                                     cache["v"][g], index)
            new_k.append(ck)
            new_v.append(cv)
        h, (conv_g, ssm_g) = jax.lax.scan(
            body, h, (_slice_layers(params["layers"], a, b),
                      cache["conv"][a:b], cache["ssm"][a:b]))
        convs.append(conv_g)
        ssms.append(ssm_g)
    cache["conv"] = jnp.concatenate(convs, axis=0)
    cache["ssm"] = jnp.concatenate(ssms, axis=0)
    if shared is not None:
        cache["k"] = jnp.stack(new_k, axis=0)
        cache["v"] = jnp.stack(new_v, axis=0)
    h = L.norm_apply(params["final_norm"], cfg, h)
    logits = L.logits_apply(params, cfg, h, f32=ec.logits_f32)[:, 0]
    return logits, cache
