"""Execution configuration: knobs that change *how* a model runs, not *what*.

These are the hillclimb levers — kernel backend, remat policy, MoE dispatch
implementation, loss chunking, microbatching — kept separate from ModelConfig
so the same architecture can be lowered under different execution plans and
compared in the roofline table.
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ExecConfig:
    backend: str = "auto"            # kernel dispatch: auto|xla|pallas|pallas_interpret
    remat: str = "full"              # "none" | "full" | "dots"
    scan_layers: bool = True         # lax.scan over stacked layer params
    moe_impl: str = "einsum"         # "einsum" (GShard dense dispatch) | "sorted" (gmm)
    moe_decode_impl: str = "sorted"  # decode steps: "sorted" (exact) | "einsum"
    moe_capacity_override: float = 0.0   # >0 overrides cfg.capacity_factor
    moe_group_size: int = 1024       # GShard dispatch group size (tokens)
    loss_chunk: int = 512            # seq chunk for fused unembed+xent (0 = off)
    attn_block_k: int = 512          # xla flash attention KV tile
    attn_buckets: int = 1            # causal q-bucketing: bucket i attends its
                                     # prefix only (4 -> 0.625x attention work)
    microbatches: int = 1            # gradient accumulation steps
    logits_f32: bool = True
    flash_for_prefill: bool = True   # blocked attention (vs naive ref) in prefill
    shard_activations: bool = True   # SP: residual stream seq-sharded over model
    accum_dtype: str = "float32"     # grad-accumulator dtype (bf16 for 1T cfg)

    def with_overrides(self, **kw) -> "ExecConfig":
        return replace(self, **kw)


DEFAULT_EXEC = ExecConfig()
