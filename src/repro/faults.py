"""Deterministic fault injection for the runtime and state fabric.

Mirrors the sanitizer's zero-overhead discipline: the module global
``_PLAN`` is ``None`` except while a :class:`FaultPlan` is armed, and every
injection site is a single call to :func:`point`, whose disarmed fast path
is one pointer compare.  Sites never branch on the global themselves —
faasmlint's ``fault-point`` rule flags any access to the plan internals
outside this file, so the full catalogue of injection sites is exactly the
set of ``faults.point(...)`` calls in the tree.

Fault points (see ``docs/fault_model.md`` for the catalogue and the
recovery contract each one exercises):

==================== ======== ==========================================
point                action   site
==================== ======== ==========================================
host-crash-pre-push  raise    ``LocalTier.push_delta`` entry, before any
                              global-tier effect (``HostCrash``)
host-crash-post-push raise    ``LocalTier.push_delta`` exit, after the
                              delta landed globally (``HostCrash``)
wire-frame-drop      drop     ``LocalTier._deliver`` — the broadcast
                              frame is lost before the subscriber
wire-frame-delay     delay    ``LocalTier._deliver`` — the frame arrives
                              late (races the next push)
subscriber-raise     raise    ``LocalTier._deliver`` — the subscriber
                              callback blows up mid-broadcast
codec-error          raise    ``Int8Codec.encode`` — the quantised
                              encode fails mid-push
slow-host            delay    ``Host._run`` dispatch and
                              ``Faaslet.reset_from_base`` — the host
                              straggles, provoking speculation
tier-pull-stall      delay    ``LocalTier.pull`` entry — a refresh
                              stalls while pushers race ahead
queue-flood          drop     ``Host.submit`` — the bounded admission
                              queue reports full, forcing the overload
                              spill/shed path (``repro.overload``)
subscriber-stall     delay    ``LocalTier._deliver`` — the subscriber
                              stalls applying a broadcast frame; the
                              pump absorbs it, the pusher must not block
deadline-clock-skew  delay    ``Host._run`` dequeue deadline check — the
                              clock reads late, evaporating the call's
                              remaining budget before the floor check
==================== ======== ==========================================

A plan is a seeded schedule: each rule names a point, an Nth-hit trigger,
an optional repeat count and per-call / per-key / per-host filters.  Arm
with :func:`arm` (or the :func:`armed` context manager), disarm with
:func:`disarm`.  ``FaultPlan.random(seed)`` builds a randomized-but-
reproducible schedule for the chaos matrix.
"""
from __future__ import annotations

import contextlib
import random
import threading
import time
from dataclasses import dataclass
from typing import List, Optional, Tuple

FAULT_POINTS = frozenset({
    "host-crash-pre-push",
    "host-crash-post-push",
    "wire-frame-drop",
    "wire-frame-delay",
    "subscriber-raise",
    "codec-error",
    "slow-host",
    "tier-pull-stall",
    "queue-flood",
    "subscriber-stall",
    "deadline-clock-skew",
})

# Action class per point: raising points throw, delaying points sleep and
# let the site continue, dropping points return True so the site discards
# the in-flight artefact (or, for queue-flood, treats the admission queue
# as full).
_DELAYING = frozenset({"wire-frame-delay", "slow-host", "tier-pull-stall",
                       "subscriber-stall", "deadline-clock-skew"})
_DROPPING = frozenset({"wire-frame-drop", "queue-flood"})
_CRASHING = frozenset({"host-crash-pre-push", "host-crash-post-push"})


class FaultInjected(RuntimeError):
    """An armed fault fired at a raising point."""


class HostCrash(FaultInjected):
    """Injected host death: the runtime fails the host and requeues its
    in-flight calls instead of settling the victim call as failed."""


@dataclass
class FaultRule:
    """One trigger in a plan: fire on the nth..nth+times-1 matching hits."""
    point: str
    nth: int = 1
    times: int = 1
    call: Optional[str] = None
    key: Optional[str] = None
    host: Optional[str] = None
    delay_s: float = 0.01
    matched: int = 0
    fired: int = 0

    def matches(self, call, key, host):
        return ((self.call is None or self.call == call)
                and (self.key is None or self.key == key)
                and (self.host is None or self.host == host))


class FaultPlan:
    """A seeded, deterministic fault schedule."""

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.rules: List[FaultRule] = []
        self.log: List[Tuple[str, Optional[str], Optional[str],
                             Optional[str]]] = []
        self._hits = {}
        self._mu = threading.Lock()

    def add(self, point: str, *, nth: int = 1, times: int = 1,
            call: Optional[str] = None, key: Optional[str] = None,
            host: Optional[str] = None, delay_s: float = 0.01) -> "FaultPlan":
        if point not in FAULT_POINTS:
            raise ValueError(f"unknown fault point {point!r}; "
                             f"known: {sorted(FAULT_POINTS)}")
        if nth < 1 or times < 1:
            raise ValueError("nth and times are 1-based and positive")
        self.rules.append(FaultRule(point, nth=nth, times=times, call=call,
                                    key=key, host=host, delay_s=delay_s))
        return self

    @classmethod
    def random(cls, seed: int, *, n_rules: int = 4, max_nth: int = 12,
               points: Tuple[str, ...] = ("wire-frame-drop",
                                          "wire-frame-delay",
                                          "subscriber-raise",
                                          "codec-error",
                                          "tier-pull-stall")) -> "FaultPlan":
        """Randomized-but-reproducible schedule over the recoverable
        points (host crashes are driven explicitly by the chaos killer)."""
        rng = random.Random(seed)
        plan = cls(seed)
        for _ in range(n_rules):
            plan.add(rng.choice(points), nth=rng.randint(1, max_nth),
                     times=rng.randint(1, 2),
                     delay_s=rng.uniform(0.0005, 0.008))
        return plan

    def hits(self, name: str) -> int:
        with self._mu:
            return self._hits.get(name, 0)

    def fired(self, name: Optional[str] = None) -> int:
        with self._mu:
            if name is None:
                return len(self.log)
            return sum(1 for p, _c, _k, _h in self.log if p == name)

    def _fire(self, name, call, key, host):
        if name not in FAULT_POINTS:
            raise ValueError(f"unregistered fault point {name!r}")
        action, delay = None, 0.0
        with self._mu:
            self._hits[name] = self._hits.get(name, 0) + 1
            for r in self.rules:
                if r.point != name or not r.matches(call, key, host):
                    continue
                r.matched += 1
                if r.nth <= r.matched < r.nth + r.times:
                    r.fired += 1
                    self.log.append((name, call, key, host))
                    if name in _DELAYING:
                        action, delay = "delay", r.delay_s
                    elif name in _DROPPING:
                        action = "drop"
                    else:
                        action = "raise"
                    break
        tel = _TEL
        if tel is not None and action is not None:
            tel.instant(f"fault.{name}", "fault", point=name, action=action,
                        key=key, target_host=host)
        if action == "delay":
            time.sleep(delay)
            return False
        if action == "drop":
            return True
        if action == "raise":
            exc = HostCrash if name in _CRASHING else FaultInjected
            ctx = ", ".join(f"{k}={v}" for k, v in
                            (("call", call), ("key", key), ("host", host))
                            if v is not None)
            raise exc(f"injected fault: {name}" + (f" ({ctx})" if ctx else ""))
        return False


# The one-compare disarmed fast path, same shape as the sanitizer's _SAN
# module globals.  Nothing outside this module may read it (lint rule
# `fault-point`); sites call point() unconditionally.
_PLAN: Optional[FaultPlan] = None

# Armed tracer (set by repro.telemetry.spans._install): every *triggered*
# fault rule drops a `fault.<point>` instant span so chaos traces show
# where the schedule bit.  Ring write only — safe under any lock.
_TEL = None


def point(name: str, call: Optional[str] = None, key: Optional[str] = None,
          host: Optional[str] = None) -> bool:
    """Named injection site.  Disarmed: one pointer compare, returns False.

    Armed: counts the hit against the plan and, if a rule triggers, raises
    (crash/raise points), sleeps (delay points), or returns True (drop
    points — the caller discards the in-flight artefact).
    """
    plan = _PLAN
    if plan is None:
        return False
    return plan._fire(name, call, key, host)


def arm(plan: FaultPlan) -> FaultPlan:
    global _PLAN
    _PLAN = plan
    return plan


def disarm() -> None:
    global _PLAN
    _PLAN = None


def active() -> Optional[FaultPlan]:
    """The armed plan, if any (for tests/benchmarks; sites use point())."""
    return _PLAN


@contextlib.contextmanager
def armed(plan: FaultPlan):
    arm(plan)
    try:
        yield plan
    finally:
        disarm()
