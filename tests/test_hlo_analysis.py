"""Unit tests for the HLO static analyzer (FLOPs/bytes/collectives)."""
import textwrap

import pytest

from repro.distributed.hlo_analysis import HloAnalyzer, analyze

SAMPLE = textwrap.dedent("""\
    HloModule jit_f, is_scheduled=true

    %body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %iv = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16] get-tuple-element(%p), index=1
      %w = f32[16,16] constant({...})
      %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16] all-reduce(%dot.1), replica_groups={}, to_apply=%add.1
      %one = s32[] constant(1)
      %niv = s32[] add(%iv, %one)
      ROOT %t = (s32[], f32[8,16]) tuple(%niv, %ar)
    }

    %cond.1 (p2: (s32[], f32[8,16])) -> pred[] {
      %p2 = (s32[], f32[8,16]) parameter(0)
      %iv2 = s32[] get-tuple-element(%p2), index=0
      %lim = s32[] constant(5)
      ROOT %cmp = pred[] compare(%iv2, %lim), direction=LT
    }

    ENTRY %main (a: f32[8,16]) -> f32[8,16] {
      %a = f32[8,16] parameter(0)
      %zero = s32[] constant(0)
      %t0 = (s32[], f32[8,16]) tuple(%zero, %a)
      %w.243 = (s32[], f32[8,16]) while(%t0), condition=%cond.1, body=%body.1
      %ag = f32[16,16] all-gather(%a), replica_groups={}, dimensions={0}
      ROOT %out = f32[8,16] get-tuple-element(%w.243), index=1
    }
""")


def test_dot_flops_and_trip_count():
    c = analyze(SAMPLE)
    # dot: 2 * 8*16 (out) * 16 (contract) = 4096 flops, x5 loop trips
    assert c.flops == 4096 * 5
    # all-reduce operand = 8*16*4 bytes, x5; all-gather operand = 8*16*4 once
    assert c.collective["all-reduce"] == 512 * 5
    assert c.collective["all-gather"] == 512
    assert c.collective_counts["all-reduce"] == 5


def test_known_trip_count_config_preferred():
    sample = SAMPLE.replace(
        "condition=%cond.1, body=%body.1",
        'condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"7"}}')
    c = analyze(sample)
    assert c.flops == 4096 * 7


def test_real_module_parses():
    import os
    path = "/tmp/hlo_sample.txt"
    if not os.path.exists(path):
        pytest.skip("sample HLO not present")
    c = analyze(open(path).read())
    assert c.flops > 0 and c.bytes > 0
    assert c.collective_bytes > 0


def test_bytes_skip_control_ops():
    a = HloAnalyzer(SAMPLE)
    c = a.entry_costs()
    # entry bytes: only the all-gather instruction counts in ENTRY
    # (parameter/tuple/gte/while are control ops)
    assert c.bytes >= 512 + 1024      # ag operand + result at minimum
