"""Quantised device-side delta push: wire round-trip bounds, error-feedback
convergence, HOGWILD composition, wire-byte accounting (the ≤30%-of-exact
acceptance bound), pad-region no-op, device-replica staleness, fallbacks.

The ``pallas_interpret`` parametrisations are auto-marked slow by conftest;
the xla-backend rows run in the ``scripts/tier1.sh`` fast gate."""
import threading

import numpy as np
import pytest

from repro.kernels.state_push import (apply_delta, dequantize, quantize_delta,
                                      wire_nbytes)
from repro.state.kv import GlobalTier
from repro.state.local import INT8_WIRE_MIN_BYTES, LocalTier

BACKENDS = ("xla", "pallas_interpret")


def _rng(seed=0):
    return np.random.default_rng(seed)


# -- wire format round trip ----------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", [1, 100, 128, 1000])
def test_wire_roundtrip_error_bound(backend, n):
    """Quantise→dequantise error is bounded by half a quantisation step
    (per-row absmax / 127 / 2)."""
    rng = _rng(n)
    local = rng.normal(size=n).astype(np.float32)
    base = rng.normal(size=n).astype(np.float32)
    q, s, numel = quantize_delta(local, base, backend=backend)
    assert numel == n
    deq = np.asarray(dequantize(q, s, numel))
    delta = local - base
    bound = np.abs(delta).max() / 254.0 + 1e-6
    assert np.abs(deq - delta).max() <= bound


@pytest.mark.parametrize("backend", BACKENDS)
def test_pad_region_quantises_to_zero(backend):
    """Non-multiple-of-128 values pad to (rows, 128); the pad must carry
    zero delta so applying a padded push is a no-op beyond ``numel``."""
    n = 130                                   # 2 rows, 126 pad lanes
    rng = _rng(3)
    local = rng.normal(size=n).astype(np.float32)
    base = rng.normal(size=n).astype(np.float32)
    q, s, numel = quantize_delta(local, base, backend=backend)
    assert q.shape == (2, 128) and numel == n
    assert np.all(np.asarray(q).reshape(-1)[n:] == 0)
    # apply through the kernel: the value beyond numel is never touched
    gv = rng.normal(size=n).astype(np.float32)
    out = np.asarray(apply_delta(gv, q, s, backend=backend))
    bound = np.abs(local - base).max() / 254.0 + 1e-5
    assert np.abs(out - (gv + (local - base))).max() <= bound


@pytest.mark.parametrize("backend", BACKENDS)
def test_tier_push_matches_kernel_apply(backend):
    """LocalTier int8 push through GlobalTier.apply_quantized lands the same
    value as applying the wire tuple with the fused kernel."""
    n = INT8_WIRE_MIN_BYTES // 4 * 2
    rng = _rng(7)
    init = rng.normal(size=n).astype(np.float32)
    gt = GlobalTier()
    gt.set("w", init.tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("w")
    lt.snapshot_base("w")
    upd = (rng.normal(size=n) * 0.1).astype(np.float32)
    lt.replica("w").buf.view(np.float32)[:] += upd
    lt.push_delta("w", wire="int8", backend=backend)
    got = np.frombuffer(gt.get("w", host="x"), np.float32)
    q, s, numel = quantize_delta(init + upd, init, backend=backend)
    want = np.asarray(apply_delta(init, q, s, backend=backend))
    np.testing.assert_allclose(got, want, atol=1e-5)


# -- error feedback ------------------------------------------------------------


def test_error_feedback_residual_bounded_and_converges():
    """≥10 consecutive int8 pushes track the exact path within tolerance and
    the per-replica residual stays bounded (no bias accumulation) — the
    acceptance-criterion property."""
    n = 1 << 18                               # 1 MB of f32
    rng = _rng(11)
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("w")
    lt.snapshot_base("w")
    view = lt.replica("w").buf.view(np.float32)
    expected = np.zeros(n, np.float32)
    scale = 0.01
    resid_caps = []
    for i in range(12):
        u = (rng.normal(size=n) * scale).astype(np.float32)
        view[:] += u
        expected += u
        lt.push_delta("w", wire="int8")
        r = lt.replica("w").residual
        resid_caps.append(float(np.abs(r).max()))
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    # with error feedback, total error ≤ one half-step of the *last* push,
    # not the sum of 12 half-steps
    one_step = scale * 6 / 254.0              # ~absmax of one N(0,0.01) push
    assert np.abs(final - expected).max() <= one_step * 2
    # residual bounded across all pushes: no growth trend
    assert max(resid_caps) <= one_step * 2
    assert resid_caps[-1] <= 2 * max(resid_caps[:3]) + 1e-6


def test_error_feedback_beats_no_feedback():
    """The same biased update stream quantised N times: with feedback the
    accumulated value stays near exact; zeroing the residual each push
    (no feedback) drifts measurably further."""
    n = 1 << 14
    pushes = 15
    u = np.full(n, 0.003, np.float32)         # constant update: worst case
    u[::7] = 0.1                              # large row absmax -> coarse step

    def run(feedback: bool) -> float:
        gt = GlobalTier()
        gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
        lt = LocalTier("h0", gt)
        lt.pull("w")
        lt.snapshot_base("w")
        view = lt.replica("w").buf.view(np.float32)
        for _ in range(pushes):
            view[:] += u
            lt.push_delta("w", wire="int8")
            if not feedback:
                lt.replica("w").residual[:] = 0
        final = np.frombuffer(gt.get("w", host="x"), np.float32)
        return float(np.abs(final - u * pushes).max())

    assert run(True) < run(False)


# -- HOGWILD composition -------------------------------------------------------


def test_concurrent_int8_pushes_compose():
    """Concurrent quantised pushes from different hosts accumulate instead
    of overwriting (each under the key's global write lock)."""
    n = INT8_WIRE_MIN_BYTES // 4
    n_hosts = 4
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    tiers = [LocalTier(f"h{i}", gt) for i in range(n_hosts)]
    per = n // n_hosts
    for i, lt in enumerate(tiers):
        lt.pull("w")
        lt.snapshot_base("w")
        view = lt.replica("w").buf.view(np.float32)
        # ±c patterns quantise exactly (scale = c/127, q = ±127)
        view[i * per:(i + 1) * per] += np.float32(i + 1)
    errs = []

    def push(lt):
        try:
            lt.push_delta("w", wire="int8")
        except Exception as e:                # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=push, args=(lt,)) for lt in tiers]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    want = np.zeros(n, np.float32)
    for i in range(n_hosts):
        want[i * per:(i + 1) * per] = i + 1
    np.testing.assert_allclose(final, want, atol=1e-4)


# -- wire-byte accounting (the ≤30% acceptance bound) --------------------------


def test_int8_push_of_4mb_key_moves_under_30_percent():
    """Acceptance criterion: int8 push_delta of a ≥4 MB f32 key moves ≤ 30%
    of the exact-path bytes, with the residual bounded across ≥10 pushes."""
    size = 4 << 20                            # 4 MB
    n = size // 4
    rng = _rng(23)

    def run(wire: str):
        gt = GlobalTier()
        gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
        lt = LocalTier("h0", gt)
        lt.pull("w")
        lt.snapshot_base("w")
        gt.reset_metrics()
        view = lt.replica("w").buf.view(np.float32)
        resid_caps = []
        for i in range(10):
            view[:] += (rng.normal(size=n) * 0.01).astype(np.float32)
            lt.push_delta("w", wire=wire)
            r = lt.replica("w").residual
            if r is not None:
                resid_caps.append(float(np.abs(r).max()))
        return gt.bytes_pushed["h0"], resid_caps

    exact_bytes, _ = run("exact")
    int8_bytes, resid_caps = run("int8")
    assert exact_bytes == 10 * size           # exact accounts value bytes
    assert int8_bytes <= 0.30 * exact_bytes   # wire accounting: ~26% + scales
    assert len(resid_caps) == 10
    assert max(resid_caps) <= 0.01 * 6 / 254.0 * 2   # bounded, no growth


def test_apply_quantized_accounts_wire_bytes():
    n = 1024
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    gt.reset_metrics()
    delta = np.full(n, 0.5, np.float32)
    q, s, numel = quantize_delta(delta, np.zeros(n, np.float32))
    q, s = np.asarray(q), np.asarray(s)
    moved = gt.apply_quantized("w", q, s, numel, host="h0")
    wire = wire_nbytes(q, s)
    assert moved == wire == q.nbytes + s.nbytes
    assert gt.bytes_pushed["h0"] == wire      # not the 4 KB of value bytes
    assert gt.total_copied() == wire
    np.testing.assert_allclose(
        np.frombuffer(gt.get("w", host="x"), np.float32), 0.5, atol=0.5 / 127)


# -- fallbacks -----------------------------------------------------------------


def test_sub_threshold_and_non_float_fall_back_exact():
    gt = GlobalTier()
    tiny = np.arange(16, dtype=np.float32)
    gt.set("t", np.zeros(16, np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("t")
    lt.snapshot_base("t")
    lt.replica("t").buf.view(np.float32)[:] = tiny
    moved = lt.push_delta("t", wire="int8")   # < INT8_WIRE_MIN_BYTES
    assert moved == 64                        # exact in-place path
    np.testing.assert_array_equal(
        np.frombuffer(gt.get("t", host="x"), np.float32), tiny)

    gt.set("i", np.zeros(INT8_WIRE_MIN_BYTES // 8, np.int64).tobytes(),
           host="up")
    lt.pull("i")
    lt.snapshot_base("i")
    lt.replica("i").buf.view(np.int64)[0] = 7
    lt.push_delta("i", dtype=np.int64, wire="int8")   # int dtype: exact
    assert np.frombuffer(gt.get("i", host="x"), np.int64)[0] == 7

    with pytest.raises(ValueError):
        lt.push_delta("t", wire="bogus")


# -- device-resident replica plane ---------------------------------------------


def test_device_replica_sync_and_staleness():
    import jax.numpy as jnp

    n = INT8_WIRE_MIN_BYTES // 4
    gt = GlobalTier()
    gt.set("w", np.arange(n, dtype=np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("w")
    dv = lt.to_device("w")
    assert np.asarray(dv)[5] == 5.0
    assert not lt.device_stale("w")
    ver = lt.device_replica("w").synced_version
    assert lt.to_device("w") is dv            # synced: no re-upload

    # host write bumps the version -> device copy goes stale
    lt.replica("w").buf.view(np.float32)[0] = 99.0
    lt.mark_dirty("w", 0, 4)
    assert lt.device_stale("w")
    dv2 = lt.to_device("w")
    assert np.asarray(dv2)[0] == 99.0
    assert lt.device_replica("w").synced_version > ver

    # device-side compute, then explicit D2H sync
    lt.update_device("w", dv2 + 1.0)
    assert not lt.device_stale("w")           # device is ahead, not stale
    assert lt.device_replica("w").device_dirty
    moved = lt.from_device("w")
    assert moved == n * 4
    assert lt.replica("w").buf.view(np.float32)[0] == 100.0
    assert not lt.device_replica("w").device_dirty
    assert jnp is not None


def test_device_native_int8_push_skips_host_buffer():
    """A device-resident replica pushes straight from its device arrays: the
    host replica buffer is never consulted (we poison it to prove it)."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("w")
    dv = lt.to_device("w", track_delta=True)
    lt.update_device("w", dv + 2.0)           # ±c quantises exactly
    lt.replica("w").buf.view(np.float32)[:] = 1e9   # poison the host copy
    gt.reset_metrics()
    moved = lt.push_delta("w", wire="int8")
    assert moved < n * 4                      # wire bytes, not value bytes
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    np.testing.assert_allclose(final, 2.0, atol=1e-5)
    # base refreshed on device: an immediate re-push carries ~zero delta
    lt.push_delta("w", wire="int8")
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    np.testing.assert_allclose(final, 2.0, atol=1e-5)


def test_stale_device_copy_is_not_pushed():
    """Host writes after the device sync invalidate the device arrays: the
    push must fall back to the (authoritative) host buffer."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("w")
    lt.snapshot_base("w")
    lt.to_device("w", track_delta=True)
    view = lt.replica("w").buf.view(np.float32)
    view[:] = 3.0
    lt.mark_dirty("w", 0, n * 4)              # device now stale
    lt.push_delta("w", wire="int8")
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    np.testing.assert_allclose(final, 3.0, atol=1e-4)


def test_device_push_without_track_delta_uses_host_base():
    """Regression: a device copy synced without track_delta must diff
    against the host-side base snapshot, not zeros (zeros re-pushes the
    whole value and doubles the global)."""
    n = INT8_WIRE_MIN_BYTES // 4
    init = np.arange(n, dtype=np.float32)
    gt = GlobalTier()
    gt.set("w", init.tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("w")
    lt.snapshot_base("w")
    lt.to_device("w")                         # no track_delta
    lt.push_delta("w", wire="int8")           # no changes since snapshot
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    np.testing.assert_allclose(final, init, atol=np.abs(init).max() / 200)


def test_from_device_carries_base_no_double_push():
    """Regression: after a device-native push and a D2H sync, a host-path
    push must not re-apply the device-era delta (the device base comes back
    with the value)."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("w")
    lt.snapshot_base("w")
    dv = lt.to_device("w", track_delta=True)
    lt.update_device("w", dv + 2.0)
    lt.push_delta("w", wire="int8")           # ships +2 from the device
    lt.from_device("w")                       # host buf = 2.0, base follows
    lt.push_delta("w")                        # exact host push: delta ≈ 0
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    np.testing.assert_allclose(final, 2.0, atol=1e-4)


def test_track_delta_does_not_drop_pending_device_writes():
    """Regression: to_device(track_delta=True) while device writes are
    pending must not re-arm the base to the unsynced value (that would
    erase the pending delta from every future push)."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("w")
    dv = lt.to_device("w", track_delta=True)
    lt.update_device("w", dv + 2.0)               # pending, un-pushed
    again = lt.to_device("w", track_delta=True)   # loop-top re-sync: no-op
    assert np.asarray(again)[0] == 2.0            # device value preserved
    lt.push_delta("w", wire="int8")
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    np.testing.assert_allclose(final, 2.0, atol=1e-5)   # +2 NOT lost


def test_device_push_then_host_push_no_double_apply():
    """Regression: a device-fresh push whose value mirrors the host buffer
    must refresh the host base too — a later host-path push re-applied the
    same delta otherwise (global read 2.0 where 1.0 is correct)."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("w")
    lt.snapshot_base("w")
    lt.replica("w").buf.view(np.float32)[:] = 1.0   # host write
    lt.mark_dirty("w", 0, n * 4)
    lt.to_device("w")                               # sync, no track_delta
    lt.push_delta("w", wire="int8")                 # device branch: pushes +1
    lt.mark_dirty("w", 0, 4)                        # device goes stale
    lt.push_delta("w", wire="int8")                 # host branch: delta ≈ 0
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    np.testing.assert_allclose(final, 1.0, atol=1e-4)


def test_grown_replica_base_zero_extended():
    """Regression: a base snapshotted before the replica grew is
    zero-extended for the new tail (never pushed => base 0 there), not
    replaced with an all-zeros base (which would re-push the whole value)."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt = GlobalTier()
    gt.set("w", np.full(n, 5.0, np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("w")
    lt.snapshot_base("w")                           # base = 5.0 * n
    gt.append("w", np.full(n, 3.0, np.float32).tobytes(), host="up")
    lt.replica("w", size=2 * n * 4)                 # buf grows; base is stale
    lt.pull_chunk("w", 0)                           # old chunk present
    r = lt.replica("w")
    r.present_chunks.clear()
    r.full = False
    lt.pull("w")                                    # refresh whole value
    lt.push_delta("w", wire="int8")                 # delta vs old-base: tail!
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    # head: 5 - 5 = 0 delta; tail: base zero-extended -> pushes +3 once
    np.testing.assert_allclose(final[:n], 5.0, atol=1e-3)
    np.testing.assert_allclose(final[n:], 6.0, atol=1e-3)


def test_host_writes_survive_device_dirty_push():
    """Regression: a device-dirty int8 push must not clear the host dirty
    record — host writes made alongside pending device writes were not in
    the push and must still reach the global tier."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("w")
    dv = lt.to_device("w", track_delta=True)
    lt.update_device("w", dv + 2.0)               # pending device write
    lt.replica("w").buf.view(np.float32)[0] = 7.0  # concurrent host write
    lt.mark_dirty("w", 0, 4)
    lt.push_delta("w", wire="int8")               # device branch: ships +2
    # the push covered only the device delta: the host dirty record must
    # survive so those writes can still be pushed (push_dirty carries
    # overwrite semantics, so reconciling the divergence is the caller's
    # from_device + push; the record existing is what makes that possible)
    assert lt.replica("w").dirty_chunks
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    np.testing.assert_allclose(final, 2.0, atol=1e-5)
