"""faasmlint: every rule catches a seeded violation, spares the clean
idiom, honours justified suppressions — and the real src/ tree is clean.
"""
import pathlib
import subprocess
import sys

from repro.analysis.lint import RULES, lint_paths, lint_source

REPO = pathlib.Path(__file__).resolve().parents[1]


def rules_of(violations):
    return {v.rule for v in violations}


# -- stripe-access ----------------------------------------------------------

def test_stripe_access_seeded():
    code = (
        "class GlobalTier:\n"
        "    def bad(self, key):\n"
        "        s = self._stripe(key)\n"
        "        return s.store[key]\n"
    )
    vs = lint_source(code, "state/kv.py")
    assert rules_of(vs) == {"stripe-access"}
    assert vs[0].line == 4


def test_stripe_access_clean_under_lock():
    code = (
        "class GlobalTier:\n"
        "    def good(self, key):\n"
        "        s = self._stripe(key)\n"
        "        with s.lock:\n"
        "            return s.store[key]\n"
    )
    assert lint_source(code, "state/kv.py") == []


def test_stripe_access_iteration_and_holds_stripe():
    code = (
        "from repro.analysis import holds_stripe\n"
        "class GlobalTier:\n"
        "    def bad(self):\n"
        "        for s in self._stripes:\n"
        "            s.copied = 0\n"
        "class _Stripe:\n"
        "    @holds_stripe\n"
        "    def bump(self, key):\n"
        "        self.vc += 1\n"
    )
    vs = lint_source(code, "state/kv.py")
    # the un-locked iteration is caught; the @holds_stripe helper is exempt
    assert rules_of(vs) == {"stripe-access"}
    assert [v.line for v in vs] == [5]


# -- lock-blocking ----------------------------------------------------------

def test_lock_blocking_under_stripe_lock_seeded():
    code = (
        "class GlobalTier:\n"
        "    def bad(self, key, frame):\n"
        "        s = self._stripe(key)\n"
        "        with s.lock:\n"
        "            return frame.decode()\n"
    )
    assert rules_of(lint_source(code, "state/kv.py")) == {"lock-blocking"}


def test_lock_blocking_under_key_lock_seeded():
    code = (
        "def bad(gt, tier, key):\n"
        "    lock = gt.lock(key)\n"
        "    lock.acquire_write()\n"
        "    try:\n"
        "        tier.pull(key)\n"
        "    finally:\n"
        "        lock.release_write()\n"
    )
    assert rules_of(lint_source(code, "state/local.py")) == {"lock-blocking"}


def test_lock_blocking_spares_str_encode_and_outside_lock():
    code = (
        "import json\n"
        "def good(api, gt, key, frame, d):\n"
        "    api.lock_state_global_write(key)\n"
        "    try:\n"
        "        gt.set(key, json.dumps(d).encode())\n"
        "    finally:\n"
        "        api.unlock_state_global_write(key)\n"
        "    return frame.decode()\n"
    )
    assert lint_source(code, "state/ddo.py") == []


def test_lock_blocking_codec_encode_under_key_lock():
    code = (
        "def bad(gt, codec, key, eff, base):\n"
        "    lock = gt.lock(key)\n"
        "    lock.acquire_write()\n"
        "    try:\n"
        "        return codec.encode(eff, base)\n"
        "    finally:\n"
        "        lock.release_write()\n"
    )
    assert rules_of(lint_source(code, "state/local.py")) == {"lock-blocking"}


# -- wire-construct ---------------------------------------------------------

def test_wire_construct_seeded_and_home_exempt():
    code = (
        "from repro.state.wire import WireFrame\n"
        "def f():\n"
        "    return WireFrame(wire='exact', numel=0, payload=None)\n"
    )
    assert rules_of(lint_source(code, "state/kv.py")) == {"wire-construct"}
    assert lint_source(code, "repro/state/wire.py") == []


# -- tier-copy --------------------------------------------------------------

def test_tier_copy_seeded():
    code = (
        "def bad(r):\n"
        "    return r.buf.copy()\n"
    )
    assert rules_of(lint_source(code, "state/local.py")) == {"tier-copy"}


def test_tier_copy_accounted_exempt():
    code = (
        "def good(self, s, v, host):\n"
        "    val = v.buf.tobytes()\n"
        "    s.copied += v.length\n"
        "    return val\n"
        "def good2(self, replica):\n"
        "    self.faaslet.usage.charge_net(n_in=replica.buf.size)\n"
        "    return replica.buf.copy()\n"
    )
    assert lint_source(code, "state/kv.py") == []


def test_tier_copy_out_of_scope_file():
    code = "def f(a):\n    return a.copy()\n"
    assert lint_source(code, "core/scheduler.py") == []


# -- fault-point ------------------------------------------------------------

def test_fault_point_internal_import_seeded():
    code = (
        "from repro.faults import _PLAN\n"
        "def bad():\n"
        "    return _PLAN is not None\n"
    )
    assert rules_of(lint_source(code, "state/local.py")) == {"fault-point"}


def test_fault_point_attribute_reach_seeded():
    code = (
        "from repro import faults\n"
        "def bad(key):\n"
        "    if faults._PLAN is not None:\n"
        "        faults._PLAN._fire('wire-frame-drop', None, key, None)\n"
    )
    vs = lint_source(code, "state/local.py")
    assert rules_of(vs) == {"fault-point"}
    assert [v.line for v in vs] == [3, 4]


def test_fault_point_clean_idiom_and_home_exempt():
    clean = (
        "from repro import faults\n"
        "def site(key, host):\n"
        "    if faults.point('wire-frame-drop', key=key, host=host):\n"
        "        return\n"
        "def harness(plan):\n"
        "    with faults.armed(plan):\n"
        "        pass\n"
        "    faults.arm(plan); faults.disarm()\n"
        "    return faults.active(), faults.FAULT_POINTS\n"
    )
    assert lint_source(clean, "state/local.py") == []
    # the faults module itself is allowed its own internals
    internal = "def arm(plan):\n    global _PLAN\n    _PLAN = plan\n"
    assert lint_source(internal, "repro/faults.py") == []


# -- metric-naming ----------------------------------------------------------

def test_metric_naming_perf_counter_in_data_plane_seeded():
    code = "import time\ndef f():\n    return time.perf_counter()\n"
    vs = lint_source(code, "state/kv.py")
    assert rules_of(vs) == {"metric-naming"}
    assert vs[0].line == 3
    # perf_counter_ns too
    code_ns = "import time\nt = time.perf_counter_ns()\n"
    assert rules_of(lint_source(code_ns, "core/runtime.py")) == \
        {"metric-naming"}


def test_metric_naming_perf_counter_out_of_scope_and_clock_home():
    code = "import time\nt = time.perf_counter()\n"
    assert lint_source(code, "analysis/bench.py") == []      # not data-plane
    assert lint_source(code, "telemetry/clock.py") == []     # the one owner


def test_metric_naming_bad_registry_name_seeded():
    code = "def f(reg):\n    reg.counter('request_count')\n"
    vs = lint_source(code, "m.py")
    assert rules_of(vs) == {"metric-naming"}
    bad_unit = "def f(reg):\n    reg.histogram('faasm_serve_latency')\n"
    assert rules_of(lint_source(bad_unit, "m.py")) == {"metric-naming"}


def test_metric_naming_clean_idiom():
    code = (
        "def f(reg, rt):\n"
        "    reg.counter('faasm_test_events_total').inc()\n"
        "    rt.metrics.histogram('faasm_serve_request_ms').observe(1.0)\n"
        "    reg.gauge('faasm_tier_net_bytes').set(0)\n"
    )
    assert lint_source(code, "m.py") == []
    # non-registry receivers named 'counter' are not metric registrations
    other = "def f(db):\n    db.counter('rows')\n"
    assert lint_source(other, "m.py") == []


def test_metric_naming_suppressable():
    code = ("import time\n"
            "def f():\n"
            "    return time.perf_counter()"
            "  # faasmlint: disable=metric-naming -- wall-clock for a log\n")
    assert lint_source(code, "state/kv.py") == []


# -- bounded-queue ----------------------------------------------------------

def test_bounded_queue_seeded():
    code = (
        "import queue\n"
        "class Host:\n"
        "    def __init__(self):\n"
        "        self.inbox = queue.Queue()\n"
    )
    vs = lint_source(code, "core/runtime.py")
    assert rules_of(vs) == {"bounded-queue"}
    assert vs[0].line == 4
    # bare-name constructions and the other stdlib queue flavours too
    bare = ("from queue import SimpleQueue, LifoQueue\n"
            "a = SimpleQueue()\n"
            "b = LifoQueue()\n")
    vs = lint_source(bare, "state/kv.py")
    assert rules_of(vs) == {"bounded-queue"}
    assert [v.line for v in vs] == [2, 3]


def test_bounded_queue_clean_idiom_and_out_of_scope():
    # the sanctioned constructors don't trip the rule
    clean = (
        "from repro.overload import bounded_queue, CoalescingQueue\n"
        "q = bounded_queue(64)\n"
        "c = CoalescingQueue(depth=8)\n"
    )
    assert lint_source(clean, "core/runtime.py") == []
    # raw queues outside the data plane (bench, launch, overload's own
    # implementation) are out of scope
    raw = "import queue\nq = queue.Queue()\n"
    assert lint_source(raw, "overload.py") == []
    assert lint_source(raw, "launch/serve.py") == []


def test_bounded_queue_suppressable():
    code = ("import queue\n"
            "q = queue.Queue()"
            "  # faasmlint: disable=bounded-queue -- drained synchronously\n")
    assert lint_source(code, "core/runtime.py") == []


# -- suppressions -----------------------------------------------------------

def test_suppression_without_justification_is_a_violation():
    # an unjustified disable is itself flagged AND does not silence the rule
    code = "def f(r):\n    return r.buf.copy()  # faasmlint: disable=tier-copy\n"
    assert rules_of(lint_source(code, "state/local.py")) == \
        {"suppress-justify", "tier-copy"}


def test_suppression_with_justification_silences_trailing():
    code = ("def f(r):\n"
            "    return r.buf.copy()"
            "  # faasmlint: disable=tier-copy -- test fixture copy\n")
    assert lint_source(code, "state/local.py") == []


def test_suppression_standalone_comment_covers_next_code_line():
    code = ("def f(r):\n"
            "    # faasmlint: disable=tier-copy -- base snapshot, not traffic\n"
            "    return r.buf.copy()\n")
    assert lint_source(code, "state/local.py") == []


def test_suppression_unknown_rule_is_a_violation():
    code = "x = 1  # faasmlint: disable=no-such-rule -- because\n"
    assert rules_of(lint_source(code, "m.py")) == {"suppress-justify"}


def test_suppression_only_silences_named_rule():
    code = ("def f(r, frame, gt, key):\n"
            "    # faasmlint: disable=lock-blocking -- wrong rule named\n"
            "    return r.buf.copy()\n")
    assert rules_of(lint_source(code, "state/local.py")) == {"tier-copy"}


# -- the gate ---------------------------------------------------------------

def test_src_tree_is_clean():
    assert lint_paths([REPO / "src"]) == []


def test_cli_exits_zero_on_src():
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "faasmlint.py")],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_every_rule_is_documented():
    assert set(RULES) == {"stripe-access", "lock-blocking", "wire-construct",
                          "tier-copy", "fault-point", "metric-naming",
                          "bounded-queue", "suppress-justify"}
    assert all(RULES.values())
