"""Shared test fixtures.

NOTE: no XLA_FLAGS device-count forcing here — smoke tests and benchmarks
must see the real single CPU device.  The multi-device mini dry-run test runs
in a subprocess with its own XLA_FLAGS (see test_dryrun_mini.py).

Sanitizer integration: ``FAASM_SANITIZE=1`` runs the whole suite with the
``repro.analysis.sanitizer`` runtime checks enabled; tests marked
``@pytest.mark.sanitize`` get them regardless.  The autouse fixture resets
the sanitizer per test and fails the test on any report it didn't consume
(seeded-violation tests drain theirs with ``take_reports()``).
"""
import os

import numpy as np
import pytest

_SANITIZE_ENV = os.environ.get("FAASM_SANITIZE") == "1"


def pytest_collection_modifyitems(config, items):
    """Mark the interpret-mode kernel matrix (and the hypothesis kernel
    sweeps) ``slow`` so scripts/tier1.sh can keep the default gate fast;
    plain ``pytest`` still runs everything."""
    for item in items:
        if "pallas_interpret" in item.nodeid or \
                "test_kernels_property" in item.nodeid:
            item.add_marker(pytest.mark.slow)


@pytest.fixture(autouse=True)
def _faults_disarmed():
    """Safety net: no fault plan leaks from one test into the next (a
    leaked plan would make unrelated tests fail nondeterministically)."""
    from repro import faults
    yield
    faults.disarm()


@pytest.fixture(autouse=True)
def _telemetry_disarmed():
    """Safety net: tracing armed by one test never leaks into the next
    (a leaked tracer keeps every hook site writing span rings)."""
    from repro import telemetry
    yield
    telemetry.disable()


@pytest.fixture(autouse=True)
def _cost_model_disarmed():
    """Safety net: a WireCostModel armed by one test never leaks into the
    next (a leaked model flips every WirePolicy into cost mode)."""
    from repro.state import wire
    yield
    wire.disable_cost_model()


@pytest.fixture(autouse=True)
def _faasm_sanitize(request):
    """Per-test sanitizer lifecycle (see module docstring)."""
    marked = request.node.get_closest_marker("sanitize") is not None
    if not (_SANITIZE_ENV or marked):
        yield
        return
    from repro.analysis import sanitizer
    sanitizer.enable()
    sanitizer.reset()
    try:
        yield
        leftovers = sanitizer.take_reports()
    finally:
        if not _SANITIZE_ENV:
            sanitizer.disable()      # marker-only: don't leak into raw tests
    if leftovers:
        pytest.fail("sanitizer reports:\n\n"
                    + "\n\n".join(str(r) for r in leftovers), pytrace=False)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def jax_():
    import jax
    return jax
