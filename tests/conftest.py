"""Shared test fixtures.

NOTE: no XLA_FLAGS device-count forcing here — smoke tests and benchmarks
must see the real single CPU device.  The multi-device mini dry-run test runs
in a subprocess with its own XLA_FLAGS (see test_dryrun_mini.py).
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def jax_():
    import jax
    return jax
