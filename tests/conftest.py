"""Shared test fixtures.

NOTE: no XLA_FLAGS device-count forcing here — smoke tests and benchmarks
must see the real single CPU device.  The multi-device mini dry-run test runs
in a subprocess with its own XLA_FLAGS (see test_dryrun_mini.py).
"""
import numpy as np
import pytest


def pytest_collection_modifyitems(config, items):
    """Mark the interpret-mode kernel matrix (and the hypothesis kernel
    sweeps) ``slow`` so scripts/tier1.sh can keep the default gate fast;
    plain ``pytest`` still runs everything."""
    for item in items:
        if "pallas_interpret" in item.nodeid or \
                "test_kernels_property" in item.nodeid:
            item.add_marker(pytest.mark.slow)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def jax_():
    import jax
    return jax
