"""Mini dry-run: lower+compile on an 8-placeholder-device mesh in a subprocess
(the main test process must keep seeing 1 CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.configs import smoke_config, ShapeConfig
    from repro.models import build_model, ExecConfig
    from repro.distributed.sharding import ShardingRules
    from repro.distributed.hlo_analysis import analyze
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import make_step_for_shape, dummy_args
    from repro.optim import SGD

    arch, kind = sys.argv[1], sys.argv[2]
    multi = sys.argv[3] == "multi"
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model")) if multi \\
        else make_mesh((2, 4), ("data", "model"))
    cfg = smoke_config(arch)
    model = build_model(cfg, ExecConfig(backend="xla", loss_chunk=16))
    rules = ShardingRules(mesh, cfg)
    shape = ShapeConfig("mini_" + kind, kind, 32, 4)
    opt = SGD(lr=0.1)
    with mesh:
        jitted, args = make_step_for_shape(model, rules, shape, optimizer=opt)
        lowered = jitted.lower(*dummy_args(model, shape, args, opt))
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        costs = analyze(compiled.as_text())
    print(json.dumps({
        "ok": True, "temp_bytes": mem.temp_size_in_bytes,
        "flops": costs.flops, "collective_bytes": costs.collective_bytes,
    }))
""")


def _run(arch, kind, mesh="single"):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT, arch, kind, mesh],
                         capture_output=True, text=True, timeout=540, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch,kind", [
    ("qwen1.5-0.5b", "train"),
    ("deepseek-moe-16b", "train"),
    ("mamba2-130m", "decode"),
    ("zamba2-1.2b", "prefill"),
])
def test_mini_dryrun_single_mesh(arch, kind):
    rec = _run(arch, kind, "single")
    assert rec["ok"] and rec["flops"] > 0


def test_mini_dryrun_multi_pod():
    rec = _run("qwen1.5-0.5b", "train", "multi")
    assert rec["ok"]
    assert rec["collective_bytes"] > 0        # pod-axis gradient reduction
