"""Per-architecture smoke tests: reduced same-family configs, one train step
on CPU asserting output shapes + no NaNs, plus prefill/decode consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import arch_ids, smoke_config
from repro.models import build_model, ExecConfig

EC = ExecConfig(backend="xla", loss_chunk=16)
RNG = np.random.default_rng(7)


def _batch(cfg, B=2, S=32):
    St = S - cfg.n_image_tokens if cfg.family == "vlm" else S
    b = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, St)), jnp.int32),
         "targets": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, St)), jnp.int32),
         "mask": jnp.ones((B, St), jnp.float32)}
    if cfg.family == "vlm":
        b["image_embeds"] = jnp.asarray(
            RNG.normal(size=(B, cfg.n_image_tokens, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            RNG.normal(size=(B, cfg.n_frames, cfg.d_model)), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", arch_ids())
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg, EC)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), arch
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.isfinite(g).all()), (arch, jax.tree_util.keystr(path))
    # one SGD step reduces nothing catastrophic: shapes preserved
    new = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new)):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", arch_ids())
def test_forward_logits_shape(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg, EC)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg)
    extra = batch.get("frames") if cfg.family == "encdec" else \
        batch.get("image_embeds")
    logits = model.logits(params, batch["tokens"], extra)
    B, St = batch["tokens"].shape
    S_total = St + (cfg.n_image_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", arch_ids())
def test_prefill_decode_consistency(arch):
    """Prefill last-token logits == full-forward; one decode step matches an
    extended full forward (the serving path is numerically the same model)."""
    cfg = smoke_config(arch)
    model = build_model(cfg, EC)
    params = model.init(jax.random.PRNGKey(2))
    B, S = 2, 16
    St = S - cfg.n_image_tokens if cfg.family == "vlm" else S
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, St)), jnp.int32)
    extra = None
    if cfg.family == "vlm":
        extra = jnp.asarray(RNG.normal(size=(B, cfg.n_image_tokens, cfg.d_model)),
                            jnp.bfloat16)
    if cfg.family == "encdec":
        extra = jnp.asarray(RNG.normal(size=(B, cfg.n_frames, cfg.d_model)),
                            jnp.bfloat16)

    cache = model.init_cache(B, S + 4)
    logits, cache, n = model.prefill(params, tokens, cache, extra)
    full = model.logits(params, tokens, extra)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               atol=3e-2, rtol=3e-2)

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    S_total = tokens.shape[1] + (cfg.n_image_tokens if cfg.family == "vlm" else 0)
    idx = jnp.full((B,), S_total, jnp.int32)
    logits2, _ = model.decode_step(params, tok, cache, idx)
    ext = jnp.concatenate([tokens, tok[:, None]], axis=1)
    full2 = model.logits(params, ext, extra)
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(full2[:, -1]),
                               atol=5e-2, rtol=5e-2)


def test_param_counts_match_published():
    """Full configs hit the published parameter counts (±3%)."""
    from repro.configs import get_config
    expected = {
        "qwen1.5-0.5b": 0.464e9, "starcoder2-7b": 7.4e9,
        "granite-3-8b": 8.2e9, "qwen3-4b": 4.0e9,
        "deepseek-moe-16b": 16.4e9, "kimi-k2-1t-a32b": 1.03e12,
        "mamba2-130m": 0.13e9, "internvl2-2b": 1.89e9,
    }
    for arch, n in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.03, (arch, got, n)


def test_moe_active_params():
    from repro.configs import get_config
    k = get_config("kimi-k2-1t-a32b")
    assert 30e9 < k.active_param_count() < 40e9
    d = get_config("deepseek-moe-16b")
    assert 2.0e9 < d.active_param_count() < 3.5e9
