"""CoW Proto-Faaslet restore + zero-copy state data plane.

Covers the §5.2 O(dirty) reset (dirty-page tracking, byte-identity with the
full-copy baseline, cross-call isolation) and the GlobalTier zero-copy
primitives (readinto/write_from/add_inplace, copy accounting, atomic
rewrite) plus the delta-record warm set."""
import numpy as np
import pytest

from repro.core import FaasmRuntime, FunctionDef, ProtoFaaslet
from repro.core.faaslet import (EAGER_COPY_MAX_BYTES, ArenaBase, Faaslet,
                                WASM_PAGE)
from repro.core.scheduler import WARM_PREFIX
from repro.state.kv import GlobalTier
from repro.state.local import LocalTier


# -- dirty-page tracking ------------------------------------------------------


def test_write_and_brk_mark_dirty_pages():
    f = Faaslet("fn", "h0", memory_limit=8 * WASM_PAGE)
    f.brk(2 * WASM_PAGE)                      # exposes pages 0-1
    assert f.dirty_pages == {0, 1}
    f.clear_dirty()
    f.write(WASM_PAGE + 10, b"abc")           # page 1 only
    assert f.dirty_pages == {1}
    f.write(WASM_PAGE - 1, b"xy")             # straddles pages 0/1
    assert f.dirty_pages == {0, 1}


def test_shared_region_writes_do_not_dirty_arena():
    f = Faaslet("fn", "h0")
    backing = np.zeros(256, np.uint8)
    r = f.map_shared_region("k", backing)
    f.write(r.base + 3, b"zz")
    assert f.dirty_pages == set()


# -- CoW restore / reset ------------------------------------------------------


def _make_proto(arena_bytes: int, fill: bytes = b"\xab") -> ProtoFaaslet:
    limit = max(arena_bytes, WASM_PAGE)
    f = Faaslet("fn", "h0", memory_limit=2 * limit)
    f.brk(arena_bytes)
    f.write(0, fill * (arena_bytes // len(fill)))
    return ProtoFaaslet.capture(f, {"model": [1, 2, 3]})


def test_cow_restore_small_uses_eager_copy():
    proto = _make_proto(2 * WASM_PAGE)
    assert len(proto.arena) <= EAGER_COPY_MAX_BYTES
    assert proto.arena_base()._fd < 0         # no memfd for tiny snapshots
    f, state = proto.restore("h1")
    assert state == {"model": [1, 2, 3]}
    assert bytes(f.read(0, 4)) == b"\xab" * 4
    assert f.restored_from_proto


def test_cow_restore_large_shares_base_no_leak():
    pages = EAGER_COPY_MAX_BYTES // WASM_PAGE + 4      # force the mmap path
    proto = _make_proto(pages * WASM_PAGE)
    a, _ = proto.restore("h0")
    b, _ = proto.restore("h0")
    a.write(7 * WASM_PAGE, b"private!")
    # b maps the same base but must not see a's private write
    assert bytes(b.read(7 * WASM_PAGE, 8)) == b"\xab" * 8
    # and the base itself is untouched
    assert proto.arena[7 * WASM_PAGE:7 * WASM_PAGE + 8] == b"\xab" * 8


@pytest.mark.parametrize("arena_pages", [2, EAGER_COPY_MAX_BYTES // WASM_PAGE + 4])
def test_dirty_reset_byte_identical_to_full_restore(arena_pages):
    """Same writes, one faaslet reset via dirty pages, one restored full-copy:
    the arenas must match byte for byte (the §5.2 isolation guarantee)."""
    proto = _make_proto(arena_pages * WASM_PAGE)
    f, _ = proto.restore("h0")
    limit = f.memory_limit
    f.brk(limit)                              # grow past the snapshot
    f.write(0, b"A" * (WASM_PAGE + 123))      # dirty low pages
    f.write(limit - 3000, b"B" * 2999)        # dirty pages beyond the snapshot
    stamped = f.reset_from_base()
    assert stamped >= 2                       # low pages + tail pages
    ref, _ = proto.restore_copy("h0")         # the old full-copy baseline
    span = min(f._arena.size, max(ref._arena.size, len(proto.arena)))
    got = np.asarray(f._arena[:span])
    want = np.zeros(span, np.uint8)
    want[:len(proto.arena)] = np.frombuffer(proto.arena, np.uint8)
    assert np.array_equal(got, want)
    assert f.brk_value == proto.brk == ref.brk_value


def test_reset_clears_dirty_and_is_idempotent():
    proto = _make_proto(2 * WASM_PAGE)
    f, _ = proto.restore("h0")
    f.write(0, b"junk")
    assert f.reset_from_base() >= 1
    assert f.dirty_pages == set()
    assert f.reset_from_base() == 0           # nothing dirty: O(0)


def test_user_state_template_cached_once():
    proto = _make_proto(WASM_PAGE)
    _, s1 = proto.restore("h0")
    _, s2 = proto.restore("h1")
    assert s1 is s2                           # decoded once, shared read-only


def test_proto_pickle_roundtrip_drops_caches():
    proto = _make_proto(WASM_PAGE)
    proto.arena_base()                        # populate caches
    proto.user_state_template()
    clone = ProtoFaaslet.deserialize(proto.serialize())
    assert clone.arena == proto.arena and clone.brk == proto.brk
    f, state = clone.restore("hX")
    assert state == {"model": [1, 2, 3]}
    assert bytes(f.read(0, 2)) == b"\xab\xab"


def test_arena_read_views_are_readonly():
    """Writes must go through write() so dirty tracking (and thus the §5.2
    reset) sees them — a read() view of the arena cannot be a side door."""
    proto = _make_proto(2 * WASM_PAGE)
    f, _ = proto.restore("h0")
    view = f.read(0, 4)
    with pytest.raises((ValueError, RuntimeError)):
        view[:] = 0x45
    # shared regions keep the zero-copy write path (unless mapped read-only)
    backing = np.zeros(128, np.uint8)
    region = f.map_shared_region("k", backing)
    f.read(region.base, 4)[:] = 7             # allowed: writable region
    assert backing[0] == 7
    ro = f.map_shared_region("k2", np.zeros(64, np.uint8), writable=False)
    with pytest.raises((ValueError, RuntimeError)):
        f.read(ro.base, 4)[:] = 1


def test_cow_faaslet_memory_charged_once_per_base():
    """Clean mmap-CoW pages belong to the shared base: N warm Faaslets from
    one snapshot must not be billed N full arenas.  Eager-copied arenas are
    fully private and stay charged in full."""
    from repro.core.faaslet import FAASLET_OVERHEAD_BYTES
    pages = EAGER_COPY_MAX_BYTES // WASM_PAGE + 4      # force the mmap path
    proto = _make_proto(pages * WASM_PAGE)
    faaslets = [proto.restore("h0")[0] for _ in range(4)]
    if faaslets[0]._mm is None:
        pytest.skip("mmap/memfd unavailable: eager fallback in use")
    fps = {f.base_footprint() for f in faaslets}
    assert len(fps) == 1                      # one shared base
    _, base_bytes = next(iter(fps))
    assert base_bytes == pages * WASM_PAGE
    for f in faaslets:
        assert f.memory_bytes() == FAASLET_OVERHEAD_BYTES   # no dirty pages
    faaslets[0].write(0, b"x")
    assert faaslets[0].memory_bytes() == WASM_PAGE + FAASLET_OVERHEAD_BYTES
    # eager path: the arena is a private copy, charged in full
    small = _make_proto(2 * WASM_PAGE)
    g, _ = small.restore("h0")
    assert g.base_footprint() is None
    assert g.memory_bytes() == g._arena.size + FAASLET_OVERHEAD_BYTES


# -- zero-copy global-tier primitives ----------------------------------------


def test_readinto_write_from_roundtrip_and_bounds():
    gt = GlobalTier()
    gt.set("k", bytes(range(64)), host="up")
    dest = np.zeros(16, np.uint8)
    assert gt.readinto("k", 8, dest, host="h") == 16
    assert bytes(dest) == bytes(range(8, 24))
    with pytest.raises(IndexError):
        gt.readinto("k", 60, dest, host="h")
    src = np.full(8, 0xEE, np.uint8)
    gt.write_from("k", 4, src, host="h")
    assert gt.get_range("k", 4, 8, host="h") == b"\xee" * 8
    # extension + gap zero-fill
    gt.set("short", b"ab", host="up")
    gt.write_from("short", 6, src, host="h")
    assert gt.get("short", host="h") == b"ab\x00\x00\x00\x00" + b"\xee" * 8


def test_readinto_clamps_after_concurrent_truncation():
    """A pull sized before a truncating push must copy what exists, not
    fail — the race the bytes-typed get() path tolerated."""
    gt = GlobalTier()
    gt.set("k", bytes(range(64)), host="up")
    dest = np.zeros(64, np.uint8)
    gt.write_from("k", 0, np.ones(16, np.uint8), host="h", truncate=True)
    moved = gt.readinto("k", 0, dest, host="h", clamp=True)
    assert moved == 16
    assert bytes(dest[:16]) == b"\x01" * 16
    with pytest.raises(IndexError):           # strict mode still traps
        gt.readinto("k", 0, dest, host="h")


def test_write_from_truncate_semantics():
    gt = GlobalTier()
    gt.set("k", bytes(32), host="up")
    gt.write_from("k", 0, np.ones(8, np.uint8), host="h", truncate=True)
    assert gt.size("k") == 8                  # full-value push replaced it


def test_pull_push_delta_single_copy_accounting():
    size = 256 * 1024
    gt = GlobalTier()
    gt.set("w", np.zeros(size // 4, np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    gt.reset_metrics()
    lt.pull("w")
    lt.snapshot_base("w")
    lt.replica("w").buf.view(np.float32)[5] += 2.5
    lt.push_delta("w")
    # one full-value memcpy for the pull, zero for the in-place delta push
    assert gt.total_copied() == size
    assert np.frombuffer(gt.get("w", host="x"), np.float32)[5] == 2.5


def test_add_inplace_accumulates_and_clips():
    gt = GlobalTier()
    gt.set("w", np.zeros(4, np.float32).tobytes(), host="up")
    local = np.array([1, 2, 3, 4, 99], np.float32)     # longer than global
    base = np.array([0, 1, 0, 1, 0], np.float32)
    moved = gt.add_inplace("w", local, base, host="h")
    assert moved == 16                        # clipped to the stored value
    np.testing.assert_allclose(
        np.frombuffer(gt.get("w", host="x"), np.float32), [1, 1, 3, 3])


def test_append_amortised_and_rewrite_atomic():
    gt = GlobalTier()
    for i in range(100):
        gt.append("log", f"+h{i}\n".encode(), host="h")
    assert gt.get("log", host="h").count(b"\n") == 100
    new, ver = gt.rewrite("log", lambda cur: b"+h99\n", host="h")
    assert new == b"+h99\n" and gt.get("log", host="h") == b"+h99\n"
    assert ver == gt.version("log")           # version captured atomically


# -- delta-record warm set ----------------------------------------------------


def test_warm_set_delta_records_and_compaction():
    rt = FaasmRuntime(n_hosts=2)
    try:
        s0 = rt.schedulers["host0"]
        s1 = rt.schedulers["host1"]
        key = WARM_PREFIX + "f"
        s0.register_warm("f")
        assert rt.global_tier.get(key, host="t") == b"+host0\n"
        s0.register_warm("f")                  # member already: no new record
        assert rt.global_tier.get(key, host="t") == b"+host0\n"
        s1.register_warm("f")
        assert s0.warm_hosts("f") == ["host0", "host1"]
        s1.deregister_warm("host1", "f")
        assert s0.warm_hosts("f") == ["host0"]
        # churn: the log compacts instead of growing without bound
        for _ in range(30):
            s1.register_warm("f")
            s1._warm_cache.clear()
            s1.deregister_warm("host1", "f")
        assert s0.warm_hosts("f") == ["host0"]
        assert rt.global_tier.get(key, host="t").count(b"\n") <= \
            2 + 8 + 1                          # membership + slack + in-flight
        # a registration appends one small record, not the whole list
        rt.global_tier.reset_metrics()
        s1.register_warm("f")
        assert rt.global_tier.bytes_pushed["host1"] == len(b"+host1\n")
    finally:
        rt.shutdown()


def test_warm_set_survives_runtime_paths():
    """End-to-end: placement still prefers warm hosts with the delta log."""
    rt = FaasmRuntime(n_hosts=3)
    try:
        def echo(api):
            api.write_call_output(api.read_call_input())
            return 0

        rt.upload(FunctionDef("e", echo))
        first = rt.invoke("e", b"x")
        rt.wait(first, timeout=10)
        for _ in range(5):
            cid = rt.invoke("e", b"y")
            assert rt.wait(cid, timeout=10) == 0
        assert rt.cold_start_stats()["warm_hits"] >= 4
    finally:
        rt.shutdown()


# -- CoW page reclaim (madvise) ----------------------------------------------


def test_reset_reclaims_dirty_pages_via_madvise():
    """On the mmap path the post-call reset hands dirty pages back with
    madvise(MADV_DONTNEED): content refaults to the shared base (byte-
    identical to re-stamping) and ``reclaimed_pages`` counts them."""
    import mmap as _mmap
    pages = EAGER_COPY_MAX_BYTES // WASM_PAGE + 4      # force the mmap path
    proto = _make_proto(pages * WASM_PAGE)
    f, _ = proto.restore("h0")
    if f._mm is None or not hasattr(_mmap, "MADV_DONTNEED"):
        pytest.skip("mmap/madvise unavailable: memcpy fallback in use")
    f.write(0, b"junk" * 64)
    f.write(5 * WASM_PAGE + 3, b"zz")
    f.write(6 * WASM_PAGE, b"ww")                      # contiguous run with 5
    n = f.reset_from_base()
    assert n >= 3
    assert f.reclaimed_pages >= 3                      # reclaimed, not copied
    assert f.dirty_pages == set()
    # refault reads the shared base content back
    assert bytes(f.read(0, 8)) == b"\xab" * 8
    assert bytes(f.read(5 * WASM_PAGE, 8)) == b"\xab" * 8
    assert bytes(f.read(6 * WASM_PAGE, 8)) == b"\xab" * 8
    # beyond-snapshot pages refault as zeros (the memfd hole)
    f.brk(f.memory_limit)
    f.write(f.memory_limit - WASM_PAGE + 7, b"tail")
    f.reset_from_base()
    f.brk(f.memory_limit)
    assert bytes(f.read(f.memory_limit - WASM_PAGE, 16)) == bytes(16)


def test_reset_reclaim_never_retains_pages():
    """``reclaim="never"`` re-stamps dirty pages in place: content is
    restored, nothing is madvise'd back, ``retained_pages`` counts them."""
    pages = EAGER_COPY_MAX_BYTES // WASM_PAGE + 4
    proto = _make_proto(pages * WASM_PAGE)
    f, _ = proto.restore("h0")
    f.write(2 * WASM_PAGE + 5, b"scratch")
    n = f.reset_from_base(reclaim="never")
    assert n >= 1
    assert f.reclaimed_pages == 0
    assert f.retained_pages >= 1
    assert bytes(f.read(2 * WASM_PAGE, 8)) == b"\xab" * 8


def test_reset_reclaim_auto_follows_pressure():
    """``reclaim="auto"`` retains without pressure (hot Faaslet stays
    refault-free) and reclaims under pressure (mmap path)."""
    pages = EAGER_COPY_MAX_BYTES // WASM_PAGE + 4
    proto = _make_proto(pages * WASM_PAGE)
    f, _ = proto.restore("h0")
    f.write(0, b"hot")
    f.reset_from_base(reclaim="auto", pressure=False)
    assert f.reclaimed_pages == 0 and f.retained_pages >= 1
    retained0 = f.retained_pages
    f.write(0, b"cold")
    f.reset_from_base(reclaim="auto", pressure=True)
    if f._mm is not None and hasattr(__import__("mmap"), "MADV_DONTNEED"):
        assert f.reclaimed_pages >= 1
        assert f.retained_pages == retained0
    assert bytes(f.read(0, 4)) == b"\xab" * 4
    with pytest.raises(ValueError):
        f.reset_from_base(reclaim="bogus")


def test_proc_rss_bytes_reads_real_rss():
    """/proc/self/statm field 2 × page size — positive and at least as big
    as the interpreter's floor on any linux box."""
    from repro.core import runtime as rtmod
    rss = rtmod._proc_rss_bytes()
    if rss is None:
        pytest.skip("procfs unavailable")
    assert rss > 4 << 20                      # a bare CPython is > 4 MB


def test_reclaim_auto_pressure_from_real_rss_with_fallback(monkeypatch):
    """``reclaim="auto"`` reads real RSS growth since host init; a zero
    threshold means every reset sees pressure.  When procfs reads fail the
    bookkeeping estimate takes over — with a huge threshold it reports no
    pressure and the hot Faaslet is retained."""
    import mmap as _mmap
    if not hasattr(_mmap, "MADV_DONTNEED"):
        pytest.skip("madvise unavailable")
    from repro.core import runtime as rtmod

    def run(threshold):
        rt = FaasmRuntime(n_hosts=1, reclaim="auto")
        try:
            rt.hosts["host0"].reclaim_rss_bytes = threshold

            def init(api):
                api.brk(EAGER_COPY_MAX_BYTES + 2 * WASM_PAGE)
                return None

            def touch_mem(api):
                api.sbrk(WASM_PAGE)
                return 0

            rt.upload(FunctionDef("touch_mem", touch_mem, init_fn=init,
                                  memory_limit=4 * EAGER_COPY_MAX_BYTES))
            for _ in range(3):
                assert rt.wait(rt.invoke("touch_mem"), timeout=20) == 0
            warm = rt.hosts["host0"]._warm["touch_mem"]
            mmapped = bool(warm) and warm[0]._mm is not None
            return rt.cold_start_stats(), mmapped
        finally:
            rt.shutdown()

    # real-RSS path, threshold 0: any growth (or none) >= 0 is pressure
    stats, mmapped = run(0)
    if mmapped:
        assert stats["reclaimed_pages"] >= 1
    # procfs gone: the estimate path with the default 256 MB threshold
    # sees no pressure from a few WASM pages — the Faaslet is retained
    monkeypatch.setattr(rtmod, "_proc_rss_bytes", lambda: None)
    stats, _ = run(256 << 20)
    assert stats["reclaimed_pages"] == 0
    assert stats["retained_pages"] >= 1


def test_runtime_reset_splits_reclaimed_and_retained():
    """End-to-end metric split: an "always" runtime reports reclaimed
    pages, a "never" runtime reports the same work as retained."""
    import mmap as _mmap
    if not hasattr(_mmap, "MADV_DONTNEED"):
        pytest.skip("madvise unavailable")

    def run(reclaim):
        rt = FaasmRuntime(n_hosts=1, reclaim=reclaim)
        try:
            def init(api):
                api.brk(EAGER_COPY_MAX_BYTES + 2 * WASM_PAGE)
                return None

            def touch_mem(api):
                api.sbrk(WASM_PAGE)
                return 0

            rt.upload(FunctionDef("touch_mem", touch_mem, init_fn=init,
                                  memory_limit=4 * EAGER_COPY_MAX_BYTES))
            for _ in range(3):
                assert rt.wait(rt.invoke("touch_mem"), timeout=20) == 0
            warm = rt.hosts["host0"]._warm["touch_mem"]
            mmapped = bool(warm) and warm[0]._mm is not None
            return rt.cold_start_stats(), mmapped
        finally:
            rt.shutdown()

    stats, mmapped = run("always")
    if mmapped:
        assert stats["reclaimed_pages"] >= 1
    stats, _ = run("never")
    assert stats["reclaimed_pages"] == 0
    assert stats["retained_pages"] >= 1


def test_runtime_reset_reports_reclaimed_pages():
    """End-to-end: under ``reclaim="always"`` a warm call that dirties
    private memory on an mmap-CoW Faaslet shows up in the host
    reclaimed_pages metric."""
    import mmap as _mmap
    if not hasattr(_mmap, "MADV_DONTNEED"):
        pytest.skip("madvise unavailable")
    rt = FaasmRuntime(n_hosts=1, reclaim="always")
    try:
        def init(api):
            api.brk(EAGER_COPY_MAX_BYTES + 2 * WASM_PAGE)  # big mmap-able arena
            return None

        def touch_mem(api):
            api.sbrk(WASM_PAGE)                        # dirties a private page
            return 0

        rt.upload(FunctionDef("touch_mem", touch_mem, init_fn=init,
                              memory_limit=4 * EAGER_COPY_MAX_BYTES))
        for _ in range(3):
            assert rt.wait(rt.invoke("touch_mem"), timeout=20) == 0
        stats = rt.cold_start_stats()
        assert stats["resets"] >= 3
        if rt.hosts["host0"]._warm["touch_mem"] and \
                rt.hosts["host0"]._warm["touch_mem"][0]._mm is not None:
            assert stats["reclaimed_pages"] >= 1
    finally:
        rt.shutdown()
