"""Two-tier state tests: chunks, pull/push, locks, delta-accumulating push."""
import threading

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.state.kv import GlobalTier, RWLock
from repro.state.local import LocalTier


def test_global_tier_basic():
    gt = GlobalTier(chunk_size=16)
    gt.set("a", b"hello", host="h0")
    assert gt.get("a", host="h1") == b"hello"
    gt.append("a", b" world", host="h0")
    assert gt.get("a", host="h1") == b"hello world"
    assert gt.bytes_pushed["h0"] == len(b"hello") + len(b" world")


def test_global_tier_range_and_chunks():
    gt = GlobalTier(chunk_size=8)
    gt.set("k", bytes(range(32)), host="h")
    assert gt.n_chunks("k") == 4
    assert gt.get_range("k", 8, 8, host="h") == bytes(range(8, 16))
    gt.set_range("k", 30, b"\xff\xff\xff", host="h")   # extends the value
    assert gt.size("k") == 33
    with pytest.raises(IndexError):
        gt.get_range("k", 30, 10)


def test_local_tier_chunked_pull_moves_only_needed_bytes():
    gt = GlobalTier(chunk_size=8)
    gt.set("k", bytes(range(64)), host="up")
    lt = LocalTier("h0", gt)
    gt.reset_metrics()
    lt.pull_range("k", 20, 4)                      # covers chunk 2 only
    assert gt.bytes_pulled["h0"] == 8
    r = lt.replica("k")
    assert bytes(r.buf[20:24]) == bytes(range(20, 24))
    # pulling the same chunk again is free
    lt.pull_range("k", 16, 8)
    assert gt.bytes_pulled["h0"] == 8


def test_local_push_dirty_only():
    gt = GlobalTier(chunk_size=8)
    gt.set("k", bytes(64), host="up")
    lt = LocalTier("h0", gt)
    lt.pull("k")
    gt.reset_metrics()
    r = lt.replica("k")
    r.buf[9] = 42
    lt.mark_dirty("k", 9, 1)
    moved = lt.push_dirty("k")
    assert moved == 8                              # one chunk
    assert gt.get("k", host="x")[9] == 42


def test_push_delta_accumulates_across_hosts():
    """Concurrent delta pushes from different hosts compose (HOGWILD-safe)."""
    gt = GlobalTier()
    base = np.zeros(16, np.float32)
    gt.set("w", base.tobytes(), host="up")
    tiers = [LocalTier(f"h{i}", gt) for i in range(4)]
    for i, lt in enumerate(tiers):
        lt.pull("w")
        lt.snapshot_base("w")
        view = lt.replica("w").buf.view(np.float32)
        view[i] += float(i + 1)
    for lt in tiers:
        lt.push_delta("w")
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    np.testing.assert_allclose(final[:4], [1, 2, 3, 4])
    np.testing.assert_allclose(final[4:], 0)


def test_plain_push_overwrites():
    gt = GlobalTier()
    gt.set("w", np.zeros(4, np.float32).tobytes(), host="up")
    l0, l1 = LocalTier("h0", gt), LocalTier("h1", gt)
    for i, lt in enumerate((l0, l1)):
        lt.pull("w")
        lt.replica("w").buf.view(np.float32)[i] = 7.0
    l0.push("w")
    l1.push("w")                                    # last-writer-wins
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    assert final[1] == 7.0 and final[0] == 0.0      # h0's write lost (expected)


def test_rwlock_mutual_exclusion():
    lock = RWLock()
    counter = {"v": 0}
    errs = []

    def writer():
        for _ in range(200):
            lock.acquire_write()
            try:
                v = counter["v"]
                counter["v"] = v + 1
            finally:
                lock.release_write()

    def reader():
        for _ in range(200):
            lock.acquire_read()
            try:
                _ = counter["v"]
            finally:
                lock.release_read()

    ts = [threading.Thread(target=writer) for _ in range(3)] + \
        [threading.Thread(target=reader) for _ in range(3)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert counter["v"] == 600
    assert not errs


@settings(max_examples=25, deadline=None)
@given(size=st.integers(1, 300), chunk=st.integers(1, 64),
       offset_frac=st.floats(0, 1), length_frac=st.floats(0, 1),
       seed=st.integers(0, 2**16))
def test_property_pull_range_correct(size, chunk, offset_frac, length_frac, seed):
    """Any chunked partial pull reproduces exactly the global bytes."""
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, size, dtype=np.uint8).tobytes()
    gt = GlobalTier(chunk_size=chunk)
    gt.set("k", data, host="up")
    lt = LocalTier("h", gt)
    off = int(offset_frac * (size - 1))
    length = max(1, int(length_frac * (size - off)))
    lt.pull_range("k", off, length)
    r = lt.replica("k")
    assert bytes(r.buf[off:off + length]) == data[off:off + length]


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 64), writes=st.lists(
    st.tuples(st.integers(0, 63), st.floats(-10, 10)), max_size=16),
    seed=st.integers(0, 2**16))
def test_property_delta_push_equals_sum(n, writes, seed):
    """global' == global + Σ per-host deltas regardless of interleaving."""
    gt = GlobalTier()
    init = np.zeros(64, np.float32)
    gt.set("w", init.tobytes(), host="up")
    expected = init.copy()
    lt = LocalTier("h", gt)
    lt.pull("w")
    lt.snapshot_base("w")
    view = lt.replica("w").buf.view(np.float32)
    for idx, val in writes:
        view[idx % 64] += np.float32(val)
        expected[idx % 64] += np.float32(val)
    lt.push_delta("w")
    final = np.frombuffer(gt.get("w", host="x"), np.float32)
    np.testing.assert_allclose(final, expected, atol=1e-5)
