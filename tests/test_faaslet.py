"""Faaslet SFI invariants: bounds checking, shared regions, resource budgets."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.faaslet import (Faaslet, FaasletMemoryFault,
                                ResourceLimitExceeded, WASM_PAGE)


def test_private_memory_bounds():
    f = Faaslet("fn", "h0", memory_limit=4 * WASM_PAGE)
    f.brk(100)
    f.write(0, b"abc")
    assert bytes(f.read(0, 3)) == b"abc"
    with pytest.raises(FaasletMemoryFault):
        f.read(98, 3)                               # crosses brk
    with pytest.raises(FaasletMemoryFault):
        f.read(-1, 1)
    with pytest.raises(FaasletMemoryFault):
        f.write(100, b"x")                          # at brk


def test_brk_respects_memory_limit():
    f = Faaslet("fn", "h0", memory_limit=2 * WASM_PAGE)
    f.brk(2 * WASM_PAGE)
    with pytest.raises(FaasletMemoryFault):
        f.brk(2 * WASM_PAGE + 1)
    old = f.sbrk(0)
    assert old == 2 * WASM_PAGE


def test_shared_region_zero_copy():
    """Two Faaslets mapping the same backing see each other's writes."""
    backing = np.zeros(256, np.uint8)
    a = Faaslet("fa", "h0")
    b = Faaslet("fb", "h0")
    ra = a.map_shared_region("k", backing)
    rb = b.map_shared_region("k", backing)
    a.write(ra.base + 10, b"\x42")
    assert b.read(rb.base + 10, 1)[0] == 0x42       # same memory
    assert backing[10] == 0x42


def test_shared_region_bounds_and_readonly():
    backing = np.zeros(100, np.uint8)
    f = Faaslet("fn", "h0")
    r = f.map_shared_region("k", backing, writable=False)
    with pytest.raises(FaasletMemoryFault):
        f.read(r.base + 98, 4)                      # crosses region end
    with pytest.raises(FaasletMemoryFault):
        f.write(r.base, b"x")                       # read-only region


def test_unmapped_gap_between_regions_traps():
    f = Faaslet("fn", "h0", memory_limit=WASM_PAGE)
    backing = np.zeros(10, np.uint8)
    r = f.map_shared_region("k", backing)
    with pytest.raises(FaasletMemoryFault):
        f.read(r.base - 1, 1)                       # below the region
    with pytest.raises(FaasletMemoryFault):
        f.read(f.brk_value + 1, 1)                  # above brk, below region


def test_resource_budgets():
    f = Faaslet("fn", "h0", net_budget=100)
    f.usage.charge_net(n_out=90)
    with pytest.raises(ResourceLimitExceeded):
        f.usage.charge_net(n_in=20)
    g = Faaslet("fn", "h0", cpu_budget_ns=1000)
    with pytest.raises(ResourceLimitExceeded):
        g.usage.charge_cpu(2000)


def test_snapshot_restore_roundtrip():
    f = Faaslet("fn", "h0")
    f.brk(64)
    f.write(0, b"initialised state!")
    snap = f.snapshot_arena()
    g = Faaslet("fn", "h1")
    g.restore_arena(snap, 64)
    assert bytes(g.read(0, 18)) == b"initialised state!"
    assert g.brk_value == 64


@settings(max_examples=30, deadline=None)
@given(brk=st.integers(0, 2 * WASM_PAGE),
       addr=st.integers(-10, 3 * WASM_PAGE),
       length=st.integers(0, WASM_PAGE))
def test_property_sfi_no_escape(brk, addr, length):
    """Every in-bounds access succeeds; every out-of-bounds access traps."""
    f = Faaslet("fn", "h0", memory_limit=2 * WASM_PAGE)
    f.brk(brk)
    in_bounds = 0 <= addr and addr + length <= brk
    if in_bounds:
        assert len(f.read(addr, length)) == length
    else:
        with pytest.raises(FaasletMemoryFault):
            f.read(addr, length)
