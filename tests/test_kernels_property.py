"""Hypothesis property tests on kernel invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention, attention_ref
from repro.kernels.state_push import apply_delta, quantize_delta
from repro.kernels.moe_gmm import gmm, gmm_ref

SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(
    B=st.integers(1, 2),
    Sq=st.integers(1, 12),
    Sk=st.integers(1, 12),
    G=st.integers(1, 3),
    K=st.integers(1, 2),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_flash_any_shape_matches_ref(B, Sq, Sk, G, K, causal, seed):
    rng = np.random.default_rng(seed)
    D = 8
    H = K * G
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, K, D)), jnp.float32)
    off = max(0, Sk - Sq) if causal else 0
    ref = attention_ref(q, k, v, causal=causal, q_offset=off)
    got = flash_attention(q, k, v, causal=causal, q_offset=off,
                          backend="xla", block_k=4)
    np.testing.assert_allclose(ref, got, atol=3e-5, rtol=3e-5)


@settings(**SETTINGS)
@given(n=st.integers(1, 500), seed=st.integers(0, 2**16),
       scale=st.floats(1e-3, 1e3))
def test_push_delta_bounded_error(n, seed, scale):
    """|dequant(quant(delta)) - delta| <= absmax/127 per 128-lane row."""
    rng = np.random.default_rng(seed)
    local = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    base = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    gv = jnp.zeros((n,), jnp.float32)
    q, s, _ = quantize_delta(local, base, backend="xla")
    got = apply_delta(gv, q, s, backend="xla")
    delta = np.asarray(local - base)
    err = np.abs(np.asarray(got) - delta)
    bound = np.abs(delta).max() / 127.0 * 1.01 + 1e-9
    assert err.max() <= bound


@settings(**SETTINGS)
@given(T=st.integers(1, 40), E=st.integers(1, 6), seed=st.integers(0, 2**16))
def test_gmm_any_grouping(T, E, seed):
    rng = np.random.default_rng(seed)
    d, f = 8, 8
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(E, d, f)), jnp.float32)
    cuts = np.sort(rng.integers(0, T + 1, size=E - 1)) if E > 1 else np.array([], int)
    gs = jnp.asarray(np.diff(np.concatenate([[0], cuts, [T]])), jnp.int32)
    ref = gmm_ref(x, w, gs)
    got = gmm(x, w, gs, backend="xla")
    np.testing.assert_allclose(ref, got, atol=1e-4, rtol=1e-4)
