"""Per-kernel allclose sweeps: pallas-interpret + xla paths vs the ref.py oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention, attention_ref
from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.ssd_scan import ssd, ssd_step, ssd_ref
from repro.kernels.moe_gmm import gmm, gmm_ref
from repro.kernels.state_push import (apply_delta, push, quantize_delta,
                                      quantize_delta_ref)

RNG = np.random.default_rng(42)


def _randn(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Sk, H, K, D, causal, q_offset
    (2, 16, 16, 4, 2, 16, True, 0),
    (1, 8, 24, 4, 4, 8, True, 16),
    (2, 17, 33, 6, 2, 16, False, 0),
    (1, 1, 40, 8, 2, 32, True, 39),
    (2, 16, 16, 4, 1, 16, True, 0),          # MQA
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_flash_attention_matches_ref(case, backend):
    B, Sq, Sk, H, K, D, causal, off = case
    q, k, v = _randn(B, Sq, H, D), _randn(B, Sk, K, D), _randn(B, Sk, K, D)
    ref = attention_ref(q, k, v, causal=causal, q_offset=off)
    got = flash_attention(q, k, v, causal=causal, q_offset=off,
                          backend=backend, block_q=8, block_k=8)
    np.testing.assert_allclose(ref, got, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = _randn(2, 12, 4, 16, dtype=dtype)
    k = _randn(2, 12, 2, 16, dtype=dtype)
    v = _randn(2, 12, 2, 16, dtype=dtype)
    ref = attention_ref(q, k, v)
    got = flash_attention(q, k, v, backend="pallas_interpret", block_q=8,
                          block_k=8)
    assert got.dtype == dtype
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.float32(ref), np.float32(got),
                               atol=tol, rtol=tol)


def test_flash_attention_grads_match_ref_autodiff():
    q, k, v = _randn(2, 16, 4, 16), _randn(2, 16, 2, 16), _randn(2, 16, 2, 16)
    f_ref = lambda q, k, v: (attention_ref(q, k, v) ** 2).sum()
    f_fa = lambda q, k, v: (flash_attention(q, k, v, backend="xla",
                                            block_k=8) ** 2).sum()
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_fa = jax.grad(f_fa, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_fa):
        np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [(2, 64, 8, 2, 16), (3, 40, 4, 4, 32), (1, 128, 16, 2, 64)]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_decode_attention_matches_ref(case, backend):
    B, S, H, K, D = case
    q = _randn(B, H, D)
    k, v = _randn(B, S, K, D), _randn(B, S, K, D)
    lengths = jnp.asarray(RNG.integers(1, S + 1, size=(B,)), jnp.int32)
    ref = decode_attention_ref(q, k, v, lengths)
    got = decode_attention(q, k, v, lengths, backend=backend, block_k=16)
    np.testing.assert_allclose(ref, got, atol=2e-5, rtol=2e-5)


def test_decode_attention_ignores_garbage_past_length():
    B, S, H, K, D = 2, 32, 4, 2, 16
    q = _randn(B, H, D)
    k, v = _randn(B, S, K, D), _randn(B, S, K, D)
    lengths = jnp.asarray([10, 20], jnp.int32)
    base = decode_attention(q, k, v, lengths, backend="xla")
    k2 = k.at[0, 15:].set(1e9)                      # garbage beyond length
    v2 = v.at[0, 15:].set(-1e9)
    got = decode_attention(q, k2, v2, lengths, backend="xla")
    np.testing.assert_allclose(base, got, atol=1e-6)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [(2, 32, 4, 16, 2, 16, 8), (1, 24, 6, 8, 3, 8, 8),
             (2, 16, 4, 16, 1, 32, 16)]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_ssd_matches_ref(case, backend):
    Bt, S, H, P, G, N, chunk = case
    x = _randn(Bt, S, H, P)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(Bt, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    B = _randn(Bt, S, G, N)
    C = _randn(Bt, S, G, N)
    D = _randn(H)
    init = _randn(Bt, H, P, N)
    y_ref, f_ref = ssd_ref(x, dt, A, B, C, D, initial_state=init)
    y, f = ssd(x, dt, A, B, C, D, chunk=chunk, initial_state=init,
               backend=backend)
    np.testing.assert_allclose(y_ref, y, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(f_ref, f, atol=1e-4, rtol=1e-4)


def test_ssd_large_decay_no_nan():
    """Regression: masked upper-tri segsum overflow must not produce NaNs."""
    Bt, S, H, P, G, N = 1, 32, 2, 8, 1, 8
    x = _randn(Bt, S, H, P)
    dt = jnp.asarray(RNG.uniform(0.5, 3.0, size=(Bt, S, H)), jnp.float32)
    A = jnp.asarray([-12.0, -16.0], jnp.float32)
    B = _randn(Bt, S, G, N)
    C = _randn(Bt, S, G, N)
    D = _randn(H)
    y, f = ssd(x, dt, A, B, C, D, chunk=8, backend="xla")
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(f).all())


def test_ssd_step_matches_scan():
    Bt, S, H, P, G, N = 2, 6, 4, 8, 2, 8
    x = _randn(Bt, S, H, P)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, size=(Bt, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    B = _randn(Bt, S, G, N)
    C = _randn(Bt, S, G, N)
    D = _randn(H)
    y_ref, _ = ssd_ref(x, dt, A, B, C, D)
    state = jnp.zeros((Bt, H, P, N), jnp.float32)
    for t in range(S):
        y_t, state = ssd_step(state, x[:, t], dt[:, t], A, B[:, t], C[:, t], D)
        np.testing.assert_allclose(y_ref[:, t], y_t, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [(64, 32, 48, 4, 8), (100, 16, 16, 5, 16),
                                  (40, 8, 24, 3, 8)])
@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_gmm_matches_ref(case, backend):
    T, d, f, E, bm = case
    x = _randn(T, d)
    w = _randn(E, d, f)
    cuts = np.sort(RNG.integers(0, T + 1, size=E - 1))
    gs = jnp.asarray(np.diff(np.concatenate([[0], cuts, [T]])), jnp.int32)
    ref = gmm_ref(x, w, gs)
    got = gmm(x, w, gs, backend=backend, block_m=bm, block_n=8)
    np.testing.assert_allclose(ref, got, atol=1e-4, rtol=1e-4)


def test_gmm_empty_groups():
    T, d, f, E = 32, 8, 8, 4
    x = _randn(T, d)
    w = _randn(E, d, f)
    gs = jnp.asarray([0, T, 0, 0], jnp.int32)       # all tokens -> expert 1
    ref = gmm_ref(x, w, gs)
    got = gmm(x, w, gs, backend="pallas_interpret", block_m=8, block_n=8)
    np.testing.assert_allclose(ref, got, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# state push
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(100,), (13, 7), (5, 5, 5), (1,)])
@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_state_push_roundtrip(shape, backend):
    local = _randn(*shape)
    base = _randn(*shape)
    gv = _randn(*shape)
    q, s, n = quantize_delta(local, base, backend=backend)
    newg = apply_delta(gv, q, s, backend=backend)
    exact = gv + (local - base)
    bound = float(np.abs(np.asarray(local - base)).max()) / 127 * 1.01 + 1e-8
    np.testing.assert_allclose(newg, exact, atol=bound)      # int8 error bound
    p = push(local, base, gv, backend=backend)
    np.testing.assert_allclose(p, exact, atol=1e-6)


def test_quantize_zero_delta_is_exact():
    x = _randn(64)
    q, s, _ = quantize_delta(x, x, backend="xla")
    assert int(jnp.abs(q).max()) == 0
