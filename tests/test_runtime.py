"""FAASM runtime integration: chaining, scheduler, proto restore, isolation
modes, fault tolerance, stragglers, elasticity."""
import time

import numpy as np
import pytest

from repro.core import (FaasmRuntime, FunctionDef, chain, await_all, outputs,
                        ProtoFaaslet)
from repro.state.ddo import Counter, DistDict, VectorAsync


def _echo(api):
    api.write_call_output(b"echo:" + api.read_call_input())
    return 0


def test_invoke_and_output():
    rt = FaasmRuntime(n_hosts=2)
    try:
        rt.upload(FunctionDef("echo", _echo))
        cid = rt.invoke("echo", b"hi")
        assert rt.wait(cid, timeout=10) == 0
        assert rt.output(cid) == b"echo:hi"
    finally:
        rt.shutdown()


def test_chained_calls_listing1_pattern():
    rt = FaasmRuntime(n_hosts=3, capacity=4)
    try:
        def worker(api):
            i = int.from_bytes(api.read_call_input(), "little")
            api.write_call_output((i * i).to_bytes(4, "little"))
            return 0

        def main(api):
            cids = chain(api, "worker", [i.to_bytes(1, "little")
                                         for i in range(8)])
            rcs = await_all(api, cids)
            assert all(r == 0 for r in rcs)
            outs = outputs(api, cids)
            total = sum(int.from_bytes(o, "little") for o in outs)
            api.write_call_output(total.to_bytes(4, "little"))
            return 0

        rt.upload(FunctionDef("worker", worker))
        rt.upload(FunctionDef("main", main))
        cid = rt.invoke("main")
        assert rt.wait(cid, timeout=30) == 0
        assert int.from_bytes(rt.output(cid), "little") == sum(i * i
                                                               for i in range(8))
    finally:
        rt.shutdown()


def test_warm_faaslets_reused_and_reset():
    """Second call hits a warm Faaslet; private memory is reset between calls
    (§5.2 multi-tenant guarantee)."""
    rt = FaasmRuntime(n_hosts=1)
    try:
        leaks = []

        def fn(api):
            api.faaslet.brk(64)
            data = bytes(api.faaslet.read(0, 6))
            leaks.append(data)
            api.faaslet.write(0, b"secret")
            return 0

        rt.upload(FunctionDef("fn", fn))
        for _ in range(3):
            assert rt.wait(rt.invoke("fn"), timeout=10) == 0
        stats = rt.cold_start_stats()
        assert stats["warm_hits"] >= 2
        assert len(leaks) == 3
        assert b"secret" not in leaks[1:]            # reset wiped it
        # the reset went through the O(dirty) CoW path, not a full copy
        assert stats["resets"] == 3
        assert 1 <= stats["reset_pages"] <= 3
    finally:
        rt.shutdown()


def test_proto_faaslet_cross_host_restore():
    p = None

    def init(api):
        api.faaslet.brk(128)
        api.faaslet.write(0, b"weights-v1")
        return {"extra": 42}

    rt = FaasmRuntime(n_hosts=2)
    try:
        rt.upload(FunctionDef("f", _echo, init_fn=init))
        key = "proto/f"
        assert rt.global_tier.exists(key)
        proto = ProtoFaaslet.deserialize(rt.global_tier.get(key, host="test"))
        faaslet, state = proto.restore("some-other-host")
        assert bytes(faaslet.read(0, 10)) == b"weights-v1"
        assert state == {"extra": 42}
        assert faaslet.restored_from_proto
    finally:
        rt.shutdown()


def test_scheduler_prefers_warm_hosts():
    rt = FaasmRuntime(n_hosts=4)
    try:
        rt.upload(FunctionDef("f", _echo))
        first = rt.invoke("f", b"a")
        rt.wait(first, timeout=10)
        warm_host = rt.call(first).host
        hosts = set()
        for _ in range(6):
            cid = rt.invoke("f", b"b")
            rt.wait(cid, timeout=10)
            hosts.add(rt.call(cid).host)
        assert warm_host in hosts
        stats = rt.cold_start_stats()
        assert stats["warm_hits"] >= 5               # most calls stayed warm
    finally:
        rt.shutdown()


def test_host_failure_reexecutes_calls():
    rt = FaasmRuntime(n_hosts=2)
    try:
        def slow(api):
            time.sleep(0.4)
            api.write_call_output(b"done")
            return 0

        rt.upload(FunctionDef("slow", slow))
        cid = rt.invoke("slow")
        time.sleep(0.1)
        victim = rt.call(cid).host
        assert victim is not None
        rt.fail_host(victim)
        assert rt.wait(cid, timeout=30) == 0
        assert rt.output(cid) == b"done"
        assert rt.call(cid).attempts == 2
    finally:
        rt.shutdown()


def test_state_survives_host_failure_via_global_tier():
    rt = FaasmRuntime(n_hosts=2)
    try:
        VectorAsync.create(rt.global_tier, "w", np.arange(4, dtype=np.float32))

        def reader(api):
            v = VectorAsync(api, "w")
            api.write_call_output(np.asarray(v.values, np.float32).tobytes())
            return 0

        rt.upload(FunctionDef("reader", reader))
        c1 = rt.invoke("reader")
        rt.wait(c1, timeout=10)
        rt.fail_host(rt.call(c1).host)               # local tier dropped
        c2 = rt.invoke("reader")
        assert rt.wait(c2, timeout=10) == 0
        got = np.frombuffer(rt.output(c2), np.float32)
        np.testing.assert_allclose(got, np.arange(4, dtype=np.float32))
    finally:
        rt.shutdown()


def test_straggler_speculative_execution():
    rt = FaasmRuntime(n_hosts=2, straggler_timeout=0.3)
    try:
        state = {"n": 0}

        def sometimes_slow(api):
            state["n"] += 1
            if state["n"] == 1:
                time.sleep(5.0)                      # first attempt straggles
            api.write_call_output(b"ok")
            return 0

        rt.upload(FunctionDef("s", sometimes_slow))
        t0 = time.perf_counter()
        cid = rt.invoke("s")
        assert rt.wait(cid, timeout=30) == 0
        assert time.perf_counter() - t0 < 4.0        # didn't wait for straggler
    finally:
        rt.shutdown()


def test_elastic_add_remove_host():
    rt = FaasmRuntime(n_hosts=1)
    try:
        rt.upload(FunctionDef("echo", _echo))
        hid = rt.add_host()
        assert len(rt.alive_hosts()) == 2
        cids = [rt.invoke("echo", bytes([i])) for i in range(6)]
        for c in cids:
            rt.wait(c, timeout=10)
        rt.remove_host(hid, drain=True)
        assert len(rt.alive_hosts()) == 1
        cid = rt.invoke("echo", b"post")
        assert rt.wait(cid, timeout=10) == 0
    finally:
        rt.shutdown()


def test_container_mode_ships_data_faaslet_shares():
    """The §6 comparison: same code, container mode moves more bytes."""
    results = {}
    for mode in ("faaslet", "container"):
        rt = FaasmRuntime(n_hosts=1, isolation=mode)
        try:
            VectorAsync.create(rt.global_tier,
                               "big", np.zeros(50_000, np.float32))

            def toucher(api):
                api.get_state("big", writable=False)
                time.sleep(0.3)                     # force concurrent instances
                return 0

            rt.upload(FunctionDef("t", toucher))
            rt.global_tier.reset_metrics()
            cids = [rt.invoke("t") for _ in range(4)]
            for c in cids:
                assert rt.wait(c, timeout=15) == 0
            results[mode] = rt.transfer_bytes()
        finally:
            rt.shutdown()
    # container mode re-pulls per instance; faaslets share one replica
    assert results["faaslet"] < results["container"]


def test_counter_and_dict_consistency_under_concurrency():
    rt = FaasmRuntime(n_hosts=3, capacity=4)
    try:
        def inc(api):
            Counter(api, "c").increment()
            return 0

        rt.upload(FunctionDef("inc", inc))
        cids = [rt.invoke("inc") for _ in range(20)]
        for c in cids:
            assert rt.wait(c, timeout=20) == 0

        def read(api):
            api.write_call_output(str(Counter(api, "c").value()).encode())
            return 0

        rt.upload(FunctionDef("read", read))
        cid = rt.invoke("read")
        rt.wait(cid, timeout=10)
        assert rt.output(cid) == b"20"
    finally:
        rt.shutdown()


def test_container_tier_dropped_on_failed_call():
    """A failed call in container isolation must not leave its private tier
    (half-written replicas) behind: the retry re-pulls clean state."""
    rt = FaasmRuntime(n_hosts=1, isolation="container")
    try:
        VectorAsync.create(rt.global_tier, "w", np.zeros(8, np.float32))
        attempts = {"n": 0}

        def writer(api):
            attempts["n"] += 1
            v = VectorAsync(api, "w")
            v[0] = 13.0                          # half-written replica
            if attempts["n"] == 1:
                raise RuntimeError("boom")       # fail before push
            # retry: the private replica must be a clean re-pull, not the
            # poisoned one from the failed attempt
            api.write_call_output(
                np.asarray(v.values, np.float32).tobytes())
            return 0

        rt.upload(FunctionDef("writer", writer))
        host = rt.hosts["host0"]
        c1 = rt.invoke("writer")
        assert rt.wait(c1, timeout=10) == 1      # first attempt fails
        assert host._container_tiers == {}       # tier dropped with the failure
        c2 = rt.invoke("writer")
        assert rt.wait(c2, timeout=10) == 0
    finally:
        rt.shutdown()


def test_straggler_cancelled_after_twin_settles():
    """Speculation cleanup: once the twin's result is adopted, the straggler
    stops at its next host-interface checkpoint instead of running its loop
    to completion in an executor slot."""
    rt = FaasmRuntime(n_hosts=2, straggler_timeout=0.2)
    try:
        VectorAsync.create(rt.global_tier, "w", np.zeros(4, np.float32))
        progress = {"first": 0}
        state = {"n": 0}

        def sometimes_slow(api):
            state["n"] += 1
            if state["n"] == 1:                  # first attempt straggles
                for _ in range(100):
                    time.sleep(0.05)
                    api.pull_state("w")          # cooperative checkpoint
                    progress["first"] += 1
            api.write_call_output(b"ok")
            return 0

        rt.upload(FunctionDef("s", sometimes_slow))
        cid = rt.invoke("s")
        assert rt.wait(cid, timeout=30) == 0
        assert rt.output(cid) == b"ok"
        # the straggler hits a checkpoint within ~50ms of the twin settling
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline and \
                sum(h.cancelled_execs for h in rt.hosts.values()) == 0:
            time.sleep(0.05)
        assert sum(h.cancelled_execs for h in rt.hosts.values()) == 1
        assert progress["first"] < 50            # it stopped early, not at 100
        assert rt.call(cid).status == "done"     # the adopted result stands
    finally:
        rt.shutdown()


def test_host_interface_misc():
    rt = FaasmRuntime(n_hosts=1)
    try:
        rt.vfs.put_global("models/readme.txt", b"hello file")
        rt.register_module("libmath", {"square": lambda x: x * x})

        def fn(api):
            fd = api.open("models/readme.txt")
            data = api.read(fd, 100)
            api.close(fd)
            h = api.dlopen("libmath")
            sq = api.dlsym(h, "square")
            t = api.gettime()
            rnd = api.getrandom(8)
            assert t >= 0 and len(rnd) == 8
            wfd = api.open("scratch/out.txt", "w")
            api.write(wfd, b"local write")
            api.close(wfd)
            api.write_call_output(data + str(sq(7)).encode())
            return 0

        rt.upload(FunctionDef("fn", fn))
        cid = rt.invoke("fn")
        assert rt.wait(cid, timeout=10) == 0, rt.call(cid).error
        assert rt.output(cid) == b"hello file49"
        # write-local: visible on the host overlay, not the global store
        assert rt.vfs.read(rt.call(cid).host, "scratch/out.txt") == b"local write"
        assert not rt.global_tier.exists("fs::scratch/out.txt")
    finally:
        rt.shutdown()
