"""Runtime sanitizer: a multi-threaded fabric hammer must run clean, every
check must fire on a deliberately seeded violation, and the instrumentation
must compile out to raw locks when disabled.

All fabric objects are built inside ``sanitize``-marked tests so the
conftest fixture has already enabled the sanitizer (instrumentation is
decided at lock construction).  Seeded tests drain their reports with
``take_reports()``; anything left over fails the test via the fixture.
"""
import os
import threading
import time
import types

import numpy as np
import pytest

from repro import cancellation
from repro.analysis import sanitizer
from repro.state.kv import GlobalTier, RWLock
from repro.state.local import INT8_WIRE_MIN_BYTES, LocalTier
from repro.state.wire import get_codec

N = max(INT8_WIRE_MIN_BYTES // 4, 2048)     # floats per key: int8-eligible


def checks_of(reports):
    return {r.check for r in reports}


# -- the concurrency hammer --------------------------------------------------

@pytest.mark.sanitize
def test_hammer_pushers_pullers_subscribers_run_clean():
    """N pusher tiers × M puller tiers × a broadcast subscriber pounding
    shared keys for ~2 s: the real fabric must produce zero reports."""
    gt = GlobalTier()
    keys = ["a", "b"]
    for k in keys:
        gt.set(k, np.zeros(N, np.float32).tobytes(), host="seed")

    def tier(name, *, base=False, sub=False):
        t = LocalTier(name, gt)
        for k in keys:
            t.pull(k)
            if base:
                t.snapshot_base(k)
            if sub:
                t.subscribe(k)
        return t

    pushers = [tier(f"push{i}", base=True) for i in range(2)]
    pullers = [tier(f"pull{i}") for i in range(2)]
    sub = tier("sub", sub=True)

    deadline = time.monotonic() + 2.0
    stop = threading.Event()
    errors = []

    def run(fn):
        try:
            i = 0
            while time.monotonic() < deadline and not stop.is_set():
                fn(i)
                i += 1
        except Exception as e:                  # pragma: no cover - fail path
            errors.append(e)
            stop.set()

    def pusher_loop(t, rng):
        def step(i):
            k = keys[i % len(keys)]
            view = t.replica(k).buf.view(np.float32)
            view[:] += rng.normal(size=N).astype(np.float32) * 0.01
            t.push_delta(k, wire="int8" if i % 3 else "exact")
        return step

    def puller_loop(t):
        def step(i):
            t.pull(keys[i % len(keys)], wire="int8" if i % 2 else "exact")
        return step

    def sub_loop(t):
        def step(i):
            # mostly passive (broadcast delivery), occasional catch-up pull
            if i % 7 == 0:
                t.pull(keys[i % len(keys)])
            else:
                time.sleep(0.001)
        return step

    threads = [threading.Thread(target=run, args=(pusher_loop(t, np.random.default_rng(j)),))
               for j, t in enumerate(pushers)]
    threads += [threading.Thread(target=run, args=(puller_loop(t),))
                for t in pullers]
    threads += [threading.Thread(target=run, args=(sub_loop(sub),))]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30)
    assert not errors, errors
    reports = sanitizer.take_reports()
    assert reports == [], "\n\n".join(str(r) for r in reports)


# -- seeded violations: one per check ----------------------------------------

@pytest.mark.sanitize
def test_seeded_lock_order_cycle_reports_both_stacks():
    a = sanitizer.make_mutex("A")
    b = sanitizer.make_mutex("B")
    with a:
        with b:
            pass
    with b:
        with a:                                 # reverse order: cycle
            pass
    reports = sanitizer.take_reports()
    assert checks_of(reports) == {"lock-order"}
    (r,) = reports
    assert "deadlock potential" in r.message
    assert r.stack and r.other_stack            # both acquisition stacks


@pytest.mark.sanitize
def test_seeded_same_kind_nesting_is_reported():
    s1 = sanitizer.make_mutex("stripe", "s1")
    s2 = sanitizer.make_mutex("stripe", "s2")
    with s1:
        with s2:
            pass
    reports = sanitizer.take_reports()
    assert checks_of(reports) == {"lock-order"}
    assert "homogeneous" in reports[0].message


@pytest.mark.sanitize
def test_reentrant_acquire_is_not_a_violation():
    m = sanitizer.make_mutex("host")
    with m:
        with m:
            pass
    assert sanitizer.take_reports() == []


@pytest.mark.sanitize
def test_seeded_unheld_release_is_lock_misuse():
    m = sanitizer.make_mutex("host", "probe")
    with pytest.raises(RuntimeError):
        m.release()
    assert checks_of(sanitizer.take_reports()) == {"lock-misuse"}


@pytest.mark.sanitize
def test_seeded_stripe_touch_without_lock():
    st = sanitizer.enable()                     # the active state (idempotent)
    gt = GlobalTier()
    s = gt._stripe("k")
    st.stripe_touch(s.lock, "k")                # not holding s.lock
    reports = sanitizer.take_reports()
    assert checks_of(reports) == {"stripe-ownership"}
    # and the same touch under the lock is clean
    with s.lock:
        st.stripe_touch(s.lock, "k")
    assert sanitizer.take_reports() == []


@pytest.mark.sanitize
def test_seeded_torn_read():
    st = sanitizer.enable()
    gt = GlobalTier()
    tok = st.read_begin(gt, "k")
    st.gen_bump(gt, "k")                        # concurrent mutation mid-read
    st.read_end(gt, "k", tok)
    assert checks_of(sanitizer.take_reports()) == {"torn-read"}


@pytest.mark.sanitize
def test_seeded_wire_version_regression():
    st = sanitizer.enable()
    gt = GlobalTier()
    st.version_bumped(gt, "k", 5, 5)            # non-advancing bump
    st.frame_applied(gt, "k", types.SimpleNamespace(prev_version=3,
                                                    version=3))
    reports = sanitizer.take_reports()
    assert checks_of(reports) == {"wire-version"}
    assert len(reports) == 2


@pytest.mark.sanitize
def test_seeded_wire_window_gap_and_floor():
    st = sanitizer.enable()
    gt = GlobalTier()
    # gap: frame 7->8 appended after a window whose tail is version 5
    st.frame_recorded(gt, "k", types.SimpleNamespace(prev_version=7,
                                                     version=8),
                      tail_version=5, floor=0)
    # empty window starting below its floor
    st.frame_recorded(gt, "k", types.SimpleNamespace(prev_version=1,
                                                     version=2),
                      tail_version=None, floor=4)
    reports = sanitizer.take_reports()
    assert checks_of(reports) == {"wire-window"}
    assert len(reports) == 2


@pytest.mark.sanitize
def test_seeded_residual_conservation_violation():
    st = sanitizer.enable()
    delta = np.array([1.0, -2.0, 0.5], np.float32)
    carried = np.array([0.9, -1.9, 0.4], np.float32)
    st.check_residual(delta, carried, None)     # dropped the carry: off by .1
    assert checks_of(sanitizer.take_reports()) == {"wire-residual"}
    # conserved residual is clean
    st.check_residual(delta, carried, delta - carried)
    assert sanitizer.take_reports() == []


@pytest.mark.sanitize
def test_seeded_attempt_fence_violations():
    st = sanitizer.enable()
    # same (call, key, seq) admitted twice: a re-execution double-applied
    st.fence_write("c1", 1, "k", 1, True)
    st.fence_write("c1", 2, "k", 1, True)
    # a write admitted from an epoch the runtime already superseded: zombie
    st.fence_superseded("c2", 3)
    st.fence_write("c2", 3, "k", 1, True)
    reports = sanitizer.take_reports()
    assert checks_of(reports) == {"attempt-fence"}
    assert len(reports) == 2
    assert any("double-applied" in r.message for r in reports)
    assert any("zombie" in r.message for r in reports)
    # the healthy traces are clean: a rejected duplicate, a fresh seq, and
    # a live (not yet superseded) epoch
    st.fence_write("c3", 1, "k", 1, True)
    st.fence_write("c3", 2, "k", 1, False)          # tier rejected the dup
    st.fence_write("c3", 2, "k", 2, True)
    st.fence_superseded("c4", 1)
    st.fence_write("c4", 2, "k", 1, True)
    assert sanitizer.take_reports() == []


@pytest.mark.sanitize
def test_seeded_cancellation_checkpoint_under_stripe_lock():
    gt = GlobalTier()
    s = gt._stripe("w")
    with s.lock:
        cancellation.checkpoint()               # end-to-end through the guard
    reports = sanitizer.take_reports()
    assert checks_of(reports) == {"cancel-under-lock"}
    assert "stripe" in reports[0].message
    # outside the lock the checkpoint is clean
    cancellation.checkpoint()
    assert sanitizer.take_reports() == []


@pytest.mark.sanitize
def test_seeded_apply_frame_without_write_lock():
    gt = GlobalTier()
    gt.set("k", np.zeros(4, np.float32).tobytes(), host="seed")
    t = LocalTier("h", gt)
    t.pull("k")
    r = t.replica("k")
    frame, _ = get_codec("exact").encode(np.ones(4, np.float32),
                                         np.zeros(4, np.float32))
    t._apply_frame_locked(r, frame)             # contract: write lock held
    assert checks_of(sanitizer.take_reports()) == {"lock-misuse"}
    r.lock.acquire_write()
    try:
        t._apply_frame_locked(r, frame)
    finally:
        r.lock.release_write()
    assert sanitizer.take_reports() == []


# -- compile-out --------------------------------------------------------------

@pytest.mark.skipif(os.environ.get("FAASM_SANITIZE") == "1",
                    reason="suite running under FAASM_SANITIZE=1")
def test_disabled_sanitizer_compiles_out_to_raw_locks():
    raw_rlock = type(threading.RLock())
    assert isinstance(sanitizer.make_mutex("stripe"), raw_rlock)
    rw = RWLock()
    assert sanitizer.wrap_rwlock(rw, "replica") is rw
    gt = GlobalTier()
    assert isinstance(gt._stripe("k").lock, raw_rlock)
    t = LocalTier("h", gt)
    gt.set("k", b"\0" * 8, host="seed")
    assert isinstance(t.replica("k").lock, RWLock)
    # hook globals are cleared: the per-call guard is one pointer compare
    from repro.state import kv, local, wire
    assert kv._SAN is None and local._SAN is None and wire._SAN is None
    assert cancellation._SAN_GUARD is None
