"""Symmetric wire fabric: quantised delta pulls (with stale-base fallback
and pull-side error feedback), peer broadcast (subscriber churn, base
coherence, device apply), adaptive wire selection (flip-flop damping), and
exact/int8 parity of the pull-direction kernel entry points on both the
``xla`` and ``pallas_interpret`` backends.

The ``pallas_interpret`` parametrisations are auto-marked slow by conftest;
the xla rows run in the ``scripts/tier1.sh`` fast gate."""
import numpy as np
import pytest

from repro.kernels.state_push import apply_pull, dequantize, encode_pull
from repro.state.kv import GlobalTier
from repro.state.local import INT8_WIRE_MIN_BYTES, LocalTier
from repro.state.wire import WireFrame, WirePolicy, get_codec

BACKENDS = ("xla", "pallas_interpret")


def _rng(seed=0):
    return np.random.default_rng(seed)


def _setup(n, *, seed=0, init=None, **gt_kwargs):
    """Global tier with an n-float key, a pusher (base armed) and a puller
    (warm full replica)."""
    gt = GlobalTier(**gt_kwargs)
    init = np.zeros(n, np.float32) if init is None else init
    gt.set("w", init.tobytes(), host="up")
    pusher = LocalTier("pusher", gt)
    pusher.pull("w")
    pusher.snapshot_base("w")
    puller = LocalTier("puller", gt)
    puller.pull("w")
    return gt, pusher, puller


def _global(gt, key="w"):
    return np.frombuffer(gt.get(key, host="check"), np.float32)


# -- delta pulls ---------------------------------------------------------------


def test_warm_int8_refresh_moves_under_30_percent():
    """Acceptance criterion: a warm-replica 4 MB f32 refresh via
    ``pull(wire="int8")`` moves ≤ 30% of the exact (full) pull bytes."""
    size = 4 << 20
    n = size // 4
    gt, pusher, puller = _setup(n)
    view = pusher.replica("w").buf.view(np.float32)
    view[:] += (_rng(1).normal(size=n) * 0.01).astype(np.float32)
    pusher.push_delta("w", wire="int8")
    gt.reset_metrics()
    moved = puller.pull("w", wire="int8")
    assert 0 < moved <= 0.30 * size
    assert gt.bytes_pulled["puller"] == moved
    got = puller.replica("w").buf.view(np.float32)
    want = _global(gt)
    # one delta pull: error bounded by one quantisation step of the delta
    assert np.abs(got - want).max() <= 0.01 * 6 / 254.0 + 1e-6
    # up to date now: the next pull moves nothing
    assert puller.pull("w", wire="int8") == 0


def test_exact_delta_pull_is_exact():
    n = INT8_WIRE_MIN_BYTES // 4 * 4
    gt, pusher, puller = _setup(n)
    view = pusher.replica("w").buf.view(np.float32)
    view[:] += (_rng(2).normal(size=n)).astype(np.float32)
    pusher.push_delta("w", wire="exact")
    moved = puller.pull("w", wire="exact")
    assert moved == n * 4                       # the f32 delta frame
    np.testing.assert_array_equal(
        puller.replica("w").buf.view(np.float32), _global(gt))


def test_repeated_int8_pulls_carry_residual():
    """Pull-side error feedback: across many quantised refreshes the
    replica tracks the global value within ~one step (no random walk)."""
    n = INT8_WIRE_MIN_BYTES // 4 * 8
    gt, pusher, puller = _setup(n)
    view = pusher.replica("w").buf.view(np.float32)
    rng = _rng(3)
    for _ in range(12):
        view[:] += (rng.normal(size=n) * 0.01).astype(np.float32)
        pusher.push_delta("w", wire="exact")    # global moves exactly
        puller.pull("w", wire="int8")           # replica refreshes quantised
    got = puller.replica("w").buf.view(np.float32)
    assert np.abs(got - _global(gt)).max() <= 2 * 0.01 * 6 / 254.0
    assert puller.replica("w").pull_residual is not None


def test_stale_base_falls_back_to_full_pull():
    """A base older than the retained window floor can't be served as a
    delta: the pull degrades to a full (exact) re-pull."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt, pusher, puller = _setup(n, delta_window=2)
    view = pusher.replica("w").buf.view(np.float32)
    for _ in range(5):                          # window keeps only the last 2
        view[:] += 1.0
        pusher.push_delta("w", wire="int8")
    gt.reset_metrics()
    moved = puller.pull("w", wire="int8")
    assert moved == n * 4                       # full-value bytes
    np.testing.assert_array_equal(
        puller.replica("w").buf.view(np.float32), _global(gt))
    assert puller.pull("w") == 0                # re-based: now current


def test_non_delta_write_invalidates_window():
    """set()/push() overwrite semantics can't be expressed as retained
    deltas: pulls from older bases full-pull, exactly."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt, pusher, puller = _setup(n)
    view = pusher.replica("w").buf.view(np.float32)
    view[:] += 2.0
    pusher.push_delta("w", wire="int8")
    gt.set("w", np.full(n, 7.0, np.float32).tobytes(), host="up")
    gt.reset_metrics()
    moved = puller.pull("w", wire="int8")
    assert moved == n * 4
    np.testing.assert_array_equal(puller.replica("w").buf.view(np.float32),
                                  np.full(n, 7.0, np.float32))


def test_pull_after_grow_falls_back():
    """append() grows the value and invalidates the window: the warm
    replica full-pulls the grown value instead of mis-applying a delta."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt, pusher, puller = _setup(n, init=np.full(n, 1.0, np.float32))
    gt.append("w", np.full(n, 5.0, np.float32).tobytes(), host="up")
    moved = puller.pull("w", wire="int8")
    assert moved == 2 * n * 4
    got = puller.replica("w").buf.view(np.float32)
    np.testing.assert_array_equal(got[n:], 5.0)


def test_pull_rejects_bogus_wire():
    n = INT8_WIRE_MIN_BYTES // 4
    gt, pusher, puller = _setup(n)
    pusher.replica("w").buf.view(np.float32)[:] += 1.0
    pusher.push_delta("w", wire="int8")
    with pytest.raises(ValueError):
        puller.pull("w", wire="bogus")


# -- peer broadcast ------------------------------------------------------------


def test_subscribed_peer_converges_with_zero_pull_bytes():
    """Acceptance criterion: after one int8 push a subscribed peer replica
    holds the new global value and its next pull moves zero bytes."""
    n = (4 << 20) // 4
    gt, pusher, _ = _setup(n)
    peer = LocalTier("peer", gt)
    peer.subscribe("w")
    gt.reset_metrics()
    view = pusher.replica("w").buf.view(np.float32)
    view[:] += (_rng(5).normal(size=n) * 0.01).astype(np.float32)
    pusher.push_delta("w", wire="int8")
    gt.flush_broadcasts()                       # fan-out is async: drain it
    # the peer replica converged through the broadcast alone
    np.testing.assert_array_equal(peer.replica("w").buf.view(np.float32),
                                  _global(gt))
    assert gt.bytes_pulled.get("peer", 0) == 0
    assert peer.pull("w", wire="int8") == 0     # zero pull bytes
    assert gt.total_broadcast() > 0             # push-side fan-out accounted


def test_broadcast_updates_base_no_repush():
    """The broadcast delta lands in the peer's delta base too: its next
    push ships only its own writes, never the peer-received delta."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt, pusher, _ = _setup(n)
    peer = LocalTier("peer", gt)
    peer.subscribe("w")
    peer.snapshot_base("w")
    pview = pusher.replica("w").buf.view(np.float32)
    pview[:] += 2.0
    pusher.push_delta("w", wire="int8")         # broadcast lands at the peer
    gt.flush_broadcasts()
    peer.push_delta("w", wire="exact")          # peer pushes nothing new
    np.testing.assert_allclose(_global(gt), 2.0, atol=1e-5)


def test_broadcast_applies_to_fresh_device_replica():
    """A device-resident subscribed replica stays fresh: the frame is
    applied to the device value and base, so a later device-native push
    carries no phantom delta."""
    jnp = pytest.importorskip("jax.numpy")
    n = INT8_WIRE_MIN_BYTES // 4
    gt, pusher, _ = _setup(n)
    peer = LocalTier("peer", gt)
    peer.subscribe("w")
    peer.to_device("w", track_delta=True)
    pview = pusher.replica("w").buf.view(np.float32)
    pview[:] += 2.0
    pusher.push_delta("w", wire="int8")
    gt.flush_broadcasts()
    assert not peer.device_stale("w")
    np.testing.assert_allclose(np.asarray(peer.device_replica("w").value),
                               _global(gt), atol=1e-6)
    peer.push_delta("w", wire="int8")           # device-native, zero delta
    np.testing.assert_allclose(_global(gt), 2.0, atol=1e-5)
    assert jnp is not None


def test_subscriber_churn_host_leaves_mid_broadcast():
    """A subscriber whose host left (replica evicted / callback raising) is
    dropped mid-broadcast; the healthy peers still receive the frame."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt, pusher, _ = _setup(n)
    healthy = LocalTier("healthy", gt)
    healthy.subscribe("w")
    leaver = LocalTier("leaver", gt)
    leaver.subscribe("w")
    calls = {"dead": 0}

    def dead_cb(key, frame):
        calls["dead"] += 1
        raise RuntimeError("host went away")

    gt.subscribe("w", "dead-host", dead_cb)
    # the leaver's host fails between subscribe and push: drop() cancels
    # its subscription, simulating departure mid-stream
    leaver.drop()
    view = pusher.replica("w").buf.view(np.float32)
    view[:] += 1.0
    pusher.push_delta("w", wire="int8")
    gt.flush_broadcasts()
    np.testing.assert_array_equal(healthy.replica("w").buf.view(np.float32),
                                  _global(gt))
    assert calls["dead"] == 1                   # delivered once, then dropped
    view[:] += 1.0
    pusher.push_delta("w", wire="int8")
    gt.flush_broadcasts()
    assert calls["dead"] == 1                   # raising subscriber was culled
    np.testing.assert_array_equal(healthy.replica("w").buf.view(np.float32),
                                  _global(gt))


def test_out_of_order_frame_skipped_then_repaired_by_pull():
    """A frame that doesn't extend the replica's exact version is skipped
    (never misapplied); the next pull repairs through the delta window."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt, pusher, _ = _setup(n)
    peer = LocalTier("peer", gt)
    peer.subscribe("w")
    view = pusher.replica("w").buf.view(np.float32)
    view[:] += 1.0
    pusher.push_delta("w", wire="exact")
    gt.flush_broadcasts()
    # replay the same frame versions: prev no longer matches -> skipped
    stale = WireFrame(wire="exact", numel=n,
                      payload=np.full(n, 100.0, np.float32),
                      prev_version=0, version=1)
    peer._deliver("w", stale)
    assert float(peer.replica("w").buf.view(np.float32).max()) < 50.0
    view[:] += 1.0
    pusher.push_delta("w", wire="exact")        # peer applies (versions chain)
    gt.flush_broadcasts()
    assert peer.pull("w") == 0 or True          # and pull reconciles any gap
    np.testing.assert_allclose(peer.replica("w").buf.view(np.float32),
                               _global(gt), atol=1e-5)


def test_racing_pushers_never_replay_their_own_frame():
    """Regression: a pusher whose push raced a peer's (its frame landed on
    top of a version it never saw) must not re-apply its own delta when it
    later delta-pulls — own-origin frames are excluded from the window
    composition."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt = GlobalTier()
    init = np.full(n, 10.0, np.float32)
    gt.set("w", init.tobytes(), host="up")
    a, b = LocalTier("a", gt), LocalTier("b", gt)
    for lt in (a, b):
        lt.pull("w")
        lt.snapshot_base("w")
    a.replica("w").buf.view(np.float32)[:] += 1.0
    a.push_delta("w", wire="exact")
    # b's push lands second: its frame's prev_version is a's version, which
    # b has not seen — b's global_version goes stale
    b.replica("w").buf.view(np.float32)[:] += 2.0
    b.push_delta("w", wire="exact")
    np.testing.assert_allclose(_global(gt), 13.0, atol=1e-5)
    moved = b.pull("w")                         # catches up on a's frame ONLY
    assert moved > 0
    np.testing.assert_allclose(b.replica("w").buf.view(np.float32), 13.0,
                               atol=1e-5)      # NOT 15.0 (own +2 replayed)
    assert b.pull("w") == 0
    # and b's next push carries nothing new
    b.push_delta("w", wire="exact")
    np.testing.assert_allclose(_global(gt), 13.0, atol=1e-5)


def test_broadcast_applies_f64_frames_with_value_dtype():
    """Regression: a broadcast frame for a float64 key must be applied
    through f64 views — an f32 reinterpretation scrambles the bytes."""
    n = INT8_WIRE_MIN_BYTES // 8
    gt = GlobalTier()
    gt.set("w", np.full(n, 1.0, np.float64).tobytes(), host="up")
    pusher = LocalTier("p", gt)
    pusher.pull("w")
    pusher.snapshot_base("w")
    peer = LocalTier("peer", gt)
    peer.subscribe("w")
    pusher.replica("w").buf.view(np.float64)[:] += 2.0
    pusher.push_delta("w", dtype=np.float64, wire="int8")
    gt.flush_broadcasts()                       # fan-out is async: drain it
    got = peer.replica("w").buf.view(np.float64)
    want = np.frombuffer(gt.get("w", host="x"), np.float64)
    np.testing.assert_allclose(got, want, atol=1e-4)
    np.testing.assert_allclose(got, 3.0, atol=1e-4)


def test_full_pull_fallback_refreshes_base_no_repush():
    """Regression: the warm-refresh full-pull fallback re-stamps the delta
    base from the pulled buffer — otherwise the next push re-applies every
    peer write since the stale snapshot."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt, pusher, puller = _setup(n)
    puller.snapshot_base("w")                   # base at version v0
    gt.set("w", np.full(n, 7.0, np.float32).tobytes(), host="up")  # window gone
    moved = puller.pull("w")                    # fallback full pull
    assert moved == n * 4
    puller.push_delta("w", wire="exact")        # nothing local: no-op push
    np.testing.assert_allclose(_global(gt), 7.0, atol=1e-6)


def test_exact_wire_pushes_fresh_device_value():
    """Regression: the exact wire must push from a fresh DeviceReplica's
    arrays, like the int8 path — a policy flip to exact on a
    device-resident key must not silently drop device-side updates."""
    pytest.importorskip("jax")
    n = INT8_WIRE_MIN_BYTES // 4
    gt, pusher, _ = _setup(n)
    dv = pusher.to_device("w", track_delta=True)
    pusher.update_device("w", dv + 2.0)         # device-side compute
    pusher.replica("w").buf.view(np.float32)[:] = 1e9   # poison host copy
    pusher.push_delta("w", wire="exact")
    np.testing.assert_allclose(_global(gt), 2.0, atol=1e-6)
    pusher.push_delta("w", wire="exact")        # base rebound: no re-push
    np.testing.assert_allclose(_global(gt), 2.0, atol=1e-6)


def test_stale_refresh_keeps_unpushed_local_writes():
    """Regression: the full-pull fallback must not clobber a replica's
    un-pushed local writes — warm pulls on a dirty replica stay a no-op
    (legacy semantics) until the writes are pushed."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt, pusher, puller = _setup(n, delta_window=2)
    puller.snapshot_base("w")
    puller.replica("w").buf.view(np.float32)[0] += 5.0
    puller.mark_dirty("w", 0, 4)                # un-pushed local write
    view = pusher.replica("w").buf.view(np.float32)
    for _ in range(5):                          # window floor passes puller
        view[:] += 1.0
        pusher.push_delta("w", wire="int8")
    assert puller.pull("w") == 0                # no clobber: writes pending
    assert puller.replica("w").buf.view(np.float32)[0] == 5.0
    puller.push_delta("w", wire="exact")        # ship the local write
    assert puller.pull("w") == n * 4            # clean now: full refresh
    np.testing.assert_allclose(_global(gt)[0], 10.0, atol=1e-3)
    np.testing.assert_allclose(
        puller.replica("w").buf.view(np.float32), _global(gt), atol=1e-6)


def test_inplace_exact_push_keeps_warm_pull_free():
    """Regression: the zero-copy in-place exact path (sole consumer, or
    sub-threshold keys) must keep the pusher's base version current — its
    warm pulls stay 0-byte no-ops instead of full re-pulls per push."""
    n = 1024                                    # sub-threshold f32 key
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    lt = LocalTier("h", gt)
    lt.pull("w")
    lt.snapshot_base("w")
    gt.reset_metrics()
    for _ in range(3):
        lt.replica("w").buf.view(np.float32)[:] += 1.0
        lt.push_delta("w", wire="exact")        # in-place legacy path
        assert lt.pull("w") == 0                # warm pull: no re-pull
    assert gt.bytes_pulled.get("h", 0) == 0
    np.testing.assert_allclose(_global(gt), 3.0, atol=1e-6)


def test_container_sibling_tiers_are_distinct_fabric_parties():
    """Regression: container tiers share a metrics host id (`runtime`
    re-points ``host_id`` at the physical host) but must remain distinct
    wire-fabric parties — a sibling's frames are NOT 'own frames' and a
    delta pull must deliver them."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    a = LocalTier("host0/c1", gt)
    b = LocalTier("host0/c2", gt)
    a.host_id = b.host_id = "host0"             # what container mode does
    for lt in (a, b):
        lt.pull("w")
        lt.snapshot_base("w")
    a.replica("w").buf.view(np.float32)[:] += 3.0
    a.push_delta("w", wire="int8")
    moved = b.pull("w", wire="int8")
    assert moved > 0                            # sibling's frame delivered
    np.testing.assert_allclose(b.replica("w").buf.view(np.float32), 3.0,
                               atol=1e-4)
    # and both siblings can hold broadcast subscriptions at once
    a.subscribe("w")
    b.subscribe("w")
    b.replica("w").buf.view(np.float32)[:] += 1.0
    b.push_delta("w", wire="exact")
    gt.flush_broadcasts()
    np.testing.assert_allclose(a.replica("w").buf.view(np.float32), 4.0,
                               atol=1e-4)


def test_write_only_keys_retain_no_frames():
    """Demand gating: with no other warm puller or subscriber, exact f32
    pushes stay on the zero-copy in-place path (no value-sized memcpy
    accounted) and nothing is retained; the first consumer full-pulls once
    and flips later pushes onto the frame path."""
    n = INT8_WIRE_MIN_BYTES // 4
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    pusher = LocalTier("pusher", gt)
    pusher.pull("w")
    pusher.snapshot_base("w")
    assert not gt.wire_interest("w", exclude="pusher")
    gt.reset_metrics()
    pusher.replica("w").buf.view(np.float32)[:] += 1.0
    pusher.push_delta("w", wire="exact")
    assert gt.total_copied() == 0               # in-place, no frame built
    late = LocalTier("late", gt)
    late.pull("w")                              # full pull declares interest
    assert gt.wire_interest("w", exclude="pusher")
    pusher.replica("w").buf.view(np.float32)[:] += 1.0
    pusher.push_delta("w", wire="exact")        # now recorded
    assert late.pull("w", wire="exact") == n * 4   # served as a delta
    np.testing.assert_array_equal(late.replica("w").buf.view(np.float32),
                                  _global(gt))


# -- adaptive wire selection ---------------------------------------------------


def test_policy_structural_fallbacks():
    p = WirePolicy()
    assert p.select(INT8_WIRE_MIN_BYTES - 1, np.float32) == "exact"
    assert p.select(1 << 20, np.int64) == "exact"
    assert p.select(1 << 20, np.float32) == "int8"


def test_policy_flips_after_damping_and_back():
    p = WirePolicy(damping=3)
    bad = dict(delta_absmax=1.0, density=0.9, residual_ratio=2.0)
    good = dict(delta_absmax=1.0, density=0.9, residual_ratio=0.001)
    p.observe(**bad)
    p.observe(**bad)
    assert p.wire == "int8"                     # not yet: damping holds
    p.observe(**bad)
    assert p.wire == "exact"                    # 3 consecutive -> flip
    p.observe(**good)
    p.observe(**good)
    p.observe(**good)
    assert p.wire == "int8"                     # healthy again -> flip back


def test_policy_flip_flop_damped():
    """Alternating good/bad observations never accumulate a streak: the
    wire stays put instead of thrashing."""
    p = WirePolicy(damping=2)
    bad = dict(delta_absmax=1.0, density=0.9, residual_ratio=2.0)
    good = dict(delta_absmax=1.0, density=0.9, residual_ratio=0.0)
    for _ in range(10):
        p.observe(**bad)
        p.observe(**good)
    assert p.wire == "int8"
    # zero-delta pushes teach nothing either
    p.observe(delta_absmax=0.0, density=0.0, residual_ratio=9.9)
    assert p.wire == "int8"


def test_policy_prefers_exact_for_sparse_deltas():
    p = WirePolicy(damping=1)
    p.observe(delta_absmax=1.0, density=1e-5, residual_ratio=0.0)
    assert p.wire == "exact"


def test_policy_exact_observations_never_vote_int8():
    """Regression: exact-wire pushes carry no quantisation evidence
    (residual_ratio=None) — they must not vote the policy back onto int8,
    or a key int8 genuinely mishandles would thrash exact↔int8 forever.
    Returning to int8 happens only through an explicit probe push."""
    p = WirePolicy(damping=1, probe_after=3)
    big, f32 = 1 << 20, np.float32
    p.observe(delta_absmax=1.0, density=0.9, residual_ratio=2.0)
    assert p.wire == "exact"
    for _ in range(2):                          # dense exact pushes: no vote
        p.observe(delta_absmax=1.0, density=0.9)
        assert p.wire == "exact" and p.select(big, f32) == "exact"
    p.observe(delta_absmax=1.0, density=0.9)    # 3rd: probe clock expires
    assert p.select(big, f32, probe=False) == "exact"   # pulls don't consume
    assert p.select(big, f32) == "int8"         # exactly one probe push
    assert p.select(big, f32) == "exact"        # then back until evidence
    p.observe(delta_absmax=1.0, density=0.9, residual_ratio=0.0)
    assert p.wire == "int8"                     # healthy probe re-qualifies


def test_auto_wire_end_to_end():
    """wire="auto" picks int8 for a large dense f32 key (wire bytes ~¼ of
    the value) and exact for a sub-threshold key."""
    big = (1 << 20) // 4
    gt, pusher, _ = _setup(big)
    view = pusher.replica("w").buf.view(np.float32)
    view[:] += (_rng(7).normal(size=big) * 0.1).astype(np.float32)
    moved = pusher.push_delta("w", wire="auto")
    assert moved <= 0.30 * big * 4
    tiny = 16
    gt.set("t", np.zeros(tiny, np.float32).tobytes(), host="up")
    lt = LocalTier("h", gt)
    lt.pull("t")
    lt.snapshot_base("t")
    lt.replica("t").buf.view(np.float32)[:] = 3.0
    assert lt.push_delta("t", wire="auto") == tiny * 4     # exact path
    np.testing.assert_array_equal(_global(gt, "t"), 3.0)


def test_policy_backoff_switches_pushes_to_exact():
    """End-to-end adaptivity: deltas so sparse the per-row scales carry no
    information flip the key's policy after `damping` pushes, and auto
    pushes move to the exact wire."""
    n = INT8_WIRE_MIN_BYTES // 4 * 4
    gt, pusher, _ = _setup(n)
    pol = pusher.wire_policy("w")
    view = pusher.replica("w").buf.view(np.float32)
    for _ in range(pol.damping):
        view[0] += 5.0                          # a single spot write
        assert pusher.push_delta("w", wire="auto") <= 0.3 * n * 4
    assert pol.wire == "exact"
    view[:] += 1.0
    assert pusher.push_delta("w", wire="auto") == n * 4    # exact frame now
    np.testing.assert_allclose(_global(gt)[1:], 1.0, atol=1e-4)


# -- pull-direction kernel entry points (ref + interpret parity) ---------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", [64, 128, 1000])
def test_encode_pull_apply_pull_roundtrip(backend, n):
    rng = _rng(n)
    new = rng.normal(size=n).astype(np.float32)
    base = rng.normal(size=n).astype(np.float32)
    q, s, numel = encode_pull(new, base, backend=backend)
    assert numel == n
    deq = np.asarray(dequantize(q, s, numel))
    bound = np.abs(new - base).max() / 254.0 + 1e-6
    assert np.abs(deq - (new - base)).max() <= bound
    val = rng.normal(size=n).astype(np.float32)
    got = np.asarray(apply_pull(val, q, s, backend=backend))
    np.testing.assert_allclose(got, val + deq, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("wire", ("exact", "int8"))
def test_tier_delta_pull_parity(backend, wire):
    """A warm-replica refresh lands the same value whichever backend runs
    the codec, and the exact wire is bit-exact with the global value."""
    n = INT8_WIRE_MIN_BYTES // 4 * 2
    gt, pusher, puller = _setup(n, seed=13)
    view = pusher.replica("w").buf.view(np.float32)
    view[:] += (_rng(13).normal(size=n) * 0.05).astype(np.float32)
    pusher.push_delta("w", wire="exact", backend=backend)
    moved = puller.pull("w", wire=wire, backend=backend)
    assert moved > 0
    got = puller.replica("w").buf.view(np.float32)
    want = _global(gt)
    if wire == "exact":
        np.testing.assert_array_equal(got, want)
    else:
        assert np.abs(got - want).max() <= 0.05 * 6 / 254.0 + 1e-6


# -- frame plumbing ------------------------------------------------------------


def test_wire_frame_nbytes_and_decode():
    delta = np.arange(8, dtype=np.float32)
    exact = get_codec("exact").encode_delta(delta)
    assert exact.nbytes == 32
    np.testing.assert_array_equal(exact.decode(), delta)
    int8 = get_codec("int8").encode_delta(delta)
    assert int8.nbytes == int8.payload.nbytes + int8.scales.nbytes
    assert int8.numel == 8
    assert np.abs(int8.decode() - delta).max() <= delta.max() / 254.0 + 1e-6


def test_exact_frame_push_matches_legacy_inplace():
    """The exact f32 frame path lands bit-identical results to the old
    in-place add (same math, now recordable/broadcastable)."""
    n = 256
    rng = _rng(17)
    init = rng.normal(size=n).astype(np.float32)
    upd = rng.normal(size=n).astype(np.float32)

    # _setup's puller declares interest, so pusher1 takes the frame path
    gt1, pusher1, _ = _setup(n, init=init.copy())
    pusher1.replica("w").buf.view(np.float32)[:] += upd
    pusher1.push_delta("w", wire="exact")

    gt2 = GlobalTier()
    gt2.set("w", init.tobytes(), host="up")
    lt2 = LocalTier("h", gt2)
    lt2.pull("w")
    lt2.snapshot_base("w")
    local = lt2.replica("w").buf.view(np.float32)
    local[:] += upd
    base = lt2.replica("w").base.view(np.float32)
    gt2.add_inplace("w", local, base, host="h")
    got1, got2 = _global(gt1), _global(gt2)
    np.testing.assert_allclose(got1, init + upd, atol=1e-6)
    np.testing.assert_allclose(got1, got2, atol=1e-6)
