"""Telemetry plane: compile-out, span trees, metrics, Perfetto export.

Covers the observability contract in ``docs/observability.md``:
  * compile-out — disarmed hook sites leave zero ring-buffer writes
  * span-tree correctness — a speculation twin and a retry-after-crash
    appear as sibling spans of one logical call (same fence, distinct
    epochs), with fault-point hits as instant spans
  * histogram percentile accuracy against numpy on the log-bucketed bins
  * Chrome/Perfetto trace_event schema of the exporter
  * the traced chaos smoke (``-k smoke`` in scripts/tier1.sh): seed-0
    storm with tracing armed under the sanitizer exports a non-empty,
    well-formed trace
  * sanitizer integration — collector drain under a stripe/key lock is
    reported, ring writes under the same lock are not
"""
import json
import threading
import time

import numpy as np
import pytest

from repro import faults, telemetry
from repro.core import FaasmRuntime, FunctionDef
from repro.state.ddo import VectorAsync
from repro.state.kv import GlobalTier
from repro.state.local import LocalTier
from repro.telemetry import clock, metrics, spans, trace

KEY = "w"


def _global(gt, key=KEY):
    return np.frombuffer(gt.get(key, host="check"), np.float32)


def _fabric(n_floats=256):
    gt = GlobalTier()
    gt.set(KEY, np.zeros(n_floats, np.float32).tobytes(), host="seed")
    t = LocalTier("push0", gt)
    t.pull(KEY)
    t.snapshot_base(KEY)
    return gt, t


def _spans_named(span_list, name):
    return [s for s in span_list if s.name == name]


# -- compile-out --------------------------------------------------------------

def test_disarmed_hooks_compile_out():
    """Disarmed, every hook slot is None and a full runtime + fabric
    workload performs zero ring-buffer writes."""
    from repro.core import runtime as runtime_mod
    from repro.state import kv as kv_mod
    from repro.state import local as local_mod

    assert not telemetry.enabled()
    for mod in (runtime_mod, kv_mod, local_mod, faults):
        assert mod._TEL is None

    gt, t = _fabric()
    t.replica(KEY).buf.view(np.float32)[0] += 1.0
    t.push_delta(KEY, wire="exact")
    gt.pull_wire(KEY, 0, host="other")

    rt = FaasmRuntime(n_hosts=1)
    try:
        rt.upload(FunctionDef("echo", lambda api: 0))
        assert rt.wait(rt.invoke("echo"), timeout=10) == 0
    finally:
        rt.shutdown()

    # arming *after* the workload finds a tracer that never saw a write
    tr = telemetry.enable()
    assert tr.writes == 0
    assert tr.spans() == []


def test_enable_disable_installs_hooks():
    from repro.core import runtime as runtime_mod
    from repro.state import kv as kv_mod
    from repro.state import local as local_mod

    t = telemetry.enable()
    assert telemetry.enable() is t               # idempotent
    for mod in (runtime_mod, kv_mod, local_mod, faults):
        assert mod._TEL is t
    telemetry.disable()
    for mod in (runtime_mod, kv_mod, local_mod, faults):
        assert mod._TEL is None


def test_ring_drop_oldest():
    tr = spans.Tracer()
    for i in range(spans._RING_CAPACITY + 100):
        tr.record("x", "call", float(i), float(i) + 0.5, idx=i)
    got = tr.take()
    assert tr.dropped == 100
    assert len(got) == spans._RING_CAPACITY
    # oldest 100 were dropped; survivors come back in t0 order
    assert got[0].tags["idx"] == 100
    assert [s.t0 for s in got] == sorted(s.t0 for s in got)


# -- the single clock ---------------------------------------------------------

def test_call_timing_single_clock():
    """Call.t_* all come from telemetry.clock; queue_wait/exec_wall are
    derived and sum to the settled latency."""
    rt = FaasmRuntime(n_hosts=1)
    try:
        rt.upload(FunctionDef("nap", lambda api: time.sleep(0.02) or 0))
        cid = rt.invoke("nap")
        assert rt.wait(cid, timeout=10) == 0
        c = rt.call(cid)
        assert c.queue_wait >= 0.0
        assert c.exec_wall >= 0.02
        assert abs(c.latency - (c.queue_wait + c.exec_wall)) < 1e-9
    finally:
        rt.shutdown()


# -- span trees ---------------------------------------------------------------

def test_call_lifecycle_spans():
    t = telemetry.enable()
    rt = FaasmRuntime(n_hosts=1)
    try:
        rt.upload(FunctionDef("echo", lambda api: 0))
        cid = rt.invoke("echo")
        assert rt.wait(cid, timeout=10) == 0
        rt.wait_all([rt.invoke("echo")], timeout=10)
        got = t.spans()
        for name in ("call.queue", "call.restore", "call.exec",
                     "call.reset", "call.settle"):
            assert _spans_named(got, name), name
        ex = _spans_named(got, "call.exec")
        assert any(s.call == cid for s in ex)
        s = next(s for s in ex if s.call == cid)
        assert s.fence == rt.call(cid).fence_id
        assert s.host is not None and s.t1 >= s.t0
        assert s.tags["status"] == "done" and s.tags["rc"] == 0
        settle = next(x for x in _spans_named(got, "call.settle")
                      if x.call == cid)
        assert settle.tags["queue_wait"] >= 0.0
        assert settle.tags["exec_wall"] > 0.0
    finally:
        rt.shutdown()
        telemetry.disable()


def test_speculation_twin_sibling_spans():
    """A straggler's speculative twin shares the primary's fence with a
    distinct epoch and call id — sibling spans of one logical call."""
    t = telemetry.enable()
    rt = FaasmRuntime(n_hosts=2, straggler_timeout=0.3)
    try:
        state = {"n": 0}

        def sometimes_slow(api):
            state["n"] += 1
            if state["n"] == 1:
                time.sleep(2.5)
            return 0

        rt.upload(FunctionDef("s", sometimes_slow))
        cid = rt.invoke("s")
        assert rt.wait(cid, timeout=30) == 0
        fence = rt.call(cid).fence_id
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            fam = [s for s in t.spans()
                   if s.fence == fence and s.name == "call.exec"]
            if len({s.epoch for s in fam}) >= 2:
                break
            time.sleep(0.1)
        assert len({s.epoch for s in fam}) >= 2, fam      # twin + primary
        assert len({s.call for s in fam}) >= 2, fam       # distinct attempts
    finally:
        rt.shutdown()
        telemetry.disable()


def test_retry_after_crash_sibling_spans():
    """A call requeued past a dead host re-runs under the same fence with
    a bumped epoch; both attempts' spans are visible."""
    t = telemetry.enable()
    rt = FaasmRuntime(n_hosts=2, capacity=1, backoff=0.001)
    try:
        release = threading.Event()

        def gated(api):
            release.wait(10.0)
            return 0

        rt.upload(FunctionDef("gated", gated))
        cid = rt.invoke("gated")
        deadline = time.monotonic() + 5.0
        victim = None
        while victim is None and time.monotonic() < deadline:
            victim = next((h for h in rt.alive_hosts()
                           if h._inflight > 0), None)
        assert victim is not None
        rt.fail_host(victim.id)
        release.set()
        assert rt.wait(cid, timeout=30) == 0
        got = t.spans()
        fence = rt.call(cid).fence_id
        fam = [s for s in got if s.fence == fence
               and s.name in ("call.queue", "call.exec")]
        assert len({s.epoch for s in fam}) >= 2, fam
        hosts = {s.host for s in fam if s.name == "call.exec"}
        assert victim.id in {s.host for s in fam} or len(hosts) >= 1
    finally:
        rt.shutdown()
        telemetry.disable()


def test_fault_hits_become_instant_spans():
    t = telemetry.enable()
    gt, tier = _fabric()
    sub = LocalTier("sub", gt)
    sub.pull(KEY)
    sub.subscribe(KEY)
    plan = faults.FaultPlan(0).add("wire-frame-drop", nth=1, times=1)
    with faults.armed(plan):
        tier.replica(KEY).buf.view(np.float32)[0] += 1.0
        tier.push_delta(KEY, wire="exact")
        gt.flush_broadcasts()            # the drop fires on the pump thread
    assert plan.fired("wire-frame-drop") == 1
    hits = _spans_named(t.spans(), "fault.wire-frame-drop")
    assert hits and hits[0].tags["action"] == "drop"
    assert hits[0].t0 == hits[0].t1                       # instant
    telemetry.disable()


# -- wire spans ---------------------------------------------------------------

def test_wire_span_tags():
    t = telemetry.enable()
    n = 64 * 1024                     # big enough for the int8 wire
    gt, tier = _fabric(n)
    sub = LocalTier("sub", gt)
    sub.pull(KEY)
    sub.subscribe(KEY)
    tier.replica(KEY).buf.view(np.float32)[:] += 1.0
    tier.push_delta(KEY, wire="int8")
    gt.flush_broadcasts()                # bcast spans record on the pump
    puller = LocalTier("puller", gt)
    puller.pull(KEY)
    got = t.spans()

    push = _spans_named(got, "wire.push")
    assert push, got
    p = push[-1]
    assert p.tags["key"] == KEY and p.tags["wire"] == "int8"
    assert p.tags["nbytes"] > 0 and p.tags["encode_ns"] > 0
    assert p.tags["version"] == p.tags["prev_version"] + 1

    bcast = _spans_named(got, "wire.bcast")
    assert bcast and bcast[-1].tags["applied"] is True
    assert bcast[-1].tags["subscriber"] == "sub"

    # the cold pull moved the full value
    full = _spans_named(got, "wire.full_pull")
    assert full and full[-1].tags["puller"] == "puller"
    assert full[-1].tags["nbytes"] > 0
    telemetry.disable()


def test_fence_reject_instant():
    t = telemetry.enable()
    gt = GlobalTier()
    assert gt.fence_admit(KEY, ("c1", 1, 1)) is True
    gt.fence_supersede("c1", 2)
    assert gt.fence_admit(KEY, ("c1", 2, 2)) is False     # dead epoch
    assert gt.fence_rejections == 1
    rej = _spans_named(t.spans(), "fence.reject")
    assert rej and rej[0].fence == "c1" and rej[0].epoch == 2
    assert rej[0].tags["key"] == KEY and rej[0].tags["seq"] == 2
    telemetry.disable()


# -- metrics registry ---------------------------------------------------------

def test_metric_name_validation():
    reg = metrics.Registry()
    with pytest.raises(ValueError):
        reg.counter("bad_name")
    with pytest.raises(ValueError):
        reg.gauge("faasm_thing")                          # no unit suffix
    with pytest.raises(ValueError):
        reg.histogram("faasm_Upper_case_ms")
    c = reg.counter("faasm_test_things_total")
    assert reg.counter("faasm_test_things_total") is c    # get-or-create
    with pytest.raises(TypeError):
        reg.gauge("faasm_test_things_total")              # kind mismatch
    with pytest.raises(ValueError):
        c.inc(-1)


def test_histogram_percentiles_vs_numpy(rng):
    sample = rng.lognormal(mean=1.0, sigma=1.2, size=20_000)
    h = metrics.Histogram("faasm_test_lat_ms")
    for v in sample:
        h.observe(v)
    assert h.count == sample.size
    assert abs(h.sum - float(sample.sum())) < 1e-6 * sample.size
    for p in (0.50, 0.90, 0.99, 0.999):
        want = float(np.percentile(sample, 100 * p))
        got = h.percentile(p)
        # half-bucket geometric error is ~2.2%; allow headroom for the
        # rank-interpolation difference on the tail
        assert abs(got - want) / want < 0.06, (p, got, want)
    assert h.min == pytest.approx(float(sample.min()))
    assert h.max == pytest.approx(float(sample.max()))


def test_histogram_zero_bucket():
    h = metrics.Histogram("faasm_test_zero_ms")
    for v in (0.0, -1.0, 0.0, 5.0):
        h.observe(v)
    assert h.percentile(0.5) == 0.0
    assert h.percentile(0.999) <= 5.0


def test_registry_render_text_and_collector():
    reg = metrics.Registry()
    reg.counter("faasm_test_events_total", "things that happened").inc(3)
    reg.histogram("faasm_test_lat_ms").observe(2.0)
    pulls = {"n": 0}
    reg.register_collector(
        lambda r: r.gauge("faasm_test_live_count").set(
            pulls.__setitem__("n", pulls["n"] + 1) or pulls["n"]))
    text = reg.render_text()
    assert pulls["n"] == 1                                 # collector ran
    assert "# TYPE faasm_test_events_total counter" in text
    assert "faasm_test_events_total 3" in text
    assert 'faasm_test_lat_ms{quantile="0.99"}' in text
    assert "faasm_test_live_count 1" in text
    snap = reg.snapshot()
    assert snap["faasm_test_events_total"] == 3.0
    assert snap["faasm_test_lat_ms_count"] == 1.0


def test_runtime_metrics_single_source_of_truth():
    rt = FaasmRuntime(n_hosts=1)
    try:
        rt.upload(FunctionDef("echo", lambda api: 0))
        for _ in range(3):
            assert rt.wait(rt.invoke("echo"), timeout=10) == 0
        stats = rt.cold_start_stats()
        snap = rt.metrics.snapshot()
        assert snap["faasm_host_warm_hits_total"] == stats["warm_hits"]
        assert snap["faasm_host_resets_total"] == stats["resets"] >= 3
        assert snap["faasm_runtime_calls_done_total"] >= 3
        text = rt.metrics_text()
        assert "faasm_tier_net_bytes" in text
        assert "faasm_host_init_ms" in text
    finally:
        rt.shutdown()


def test_metrics_http_endpoint():
    import urllib.request
    reg = metrics.Registry()
    reg.counter("faasm_test_hits_total").inc()
    srv = metrics.serve_http(reg, 0)                      # ephemeral port
    try:
        port = srv.server_address[1]
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=5).read().decode()
        assert "faasm_test_hits_total 1" in body
    finally:
        srv.shutdown()


# -- Chrome/Perfetto export ---------------------------------------------------

def test_chrome_export_schema(tmp_path):
    t = telemetry.enable()
    n = 64 * 1024
    gt, tier = _fabric(n)
    sub = LocalTier("sub", gt)
    sub.pull(KEY)
    sub.subscribe(KEY)
    tier.replica(KEY).buf.view(np.float32)[:] += 1.0
    tier.push_delta(KEY, wire="int8")
    gt.flush_broadcasts()                # bcast flow-finish records on the pump

    path = tmp_path / "trace.json"
    n_events = trace.export_chrome(str(path))
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert len(events) == n_events > 0
    assert doc["displayTimeUnit"] == "ms"
    phases = {e["ph"] for e in events}
    assert "X" in phases and "M" in phases
    for e in events:
        assert e["pid"] == 1 and "tid" in e
        if e["ph"] == "M":
            assert e["name"] == "thread_name" and e["args"]["name"]
            continue
        assert isinstance(e["ts"], float)
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        if e["ph"] == "i":
            assert e["s"] == "t"
    # wire flow: every finish has a matching start with the same id
    starts = {e["id"] for e in events if e["ph"] == "s"}
    finishes = [e for e in events if e["ph"] == "f"]
    assert starts                                          # push emitted one
    for f in finishes:
        assert f["id"] in starts and f["bp"] == "e"
    telemetry.disable()


# -- sanitizer integration ----------------------------------------------------

@pytest.mark.sanitize
def test_drain_under_key_lock_reported():
    """Ring writes under a fabric lock are fine; a collector drain there
    is a telemetry-under-lock report."""
    from repro.analysis import sanitizer

    t = telemetry.enable()
    gt = GlobalTier()                    # built with sanitizer armed
    gt.set(KEY, np.zeros(8, np.float32).tobytes(), host="seed")
    lock = gt.lock(KEY)
    lock.acquire_write()
    try:
        t.instant("probe.write", "wire", key=KEY)          # allowed
        t.drain()                                          # not allowed
    finally:
        lock.release_write()
    reports = sanitizer.take_reports()
    assert [r.check for r in reports] == ["telemetry-under-lock"], reports
    # outside the lock the same drain is clean
    t.drain()
    assert sanitizer.take_reports() == []
    telemetry.disable()


# -- traced chaos smoke (runs in scripts/tier1.sh via -k smoke) ---------------

@pytest.mark.sanitize
def test_traced_chaos_smoke(tmp_path):
    """Seed-0 runtime chaos with tracing armed under the sanitizer: the
    run converges exactly-once AND exports a non-empty, well-formed
    Perfetto trace with restore/exec/wire spans."""
    t = telemetry.enable()
    rt = FaasmRuntime(n_hosts=2, capacity=2, backoff=0.001)
    try:
        VectorAsync.create(rt.global_tier, KEY, np.zeros(8, np.float32))

        def inc(api):
            v = VectorAsync(api, KEY)
            v.pull(track_delta=True)
            v.add(0, 1.0)
            v.push_delta(wire="exact")
            return 0

        rt.upload(FunctionDef("inc", inc))
        with faults.armed(faults.FaultPlan.random(0)):
            cids = rt.invoke_many("inc", [b""] * 8, state_hint=[KEY])
            assert rt.wait_all(cids, timeout=60) == [0] * 8
        assert _global(rt.global_tier)[0] == 8.0          # exactly once

        names = {s.name for s in t.spans()}
        assert {"call.restore", "call.exec", "wire.push"} <= names, names
        path = tmp_path / "chaos_trace.json"
        n_events = trace.export_chrome(str(path))
        doc = json.loads(path.read_text())
        assert n_events > 0 and len(doc["traceEvents"]) == n_events
        assert all("ph" in e and "pid" in e for e in doc["traceEvents"])
    finally:
        rt.shutdown()
        telemetry.disable()
