"""ShardingRules unit tests: spec validity, divisibility guards, coverage."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_shape, smoke_config
from repro.models import build_model, ExecConfig


class FakeMesh:
    """Axis-name/shape stand-in (rules only read names + sizes)."""

    def __init__(self, shape_map):
        self.axis_names = tuple(shape_map)
        self.shape = dict(shape_map)
        self.size = int(np.prod(list(shape_map.values())))


def _rules(cfg, shape_map=None):
    from repro.distributed.sharding import ShardingRules
    return ShardingRules(FakeMesh(shape_map or {"data": 16, "model": 16}), cfg)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "starcoder2-7b",
                                  "kimi-k2-1t-a32b", "mamba2-130m",
                                  "zamba2-1.2b", "whisper-tiny",
                                  "internvl2-2b"])
def test_param_specs_cover_every_leaf(arch):
    cfg = get_config(arch)
    model = build_model(cfg, ExecConfig(backend="xla"))
    shapes = model.init_shapes()
    rules = _rules(cfg)
    specs = rules.params_specs(shapes)
    for (pa, leaf), (pb, spec) in zip(
            jax.tree_util.tree_flatten_with_path(shapes)[0],
            jax.tree_util.tree_flatten_with_path(specs)[0]):
        assert isinstance(spec, P), (jax.tree_util.keystr(pa), spec)
        assert len(spec) <= leaf.ndim, (jax.tree_util.keystr(pa), spec, leaf.shape)
        # every sharded dim must divide the axis product
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([rules.mesh.shape[a] for a in axes]))
            assert dim % total == 0, (jax.tree_util.keystr(pa), spec, leaf.shape)


def test_tp_rules_megatron_pattern():
    cfg = get_config("granite-3-8b")
    model = build_model(cfg, ExecConfig(backend="xla"))
    shapes = model.init_shapes()
    specs = _rules(cfg).params_specs(shapes)
    lyr = specs["layers"]
    assert lyr["attn"]["wq"] == P(None, "data", "model")      # column parallel
    assert lyr["attn"]["wo"] == P(None, "model", "data")      # row parallel
    assert lyr["mlp"]["w_gate"] == P(None, "data", "model")
    assert lyr["mlp"]["w_down"] == P(None, "model", "data")
    # granite vocab (49155) doesn't divide 16 -> guard degrades to fsdp-only
    assert specs["embed"] == P(None, "data")
    cfg_q = get_config("qwen3-4b")                            # 151936 % 16 == 0
    model_q = build_model(cfg_q, ExecConfig(backend="xla"))
    specs_q = _rules(cfg_q).params_specs(model_q.init_shapes())
    assert specs_q["embed"] == P("model", "data")             # vocab parallel


def test_moe_expert_parallel_rules():
    cfg = get_config("kimi-k2-1t-a32b")
    model = build_model(cfg, ExecConfig(backend="xla"))
    shapes = model.init_shapes()
    specs = _rules(cfg).params_specs(shapes)
    moe = specs["layers"]["moe"]
    assert moe["w_gate"] == P(None, "model", "data", None)    # experts x fsdp
    assert moe["w_down"] == P(None, "model", None, "data")


def test_divisibility_guard_degrades_not_fails():
    # mamba2-130m: 24 SSD heads don't divide model=16 -> A_log replicated
    cfg = get_config("mamba2-130m")
    model = build_model(cfg, ExecConfig(backend="xla"))
    shapes = model.init_shapes()
    specs = _rules(cfg).params_specs(shapes)
    assert specs["layers"]["mamba"]["A_log"] in (P(None), P(None, None))
    assert specs["layers"]["mamba"]["w_in"] == P(None, "data", None)


def test_cache_specs_head_vs_sequence_sharding():
    # granite kv=8 < model=16 -> cache shards sequence on model
    cfg = get_config("granite-3-8b")
    model = build_model(cfg, ExecConfig(backend="xla"))
    rules = _rules(cfg)
    shape = get_shape("decode_32k")
    cache = model.cache_specs(shape.global_batch, shape.seq_len)
    specs = rules.cache_specs(cache)
    assert specs["k"][3] is None or specs["k"][3] != "model"
    assert specs["k"][2] == "model"                # sequence-parallel cache
    # qwen1.5 kv=16 == model -> heads shard
    cfg2 = get_config("qwen1.5-0.5b")
    model2 = build_model(cfg2, ExecConfig(backend="xla"))
    cache2 = model2.cache_specs(shape.global_batch, shape.seq_len)
    specs2 = _rules(cfg2).cache_specs(cache2)
    assert specs2["k"][3] == "model"


def test_long_context_batch1_shards_sequence_everywhere():
    cfg = get_config("zamba2-1.2b")
    model = build_model(cfg, ExecConfig(backend="xla"))
    rules = _rules(cfg)
    shape = get_shape("long_500k")
    cache = model.cache_specs(1, shape.seq_len)
    specs = rules.cache_specs(cache)
    k_spec = specs["k"]
    assert k_spec[1] is None                       # batch 1: unsharded
    # zamba kv=32 divides model -> heads shard; 524288 seq shards over data
    assert k_spec[3] == "model"
    assert k_spec[2] in ("data", ("data",))


def test_opt_state_inherits_param_specs():
    from repro.optim import SGD
    cfg = get_config("qwen3-4b")
    model = build_model(cfg, ExecConfig(backend="xla"))
    shapes = model.init_shapes()
    rules = _rules(cfg)
    opt = SGD(lr=0.1, momentum=0.9)
    oshapes = jax.eval_shape(opt.init, shapes)
    ospecs = rules.opt_specs(oshapes, shapes)
    pspecs = rules.params_specs(shapes)
    assert ospecs.momentum["layers"]["attn"]["wq"] == \
        pspecs["layers"]["attn"]["wq"]
    assert ospecs.step == P()
