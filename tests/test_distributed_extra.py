"""Pipeline-parallel and elastic-rescale tests (subprocess: own device count)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PIPELINE_SCRIPT = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.distributed.pipeline import (make_pipeline_fn, pipeline_stats,
                                            split_stages)
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("pipe",))
    L, d, n_micro, mb = 8, 16, 4, 2
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (L, d, d)) * 0.3
    b = jax.random.normal(jax.random.fold_in(key, 1), (L, d)) * 0.1
    params = {"w": W, "b": b}

    def block_fn(h, lp):
        return jnp.tanh(h @ lp["w"] + lp["b"])

    # reference: plain scan over all layers
    x = jax.random.normal(jax.random.fold_in(key, 2), (n_micro, mb, d))
    def ref_one(h):
        out, _ = jax.lax.scan(lambda c, lp: (block_fn(c, lp), None), h, params)
        return out
    ref = jax.vmap(ref_one)(x)

    staged = split_stages(params, 4)
    with mesh:
        piped = make_pipeline_fn(block_fn, mesh, n_micro)
        got = jax.jit(piped)(staged, x)
    err = float(jnp.abs(got - ref).max())
    stats = pipeline_stats(4, n_micro)
    print(json.dumps({"err": err, "bubble": stats["bubble_fraction"]}))
""")

ELASTIC_SCRIPT = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, numpy as np
    from repro.configs import smoke_config
    from repro.models import build_model, ExecConfig
    from repro.distributed.elastic import reshard_params, to_host
    from repro.launch.mesh import make_mesh

    cfg = smoke_config("qwen1.5-0.5b")
    model = build_model(cfg, ExecConfig(backend="xla"))
    params = model.init(jax.random.PRNGKey(0))
    host = to_host(params)

    # "scale" from a 2x4 mesh to a 4x2 mesh from the same host checkpoint
    for shape in [(2, 4), (4, 2)]:
        mesh = make_mesh(shape, ("data", "model"))
        dev = reshard_params(host, cfg, mesh)
        back = to_host(dev)
        for a, b in zip(jax.tree.leaves(host), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print(json.dumps({"ok": True}))
""")


def _run(script):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=540, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_pipeline_parallel_matches_reference():
    rec = _run(PIPELINE_SCRIPT)
    assert rec["err"] < 1e-5
    assert abs(rec["bubble"] - 3 / 7) < 1e-9


def test_elastic_reshard_roundtrip():
    rec = _run(ELASTIC_SCRIPT)
    assert rec["ok"]
