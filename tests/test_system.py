"""End-to-end behaviour tests: the paper's workloads running through the
FAASM runtime (training via chained Faaslets + shared state; inference
serving with Proto-Faaslet warm starts)."""
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import FaasmRuntime, FunctionDef, chain, await_all
from repro.state.ddo import SparseMatrixReadOnly, VectorAsync
from repro.data import make_sparse_dataset, hinge_loss, accuracy


def test_hogwild_sgd_through_runtime_converges():
    """Listing-1 reproduction: chained weight_update Faaslets training a
    linear classifier on planted sparse data, shared weights via VectorAsync.
    The paper's claim: parallel HOGWILD updates through shared memory still
    converge."""
    X, y, w_true = make_sparse_dataset(64, 256, density=0.15, seed=0)
    rt = FaasmRuntime(n_hosts=2, capacity=4)
    try:
        SparseMatrixReadOnly.create(rt.global_tier, "train_x", X)
        rt.global_tier.set("labels", y.astype(np.float32).tobytes(), host="up")
        VectorAsync.create(rt.global_tier, "weights", np.zeros(64, np.float32))

        def weight_update(api):
            lo, hi = np.frombuffer(api.read_call_input(), np.int32)
            mat = SparseMatrixReadOnly(api, "train_x")
            labels = np.frombuffer(bytes(api.get_state("labels",
                                                       writable=False)),
                                   np.float32)
            w = VectorAsync(api, "weights")
            w.pull(track_delta=True)
            lr = 0.05
            for c, rows, vals in mat.columns(int(lo), int(hi)):
                margin = float(labels[c] * (w.values[rows] * vals).sum())
                if margin < 1.0:                     # hinge subgradient
                    w.add(rows, lr * labels[c] * vals)
            w.push_delta()
            return 0

        def sgd_main(api):
            n_workers, n_epochs, n_cols = 4, 4, 256
            for _ in range(n_epochs):
                args = []
                per = n_cols // n_workers
                for wi in range(n_workers):
                    args.append(np.asarray([wi * per, (wi + 1) * per],
                                           np.int32).tobytes())
                cids = chain(api, "weight_update", args)
                rcs = await_all(api, cids)
                assert all(r == 0 for r in rcs)
            return 0

        rt.upload(FunctionDef("weight_update", weight_update))
        rt.upload(FunctionDef("sgd_main", sgd_main))
        cid = rt.invoke("sgd_main")
        assert rt.wait(cid, timeout=120) == 0, rt.call(cid).error
        w_final = np.frombuffer(rt.global_tier.get("weights", host="t"),
                                np.float32)
        assert hinge_loss(w_final, X, y) < hinge_loss(np.zeros(64, np.float32),
                                                      X, y) * 0.5
        assert accuracy(w_final, X, y) > 0.8
    finally:
        rt.shutdown()


def test_inference_serving_with_proto_faaslets():
    """Inference Faaslets share model weights through the local tier and cold
    starts restore from Proto-Faaslets (µs-scale) instead of re-initialising."""
    from repro.configs import smoke_config
    from repro.models import build_model, ExecConfig

    cfg = smoke_config("qwen1.5-0.5b")
    model = build_model(cfg, ExecConfig(backend="xla", loss_chunk=0))
    params = model.init(jax.random.PRNGKey(0))
    flat, treedef = jax.tree_util.tree_flatten(params)
    host_leaves = [np.asarray(x) for x in flat]

    rt = FaasmRuntime(n_hosts=1, capacity=4)
    try:
        def _build_fwd():
            fwd = jax.jit(lambda p, t: model.logits(p, t))
            p = jax.tree_util.tree_unflatten(
                treedef, [jnp.asarray(x) for x in host_leaves])
            fwd(p, jnp.zeros((1, 8), jnp.int32)).block_until_ready()
            return fwd

        def init(api):
            # heavyweight init: jit + weight layout; the executable lands in
            # the ExecutableCache, the weights in the (picklable) snapshot
            api.runtime.exec_cache.get_or_build(("infer", "fwd"), _build_fwd)
            return {"params": host_leaves}            # numpy: picklable

        def infer(api):
            state = api.host.user_state(api.faaslet)
            fwd, hit, _ = api.runtime.exec_cache.get_or_build(
                ("infer", "fwd"), _build_fwd)
            p = jax.tree_util.tree_unflatten(
                treedef, [jnp.asarray(x) for x in state["params"]])
            tokens = np.frombuffer(api.read_call_input(), np.int32).reshape(1, -1)
            logits = fwd(p, jnp.asarray(tokens))
            api.write_call_output(
                np.asarray(jnp.argmax(logits[0, -1])).tobytes())
            return 0

        rt.upload(FunctionDef("infer", infer, init_fn=init))
        tokens = np.arange(8, dtype=np.int32)
        lat = []
        for _ in range(5):
            t0 = time.perf_counter()
            cid = rt.invoke("infer", tokens.tobytes())
            assert rt.wait(cid, timeout=60) == 0, rt.call(cid).error
            lat.append(time.perf_counter() - t0)
        stats = rt.cold_start_stats()
        assert stats["warm_hits"] >= 4
        # warm path much faster than the first (compile-paying) call
        assert min(lat[1:]) < lat[0]
    finally:
        rt.shutdown()


def test_train_lm_loss_decreases():
    """A ~tiny LM trains through the real train-step path and the loss drops."""
    from repro.configs import smoke_config, smoke_shape
    from repro.models import build_model, ExecConfig
    from repro.optim import SGD
    from repro.data import make_batch, PipelineConfig

    cfg = smoke_config("qwen1.5-0.5b")
    shape = smoke_shape("train")
    model = build_model(cfg, ExecConfig(backend="xla", loss_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    opt = SGD(lr=0.3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    pc = PipelineConfig(seed=0)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, shape, pc, 0).items()}   # fixed batch
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses[::10]
