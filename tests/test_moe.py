"""MoE layer tests: router invariants, dispatch-implementation equivalence."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models.execution import ExecConfig
from repro.models.moe import moe_apply, moe_init, router_topk


def _setup(capacity_factor=8.0):
    cfg = smoke_config("deepseek-moe-16b").with_overrides(
        capacity_factor=capacity_factor)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


def test_router_topk_invariants():
    cfg, p, x = _setup()
    gates, idx, aux = router_topk(p, cfg, x.reshape(-1, cfg.d_model))
    T = 32
    assert gates.shape == (T, cfg.experts_per_token)
    np.testing.assert_allclose(gates.sum(-1), 1.0, atol=1e-5)   # renormalised
    assert int(idx.min()) >= 0 and int(idx.max()) < cfg.n_experts
    # top-k indices are distinct per token
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == cfg.experts_per_token
    assert float(aux) >= 0.0


def test_einsum_vs_sorted_dispatch_equivalent():
    """With capacity high enough to avoid drops, the GShard einsum dispatch
    and the dropless sorted-gmm dispatch are the same function."""
    cfg, p, x = _setup(capacity_factor=8.0)
    ec_e = ExecConfig(backend="xla", moe_impl="einsum", moe_group_size=32)
    ec_s = ExecConfig(backend="xla", moe_impl="sorted")
    y_e, aux_e = moe_apply(p, cfg, ec_e, x)
    y_s, aux_s = moe_apply(p, cfg, ec_s, x)
    np.testing.assert_allclose(np.asarray(y_e), np.asarray(y_s),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(float(aux_e), float(aux_s), rtol=1e-5)


def test_einsum_low_capacity_drops_tokens():
    """With a tiny capacity factor some tokens are dropped (zero output),
    never corrupted."""
    cfg, p, x = _setup(capacity_factor=8.0)
    ec_lo = ExecConfig(backend="xla", moe_impl="einsum", moe_group_size=32)
    y_hi, _ = moe_apply(p, cfg, ec_lo, x)
    cfg_lo = cfg.with_overrides(capacity_factor=0.25)
    y_lo, _ = moe_apply(p, cfg_lo, ec_lo, x)
    # dropped tokens shrink toward the shared-expert-only output
    assert float(jnp.abs(y_lo).mean()) <= float(jnp.abs(y_hi).mean()) + 1e-6


def test_moe_grads_flow_to_all_parts():
    cfg, p, x = _setup()
    ec = ExecConfig(backend="xla", moe_impl="einsum", moe_group_size=32)

    def loss(p):
        y, aux = moe_apply(p, cfg, ec, x)
        return (y ** 2).mean() + aux

    g = jax.grad(loss)(p)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        name = jax.tree_util.keystr(path)
        assert bool(jnp.isfinite(leaf).all()), name
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w_gate"]).sum()) > 0
    assert float(jnp.abs(g["shared"]["w_up"]).sum()) > 0
