"""Chaos suite: seeded fault schedules through the runtime and the state
fabric must converge to the fault-free final state with exactly-once state
effects (attempt fencing), and the fault layer itself must compile out to a
single pointer compare when disarmed.

Structure:
  * compile-out / plan lifecycle — the zero-overhead contract
  * one scenario per fault point — each converges and is exactly-once
  * attempt-fence semantics at the tier level (supersede / seal / dup-seq)
  * monitor interleavings — queued calls, placement races, zombie attempts
  * satellites — heartbeat beats from checkpoints, failed-call delta
    discard, degraded serving, application-level scatter/gather retry
  * the seeded chaos matrix — ``FaultPlan.random`` storms; seeds 0-2 are
    the tier-1 smoke (``-k smoke``), the wider sweep is ``slow``-marked
"""
import threading
import time

import numpy as np
import pytest

from repro import cancellation, faults
from repro import overload as oload
from repro.core import BatchTimeout, FaasmRuntime, FunctionDef
from repro.core.chain import scatter_gather
from repro.state.ddo import VectorAsync
from repro.state.kv import GlobalTier
from repro.state.local import INT8_WIRE_MIN_BYTES, LocalTier

KEY = "w"


def _global(gt, key=KEY):
    return np.frombuffer(gt.get(key, host="check"), np.float32)


def _fabric(n_floats=256, n_pushers=1, subscriber=False):
    """GlobalTier + warm pusher tiers (delta-base armed) [+ a subscriber]."""
    gt = GlobalTier()
    gt.set(KEY, np.zeros(n_floats, np.float32).tobytes(), host="seed")
    pushers = []
    for i in range(n_pushers):
        t = LocalTier(f"push{i}", gt)
        t.pull(KEY)
        t.snapshot_base(KEY)
        pushers.append(t)
    sub = None
    if subscriber:
        sub = LocalTier("sub", gt)
        sub.pull(KEY)
        sub.subscribe(KEY)
    return gt, pushers, sub


def _view(tier, key=KEY):
    return tier.replica(key).buf.view(np.float32)


# -- compile-out: the disarmed fast path is one pointer compare ---------------

def test_disarmed_points_compile_out():
    assert faults.active() is None
    # disarmed: every site returns False immediately — no validation, no
    # counting, no lock; even an unregistered name is not inspected
    assert faults.point("wire-frame-drop") is False
    assert faults.point("not-a-registered-point") is False
    plan = faults.FaultPlan(seed=7).add("wire-frame-drop")
    assert plan.hits("wire-frame-drop") == 0 and plan.fired() == 0
    # armed: the same site counts against the plan and fires
    with faults.armed(plan):
        assert faults.active() is plan
        assert faults.point("wire-frame-drop", key=KEY) is True   # rule fires
        assert faults.point("wire-frame-drop", key=KEY) is False  # rule spent
        with pytest.raises(ValueError):
            faults.point("not-a-registered-point")     # armed path validates
    assert faults.active() is None
    assert plan.hits("wire-frame-drop") == 2
    assert plan.fired("wire-frame-drop") == 1
    assert plan.log == [("wire-frame-drop", None, KEY, None)]


def test_plan_rejects_unknown_points_and_bad_triggers():
    with pytest.raises(ValueError):
        faults.FaultPlan().add("no-such-point")
    with pytest.raises(ValueError):
        faults.FaultPlan().add("wire-frame-drop", nth=0)
    # the randomized schedule is reproducible and well-formed
    a, b = faults.FaultPlan.random(3), faults.FaultPlan.random(3)
    assert [(r.point, r.nth, r.times) for r in a.rules] == \
        [(r.point, r.nth, r.times) for r in b.rules]
    assert all(r.point in faults.FAULT_POINTS for r in a.rules)


# -- host crashes: re-execution is exactly-once -------------------------------

def _inc_fn(slot=0):
    def inc(api):
        v = VectorAsync(api, KEY)
        v.pull(track_delta=True)
        v.add(slot, 1.0)
        v.push_delta(wire="exact")
        api.write_call_output(b"ok")
        return 0
    return inc


@pytest.mark.sanitize
def test_host_crash_pre_push_requeues_exactly_once():
    """Fail-stop before any global effect: the re-execution's push is the
    only one admitted."""
    rt = FaasmRuntime(n_hosts=2, capacity=1)
    try:
        VectorAsync.create(rt.global_tier, KEY, np.zeros(8, np.float32))
        rt.upload(FunctionDef("inc", _inc_fn()))
        with faults.armed(faults.FaultPlan(seed=1).add(
                "host-crash-pre-push", key=KEY)) as plan:
            cid = rt.invoke("inc")
            assert rt.wait(cid, timeout=30) == 0
            assert plan.fired("host-crash-pre-push") == 1
        assert rt.call(cid).attempts == 2
        assert rt.output(cid) == b"ok"
        assert _global(rt.global_tier)[0] == 1.0
        assert len(rt.alive_hosts()) == 1
    finally:
        rt.shutdown()


@pytest.mark.sanitize
def test_host_crash_post_push_duplicate_is_fenced():
    """Fail-stop AFTER the delta landed globally: the re-execution re-pushes
    the same (call, seq) pair and the fence rejects the duplicate — the
    increment lands exactly once, same as the fault-free run."""
    rt = FaasmRuntime(n_hosts=2, capacity=1)
    try:
        VectorAsync.create(rt.global_tier, KEY, np.zeros(8, np.float32))
        rt.upload(FunctionDef("inc", _inc_fn()))
        with faults.armed(faults.FaultPlan(seed=2).add(
                "host-crash-post-push", key=KEY)) as plan:
            cid = rt.invoke("inc")
            assert rt.wait(cid, timeout=30) == 0
            assert plan.fired("host-crash-post-push") == 1
        assert rt.call(cid).attempts == 2
        assert _global(rt.global_tier)[0] == 1.0     # NOT 2.0: deduplicated
    finally:
        rt.shutdown()


@pytest.mark.sanitize
def test_crash_storm_retries_exhausted_settles_failed():
    """A call crashing on every attempt burns its retry budget and settles
    as failed instead of hanging a waiter (bounded recovery)."""
    rt = FaasmRuntime(n_hosts=4, capacity=1, max_retries=2, backoff=0.001)
    try:
        VectorAsync.create(rt.global_tier, KEY, np.zeros(8, np.float32))
        rt.upload(FunctionDef("inc", _inc_fn()))
        with faults.armed(faults.FaultPlan(seed=3).add(
                "host-crash-pre-push", key=KEY, times=10)):
            cid = rt.invoke("inc")
            rc = rt.wait(cid, timeout=30)
        call = rt.call(cid)
        assert rc != 0 and call.status == "failed"
        assert call.attempts == rt.max_attempts == 3
        assert _global(rt.global_tier)[0] == 0.0      # no partial effect
    finally:
        rt.shutdown()


# -- wire faults: drop / delay / subscriber-raise / codec-error ---------------

def test_wire_frame_drop_repaired_by_pull():
    gt, (p,), sub = _fabric(64, subscriber=True)
    with faults.armed(faults.FaultPlan(seed=4).add(
            "wire-frame-drop", host="sub")) as plan:
        _view(p)[:] += 1.0
        p.push_delta(KEY, wire="exact")              # frame to sub is lost
        gt.flush_broadcasts()                        # drain the async fan-out
        assert plan.fired("wire-frame-drop") == 1
        assert _view(sub)[0] == 0.0                  # sub missed it
        _view(p)[:] += 1.0
        p.push_delta(KEY, wire="exact")              # arrives, but out of
        gt.flush_broadcasts()
        assert _view(sub)[0] == 0.0                  # order: skipped too
    np.testing.assert_array_equal(_global(gt), np.full(64, 2.0, np.float32))
    sub.pull(KEY)                                    # repair via delta window
    np.testing.assert_array_equal(_view(sub)[:64],
                                  np.full(64, 2.0, np.float32))


def test_wire_frame_delay_converges():
    gt, (p,), sub = _fabric(64, subscriber=True)
    with faults.armed(faults.FaultPlan(seed=5).add(
            "wire-frame-delay", host="sub", times=3, delay_s=0.003)) as plan:
        for _ in range(3):
            _view(p)[0] += 1.0
            p.push_delta(KEY, wire="exact")
            gt.flush_broadcasts()        # delivery (and its fault) is async
        assert plan.fired("wire-frame-delay") == 3
    assert _global(gt)[0] == 3.0
    sub.pull(KEY)
    assert _view(sub)[0] == 3.0


def test_subscriber_raise_culled_mid_broadcast():
    """A subscriber blowing up inside the broadcast doesn't poison the push:
    the tier culls it and the pusher's delta still lands globally."""
    gt, (p,), sub = _fabric(64, subscriber=True)
    with faults.armed(faults.FaultPlan(seed=6).add(
            "subscriber-raise", host="sub")) as plan:
        _view(p)[:] += 1.0
        p.push_delta(KEY, wire="exact")              # sub raises mid-delivery
        gt.flush_broadcasts()                        # raise fires on the pump
        assert plan.fired("subscriber-raise") == 1
        assert _global(gt)[0] == 1.0                 # push unaffected
        _view(p)[:] += 1.0
        p.push_delta(KEY, wire="exact")              # sub was culled: no raise
        gt.flush_broadcasts()
    assert _global(gt)[0] == 2.0
    sub.pull(KEY)                                    # catch-up pull repairs
    assert _view(sub)[0] == 2.0


@pytest.mark.sanitize
def test_codec_error_falls_back_to_exact_wire():
    """An int8 encode failure mid-push is rescued by re-pushing the same
    delta on the exact wire — same fence token, so the rescue is still
    exactly-once — and the landed value carries no quantisation error."""
    n = INT8_WIRE_MIN_BYTES // 4                     # int8-eligible size
    gt, (p,), _ = _fabric(n)
    _view(p)[:] += 1.0
    with faults.armed(faults.FaultPlan(seed=7).add("codec-error")) as plan:
        moved = p.push_delta(KEY, wire="int8", fence=("cc", 1, 1))
        assert plan.fired("codec-error") == 1
    assert p.codec_fallbacks == 1
    assert moved > 0
    np.testing.assert_array_equal(_global(gt), np.ones(n, np.float32))
    # the fence token was consumed exactly once: replaying it is rejected
    _view(p)[:] += 1.0
    assert p.push_delta(KEY, wire="exact", fence=("cc", 1, 1)) == 0
    np.testing.assert_array_equal(_global(gt), np.ones(n, np.float32))


# -- attempt-fence semantics at the tier level --------------------------------

@pytest.mark.sanitize
def test_fence_rejects_superseded_duplicate_and_sealed_pushes():
    gt, (a, b), _ = _fabric(16, n_pushers=2)
    one = np.ones(16, np.float32)

    # attempt 1 (epoch 1) pushes its first delta
    _view(a)[:] += 1.0
    assert a.push_delta(KEY, wire="exact", fence=("c9", 1, 1)) > 0
    np.testing.assert_array_equal(_global(gt), one)

    # the runtime requeues: epoch 1 is superseded; the re-execution (epoch 2)
    # deterministically re-derives the same first push — duplicate seq, dropped
    gt.fence_supersede("c9", 1)
    _view(b)[:] += 1.0
    assert b.push_delta(KEY, wire="exact", fence=("c9", 2, 1)) == 0
    np.testing.assert_array_equal(_global(gt), one)
    # ...and the rejected replica was resynced to the global truth
    np.testing.assert_array_equal(_view(b)[:16], one)

    # a zombie write straggling in from the dead epoch is rejected too
    _view(a)[:] += 5.0
    assert a.push_delta(KEY, wire="exact", fence=("c9", 1, 2)) == 0
    np.testing.assert_array_equal(_global(gt), one)

    # epoch 2 advances past the duplicate: a NEW seq is admitted
    _view(b)[:] += 1.0
    assert b.push_delta(KEY, wire="exact", fence=("c9", 2, 2)) > 0
    np.testing.assert_array_equal(_global(gt), one * 2.0)

    # the winning settle seals the fence: a speculative loser (epoch 3)
    # can no longer write under this call
    gt.fence_seal("c9", 2)
    _view(a)[:] += 1.0
    assert a.push_delta(KEY, wire="exact", fence=("c9", 3, 1)) == 0
    np.testing.assert_array_equal(_global(gt), one * 2.0)

    # unrelated calls are untouched by the seal
    _view(a)[:] += 1.0
    assert a.push_delta(KEY, wire="exact", fence=("c10", 1, 1)) > 0
    np.testing.assert_array_equal(_global(gt), one * 3.0)


# -- monitor interleavings (fail_host / monitor_once / zombies) ---------------

def test_fail_host_requeues_queued_and_inflight_calls():
    """Killing a host with a full queue: the running call AND the calls
    still waiting in its pool are all re-executed elsewhere."""
    rt = FaasmRuntime(n_hosts=2, capacity=1)
    try:
        def napper(api):
            time.sleep(0.05)
            api.write_call_output(b"ok:" + api.read_call_input())
            return 0

        rt.upload(FunctionDef("nap", napper))
        cids = rt.invoke_many("nap", [bytes([i]) for i in range(6)])
        deadline = time.monotonic() + 5.0
        victim = None
        while victim is None and time.monotonic() < deadline:
            victim = next((h for h in rt.alive_hosts() if h._inflight > 0),
                          None)
        assert victim is not None
        rt.fail_host(victim.id)
        assert rt.wait_all(cids, timeout=30) == [0] * 6
        for i, cid in enumerate(cids):
            assert rt.output(cid) == b"ok:" + bytes([i])
            assert rt.call(cid).attempts <= rt.max_attempts
    finally:
        rt.shutdown()


def test_dispatch_retries_when_host_dies_between_placement_and_submit(
        monkeypatch):
    """The placement/submit race: the scheduler picks a host that dies
    before ``submit`` lands — the call is re-placed with backoff, not lost
    and not settled as failed."""
    rt = FaasmRuntime(n_hosts=2)
    try:
        def echo(api):
            api.write_call_output(b"ok")
            return 0

        rt.upload(FunctionDef("echo", echo))
        victim = rt.hosts["host0"]
        orig_submit = victim.submit

        def dying_submit(call):
            victim.fail()                # dies in the race window
            return orig_submit(call)     # raises "host is down"

        monkeypatch.setattr(victim, "submit", dying_submit)
        hit = {"n": 0}
        for sched in rt.schedulers.values():
            def place(call, _orig=sched.place):
                if hit["n"] == 0:
                    hit["n"] = 1
                    return victim        # force the race once
                return _orig(call)
            monkeypatch.setattr(sched, "place", place)

        cid = rt.invoke("echo")
        assert rt.wait(cid, timeout=10) == 0
        assert rt.output(cid) == b"ok"
        assert rt.call(cid).attempts == 2
        assert not victim.alive
    finally:
        rt.shutdown()


@pytest.mark.sanitize
def test_zombie_attempt_after_heartbeat_requeue_is_fenced():
    """Heartbeat false positive: a host merely sleeping is declared dead and
    its call requeued.  The zombie attempt later wakes and pushes — under
    its superseded epoch — and the fence drops the write: the increment
    lands exactly once, from the re-execution."""
    rt = FaasmRuntime(n_hosts=2, capacity=1, heartbeat_timeout=0.25)
    try:
        VectorAsync.create(rt.global_tier, KEY, np.zeros(8, np.float32))
        seen = {"n": 0}
        zombie_done = threading.Event()

        def inc(api):
            seen["n"] += 1
            first = seen["n"] == 1
            v = VectorAsync(api, KEY)
            v.pull(track_delta=True)
            v.add(0, 1.0)
            if first:
                time.sleep(0.9)          # silent past the heartbeat timeout
            try:
                v.push_delta(wire="exact")
            finally:
                if first:
                    zombie_done.set()
            api.write_call_output(b"ok")
            return 0

        rt.upload(FunctionDef("inc", inc))
        cid = rt.invoke("inc")
        assert rt.wait(cid, timeout=30) == 0
        assert zombie_done.wait(timeout=10)
        assert seen["n"] == 2                        # requeue did re-execute
        assert rt.call(cid).attempts == 2
        assert len(rt.alive_hosts()) == 1            # false positive killed it
        time.sleep(0.05)                             # let the zombie settle
        assert _global(rt.global_tier)[0] == 1.0     # exactly once
    finally:
        rt.shutdown()


def test_monitor_once_is_noop_without_heartbeat_or_load():
    rt = FaasmRuntime(n_hosts=2)
    try:
        assert rt.monitor_once() == []               # no timeout configured
        assert rt.monitor_once(timeout=0.0) == []    # idle hosts never fail
        assert len(rt.alive_hosts()) == 2
    finally:
        rt.shutdown()


# -- satellites ---------------------------------------------------------------

def test_checkpoint_beats_heartbeat_for_pure_compute():
    """A kernel-style compute loop (no host-interface calls) beats through
    ``cancellation.checkpoint`` and survives a heartbeat timeout shorter
    than the call."""
    rt = FaasmRuntime(n_hosts=1, heartbeat_timeout=0.3)
    try:
        def crunch(api):
            t_end = time.monotonic() + 1.0           # 3x the timeout
            while time.monotonic() < t_end:
                cancellation.checkpoint()            # kernel dispatch hook
                time.sleep(0.005)
            api.write_call_output(b"ok")
            return 0

        rt.upload(FunctionDef("crunch", crunch))
        cid = rt.invoke("crunch")
        assert rt.wait(cid, timeout=30) == 0
        assert rt.call(cid).attempts == 1            # never declared dead
        assert len(rt.alive_hosts()) == 1
    finally:
        rt.shutdown()


def test_failed_call_discards_unpushed_local_deltas():
    """Faaslet-mode: a call that dirties a shared replica and fails before
    pushing must not leak its half-written delta into the next call."""
    rt = FaasmRuntime(n_hosts=1)
    try:
        VectorAsync.create(rt.global_tier, KEY, np.zeros(8, np.float32))
        bomb = {"armed": True}

        def writer(api):
            v = VectorAsync(api, KEY)
            v.pull(track_delta=True)
            v.add(0, 13.0)                           # dirty, never pushed
            if bomb.pop("armed", False):
                raise RuntimeError("boom")
            api.write_call_output(v.values.tobytes())
            return 0

        rt.upload(FunctionDef("writer", writer))
        assert rt.wait(rt.invoke("writer"), timeout=10) == 1
        host = next(iter(rt.hosts.values()))
        assert not host.local_tier.replica(KEY).dirty_chunks
        assert _global(rt.global_tier)[0] == 0.0
        # the next call sees the clean value, not the leaked 13
        c2 = rt.invoke("writer")
        assert rt.wait(c2, timeout=10) == 0
        assert np.frombuffer(rt.output(c2), np.float32)[0] == 13.0
    finally:
        rt.shutdown()


def test_submit_degradable_sheds_below_floor():
    from repro.launch.serve import SHED_RC, submit_degradable
    rt = FaasmRuntime(n_hosts=2)
    try:
        def echo(api):
            api.write_call_output(b"ok")
            return 0

        rt.upload(FunctionDef("echo", echo))
        res = submit_degradable(rt, "echo", [b""] * 4, min_alive_hosts=1)
        assert res["shed"] == 0 and not res["degraded"]
        assert res["codes"] == [0] * 4

        rt.fail_host("host0")
        # below the floor: fail fast (shed) instead of queueing into a
        # cluster that can't serve
        res = submit_degradable(rt, "echo", [b""] * 4, min_alive_hosts=2)
        assert res["degraded"] and res["shed"] == 4
        assert res["codes"] == [SHED_RC] * 4
        assert res["call_ids"] == [None] * 4
        # at the floor: the surviving host still serves everything
        res = submit_degradable(rt, "echo", [b""] * 4, min_alive_hosts=1)
        assert res["shed"] == 0 and res["codes"] == [0] * 4
    finally:
        rt.shutdown()


def test_scatter_gather_retries_settled_failures():
    """Application-level retry above the runtime: children that SETTLE as
    failed (no host loss involved) are re-chained as fresh calls."""
    rt = FaasmRuntime(n_hosts=2)
    try:
        flaked = {}

        def child(api):
            p = bytes(api.read_call_input())
            if p not in flaked:
                flaked[p] = True
                return 1                             # settled failure
            api.write_call_output(b"ok:" + p)
            return 0

        def parent(api):
            pairs = scatter_gather(api, "child", [b"a", b"b"], retries=1)
            assert [rc for rc, _ in pairs] == [0, 0]
            api.write_call_output(b"".join(out for _, out in pairs))
            return 0

        rt.upload(FunctionDef("child", child))
        rt.upload(FunctionDef("parent", parent))
        cid = rt.invoke("parent")
        assert rt.wait(cid, timeout=30) == 0
        assert rt.output(cid) == b"ok:aok:b"
    finally:
        rt.shutdown()


# -- overload control plane ---------------------------------------------------

def test_overload_chaos_smoke_queue_flood_spills_to_peer():
    """An armed queue-flood storm on one host makes its bounded admission
    refuse every submit; the dispatcher spills down the rendezvous ranking
    to the healthy peer and every call still serves — zero sheds."""
    rt = FaasmRuntime(n_hosts=2,
                      overload=oload.OverloadPolicy(max_queue_depth=2))
    try:
        rt.upload(FunctionDef("f", lambda api: 0))
        plan = faults.FaultPlan(seed=3).add("queue-flood", host="host0",
                                            times=64)
        with faults.armed(plan):
            cids = rt.invoke_many("f", [b""] * 6)
            assert rt.wait_all(cids, timeout=30) == [0] * 6
        assert plan.fired("queue-flood") >= 1
        assert rt.spill_total >= 1 and rt.shed_total == 0
        # nothing admitted on the flooded host: every call ran on the peer
        assert {rt._calls[c].host for c in cids} == {"host1"}
    finally:
        rt.shutdown()


def test_queue_flood_everywhere_sheds_fast():
    """When every host's admission refuses (cluster-wide flood), calls
    settle SHED_RC in microseconds instead of queueing invisibly."""
    rt = FaasmRuntime(n_hosts=2,
                      overload=oload.OverloadPolicy(max_queue_depth=1))
    try:
        rt.upload(FunctionDef("f", lambda api: 0))
        plan = faults.FaultPlan(seed=5).add("queue-flood", times=256)
        with faults.armed(plan):
            cids = rt.invoke_many("f", [b""] * 4)
            codes = rt.wait_all(cids, timeout=30)
        assert codes == [oload.SHED_RC] * 4
        assert rt.shed_total == 4
        assert all(rt._calls[c].status == "shed" for c in cids)
    finally:
        rt.shutdown()


def test_deadline_clock_skew_sheds_at_dequeue():
    """A call whose budget evaporates between queue and dequeue (injected
    clock skew) settles DEADLINE_RC at the dequeue check — the function
    body never runs, no executor slot is wasted on doomed work."""
    rt = FaasmRuntime(n_hosts=1,
                      overload=oload.OverloadPolicy(default_deadline_s=0.05))
    try:
        ran = []

        def f(api):
            ran.append(1)
            return 0

        rt.upload(FunctionDef("f", f))
        plan = faults.FaultPlan(seed=7).add("deadline-clock-skew",
                                            delay_s=0.15)
        with faults.armed(plan):
            cid = rt.invoke("f")
            assert rt.wait(cid, timeout=30) == oload.DEADLINE_RC
        assert plan.fired("deadline-clock-skew") == 1
        assert not ran
        assert rt._calls[cid].status == "deadline"
        assert rt.deadline_total == 1
    finally:
        rt.shutdown()


@pytest.mark.sanitize
def test_deadline_after_partial_push_is_exactly_once():
    """Deadline × fence: a call that lands one push_delta and then hits its
    deadline at the next push checkpoint leaves exactly the pushed effect —
    the un-pushed add is discarded with the failed attempt, nothing is
    double-applied, and the deadline settle never triggers a retry."""
    rt = FaasmRuntime(n_hosts=1)
    try:
        VectorAsync.create(rt.global_tier, KEY, np.zeros(8, np.float32))

        def fn(api):
            v = VectorAsync(api, KEY)
            v.pull(track_delta=True)
            v.add(0, 1.0)
            v.push_delta(wire="exact")       # lands before expiry
            v.add(1, 1.0)                    # never pushed
            time.sleep(0.2)                  # burn the whole budget
            v.push_delta(wire="exact")       # checkpoint raises here
            return 0

        rt.upload(FunctionDef("fn", fn))
        cid = rt.invoke("fn", deadline=0.08)
        assert rt.wait(cid, timeout=30) == oload.DEADLINE_RC
        assert rt._calls[cid].status == "deadline"
        g = _global(rt.global_tier)
        assert g[0] == 1.0 and g[1] == 0.0, g[:2]
    finally:
        rt.shutdown()


def test_subscriber_stall_does_not_block_pusher():
    """The async-broadcast contract with a timing bound: a subscriber
    stalled 250 ms delays only its own pump thread — the pusher's
    push_delta returns in well under 50 ms."""
    gt, (pusher,), sub = _fabric(subscriber=True)
    plan = faults.FaultPlan(seed=9).add("subscriber-stall", delay_s=0.25)
    with faults.armed(plan):
        _view(pusher)[0] += 1.0
        t0 = time.perf_counter()
        pusher.push_delta(KEY, wire="exact")
        wall = time.perf_counter() - t0
        gt.flush_broadcasts(timeout=10.0)
    assert plan.fired("subscriber-stall") == 1
    assert wall < 0.05, f"pusher blocked {wall * 1e3:.1f} ms by a stalled " \
                        f"subscriber"
    want = np.zeros(256, np.float32)
    want[0] = 1.0
    np.testing.assert_array_equal(_view(sub), want)


def test_bcast_overflow_drops_subscriber_to_pull_repair():
    """A subscriber whose channel overflows (stalled pump, pushes across
    more keys than the bounded depth holds) is dropped from the broadcast
    set instead of backpressuring the fabric — and one delta pull per key
    repairs it to the exact global state."""
    gt = GlobalTier()
    gt.bcast_depth = 1
    keys = [f"k{i}" for i in range(4)]
    push, sub = LocalTier("push", gt), LocalTier("sub", gt)
    for k in keys:
        gt.set(k, np.zeros(8, np.float32).tobytes(), host="seed")
        push.pull(k)
        push.snapshot_base(k)
        sub.pull(k)
        sub.subscribe(k)
    plan = faults.FaultPlan(seed=13).add("subscriber-stall", delay_s=0.3)
    with faults.armed(plan):
        for k in keys:
            push.replica(k).buf.view(np.float32)[0] += 1.0
            push.push_delta(k, wire="exact")
        gt.flush_broadcasts(timeout=10.0)
    assert gt.bcast_dropped >= 1
    for k in keys:
        sub.pull(k)
        assert sub.replica(k).buf.view(np.float32)[0] == 1.0, k


def test_wait_all_timeout_names_outstanding_calls():
    """A partial fan-out timeout is debuggable without tracing: BatchTimeout
    carries exactly which ids are still in flight and what the rest
    returned, and the batch stays waitable afterwards."""
    rt = FaasmRuntime(n_hosts=2)
    try:
        gate = threading.Event()
        rt.upload(FunctionDef("fast", lambda api: 0))
        rt.upload(FunctionDef("slow", lambda api: 0 if gate.wait(10) else 1))
        cid_f = rt.invoke("fast")
        assert rt.wait(cid_f, timeout=10) == 0       # settled before the batch
        cid_s = rt.invoke("slow")
        with pytest.raises(BatchTimeout) as ei:
            rt.wait_all([cid_f, cid_s], timeout=0.2)
        bt = ei.value
        assert bt.pending == [cid_s]
        assert bt.done == {cid_f: 0}
        assert bt.timeout == 0.2
        assert str(cid_s) in str(bt)
        gate.set()
        assert rt.wait_all([cid_f, cid_s], timeout=30) == [0, 0]
    finally:
        rt.shutdown()


def test_open_breaker_steers_placement_and_fails_open():
    """An open per-host breaker removes the host from the candidate pool;
    when every breaker is open the scheduler fails open (placement beats a
    self-inflicted total outage)."""
    rt = FaasmRuntime(n_hosts=2, overload=oload.OverloadPolicy(
        breaker=lambda: oload.CircuitBreaker(reset_timeout_s=60.0)))
    try:
        rt.upload(FunctionDef("f", lambda api: 0))
        rt._breakers["host0"].trip()
        cids = rt.invoke_many("f", [b""] * 4)
        assert rt.wait_all(cids, timeout=30) == [0] * 4
        assert {rt._calls[c].host for c in cids} == {"host1"}
        # all breakers open: fail open rather than refuse all placement
        rt._breakers["host1"].trip()
        cid = rt.invoke("f")
        assert rt.wait(cid, timeout=30) == 0
    finally:
        rt.shutdown()


def test_retry_budget_dry_settles_lost_calls_failed():
    """With the retry budget exhausted, a call lost to host failure settles
    failed immediately instead of amplifying the fault into a retry storm."""
    rt = FaasmRuntime(n_hosts=2, capacity=1, overload=oload.OverloadPolicy(
        retry_budget=oload.RetryBudget(initial=0.0)))
    try:
        block = threading.Event()
        rt.upload(FunctionDef("f", lambda api: 0 if block.wait(10) else 1))
        cid = rt.invoke("f")
        deadline = time.monotonic() + 5.0
        while rt._calls[cid].status != "running" and \
                time.monotonic() < deadline:
            time.sleep(0.001)
        assert rt._calls[cid].status == "running"
        rt.fail_host(rt._calls[cid].host)
        rc = rt.wait(cid, timeout=30)
        block.set()
        assert rc != 0
        assert "retry budget exhausted" in rt._calls[cid].error
        assert rt.overload.retry_budget.denied_total == 1
    finally:
        rt.shutdown()


# -- the seeded chaos matrix --------------------------------------------------

def _storm(seed, n_iters=6):
    """Two pusher tiers + a broadcast subscriber + a polling puller under a
    ``FaultPlan.random(seed)`` schedule: after the storm the global value
    must equal the fault-free sum exactly and every replica must converge
    after one repair pull."""
    n = 256                                          # < int8 floor: exact wire
    gt = GlobalTier()
    gt.set(KEY, np.zeros(n, np.float32).tobytes(), host="seed")
    pushers = []
    for i in range(2):
        t = LocalTier(f"push{i}", gt)
        t.pull(KEY)
        t.snapshot_base(KEY)
        pushers.append(t)
    sub = LocalTier("sub", gt)
    sub.pull(KEY)
    sub.subscribe(KEY)
    puller = LocalTier("puller", gt)
    puller.pull(KEY)

    stop = threading.Event()
    errors = []

    def push_loop(t, slot):
        try:
            for _ in range(n_iters):
                _view(t)[slot] += 1.0
                t.push_delta(KEY, wire="exact")
        except Exception as e:                       # pragma: no cover
            errors.append(e)

    def pull_loop():
        try:
            while not stop.is_set():
                puller.pull(KEY)
                time.sleep(0.001)
        except Exception as e:                       # pragma: no cover
            errors.append(e)

    with faults.armed(faults.FaultPlan.random(seed)) as plan:
        threads = [threading.Thread(target=push_loop, args=(t, i))
                   for i, t in enumerate(pushers)]
        pt = threading.Thread(target=pull_loop)
        for th in threads:
            th.start()
        pt.start()
        for th in threads:
            th.join(timeout=30)
        stop.set()
        pt.join(timeout=30)
        gt.flush_broadcasts()            # drain pumps while still armed
    assert not errors, errors

    want = np.zeros(n, np.float32)
    want[0] = want[1] = n_iters
    # the global tier holds the exact fault-free sum: nothing dropped,
    # nothing double-applied, regardless of the schedule
    np.testing.assert_array_equal(_global(gt), want)
    # and every replica converges after one clean repair pull
    for t in (sub, puller, *pushers):
        t.pull(KEY)
        np.testing.assert_array_equal(_view(t)[:n], want)
    return plan


@pytest.mark.sanitize
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_matrix_smoke(seed):
    _storm(seed)


@pytest.mark.slow
@pytest.mark.sanitize
@pytest.mark.parametrize("seed", list(range(3, 13)))
def test_chaos_matrix_full(seed):
    _storm(seed, n_iters=12)


@pytest.mark.sanitize
def test_runtime_chaos_kill_during_fanout():
    """Runtime-level storm: a random fault schedule plus an explicit host
    kill mid-fanout; every increment lands exactly once."""
    rt = FaasmRuntime(n_hosts=3, capacity=1, backoff=0.001)
    try:
        VectorAsync.create(rt.global_tier, KEY, np.zeros(8, np.float32))

        def inc(api):
            time.sleep(0.01)
            v = VectorAsync(api, KEY)
            v.pull(track_delta=True)
            v.add(0, 1.0)
            v.push_delta(wire="exact")
            return 0

        rt.upload(FunctionDef("inc", inc))
        with faults.armed(faults.FaultPlan.random(11)):
            cids = rt.invoke_many("inc", [b""] * 8, state_hint=[KEY])
            deadline = time.monotonic() + 5.0
            victim = None
            while victim is None and time.monotonic() < deadline:
                victim = next((h for h in rt.alive_hosts()
                               if h._inflight > 0), None)
            assert victim is not None
            rt.fail_host(victim.id)
            assert rt.wait_all(cids, timeout=60) == [0] * 8
        assert _global(rt.global_tier)[0] == 8.0     # exactly once each
    finally:
        rt.shutdown()
