"""Narrow wire tiers (int4/fp8), the host-native fused codec, chunked
encode, and measured-cost wire selection.

Parity notes baked into the bounds below:

* The host codec divides ``absmax/qmax`` plainly; XLA jit compiles the same
  division to reciprocal-multiply, which can shift a handful of row scales
  by one ULP — so cross-path assertions are tolerance-based, never bitwise.
* fp8 (e4m3fn) has a 12.5% relative step, so cross-backend code ties at
  half-step boundaries can land a *full* step apart; fp8 bounds are in
  step units.

The ``pallas_interpret`` parametrisations are auto-marked slow by conftest;
the xla rows run in the ``scripts/tier1.sh`` fast gate.
"""
import numpy as np
import pytest

from repro.kernels.state_push import hostcodec
from repro.kernels.state_push import ops
from repro.state import wire as wire_mod
from repro.state.kv import GlobalTier
from repro.state.local import LocalTier
from repro.state.wire import (WireCostModel, WirePolicy, available_wires,
                              get_codec)

BACKENDS = ("xla", "pallas_interpret")
ODD_SIZES = (1, 5, 130, 1000, 4097)

needs_fp8 = pytest.mark.skipif(not hostcodec.fp8_available(),
                               reason="ml_dtypes not installed")


def _rng(seed=0):
    return np.random.default_rng(seed)


def _pair(n, seed=0, scale=1.0):
    rng = _rng(seed)
    eff = (rng.normal(size=n) * scale).astype(np.float32)
    base = (rng.normal(size=n) * scale).astype(np.float32)
    return eff, base


# -- host codec: conservation, pad no-op, odd sizes, chunk invariance ---------


@pytest.mark.parametrize("qmax", [127, 7])
@pytest.mark.parametrize("n", ODD_SIZES)
def test_hostcodec_residual_conserves_delta(qmax, n):
    """deq + residual == delta exactly — error feedback loses nothing."""
    eff, base = _pair(n, seed=n)
    q, s, numel, resid = hostcodec.encode_quant(eff, base, qmax=qmax)
    assert numel == n and resid.shape == (n,)
    deq = hostcodec.decode_rows(q, s, n)
    np.testing.assert_allclose(deq + resid, eff - base, atol=1e-6)
    assert np.abs(q.astype(np.int32)).max() <= qmax


@pytest.mark.parametrize("qmax", [127, 7])
def test_hostcodec_pad_region_is_zero(qmax):
    n = 130                                   # 2 rows, 126 pad lanes
    eff, base = _pair(n, seed=3)
    q, s, numel, _ = hostcodec.encode_quant(eff, base, qmax=qmax)
    assert q.shape == (2, 128) and numel == n
    assert np.all(q.reshape(-1)[n:] == 0)


@pytest.mark.parametrize("chunk_rows", [1, 3, 7, 1024])
def test_hostcodec_chunked_matches_unchunked_bitwise(chunk_rows):
    """Chunks split on row boundaries and scales are per-row, so any chunk
    size yields bit-identical wire buffers."""
    n = 9 * 128 + 17
    eff, base = _pair(n, seed=9)
    q1, s1, _, r1 = hostcodec.encode_quant(eff, base, qmax=127, chunk_rows=chunk_rows)
    q2, s2, _, r2 = hostcodec.encode_quant(eff, base, qmax=127)
    assert np.array_equal(q1, q2)
    assert np.array_equal(s1, s2)
    assert np.array_equal(r1, r2)


def test_hostcodec_none_base_is_zero_base():
    eff, _ = _pair(1000, seed=4)
    q1, s1, _, r1 = hostcodec.encode_quant(eff, None)
    q2, s2, _, r2 = hostcodec.encode_quant(eff, np.zeros_like(eff))
    assert np.array_equal(q1, q2) and np.array_equal(s1, s2)
    assert np.array_equal(r1, r2)


def test_hostcodec_exact_matches_subtract():
    eff, base = _pair(4097, seed=5)
    out = hostcodec.encode_exact(eff, base, chunk_rows=2)
    np.testing.assert_array_equal(out, eff - base)


# -- int4 nibble packing ------------------------------------------------------


def test_int4_pack_roundtrips_full_code_range():
    q = np.tile(np.arange(-7, 8, dtype=np.int8), (3, 128))[:, :128]
    packed = hostcodec.pack_int4(q)
    assert packed.shape == (3, 64) and packed.dtype == np.uint8
    assert np.array_equal(hostcodec.unpack_int4(packed), q)


def test_int4_frame_halves_payload():
    eff, base = _pair(256 << 8, seed=6)
    f8 = get_codec("int8").encode(eff, base, backend="xla")[0]
    f4 = get_codec("int4").encode(eff, base, backend="xla")[0]
    assert f4.payload.nbytes * 2 == f8.payload.nbytes


# -- fp8 tier -----------------------------------------------------------------


@needs_fp8
@pytest.mark.parametrize("n", ODD_SIZES)
def test_hostcodec_fp8_conserves_and_never_nans(n):
    # huge dynamic range: without the pre-cast clip these overflow to NaN
    eff, base = _pair(n, seed=n, scale=1e4)
    q, s, numel, resid = hostcodec.encode_fp8(eff, base)
    deq = hostcodec.decode_rows(q, s, numel)
    assert not np.isnan(deq).any()
    np.testing.assert_allclose(deq + resid, eff - base, atol=1e-6)
    # e4m3 relative step is 2^-3: per-element error ≤ |delta|/8 + eps
    delta = eff - base
    assert np.abs(deq - delta).max() <= np.abs(delta).max() / 8.0 + 1e-6


@needs_fp8
def test_fp8_codec_registered_only_when_available():
    assert "fp8" in available_wires()
    assert get_codec("fp8").name == "fp8"


# -- xla / pallas_interpret parity matrix -------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("qmax", [127, 7])
@pytest.mark.parametrize("n", [130, 1000])
def test_quant_parity_host_vs_device(backend, qmax, n):
    """The device encode and the host fast path agree to quantisation
    precision (scales may differ by one ULP — see module docstring)."""
    import jax.numpy as jnp
    eff, base = _pair(n, seed=qmax + n)
    qh, sh, _, _ = hostcodec.encode_quant(eff, base, qmax=qmax)
    qd, sd, numel, _ = ops.encode_quant(jnp.asarray(eff), jnp.asarray(base),
                                        qmax=qmax, backend=backend)
    assert numel == n
    deq_h = hostcodec.decode_rows(qh, sh, n)
    deq_d = hostcodec.decode_rows(np.asarray(qd), np.asarray(sd), n)
    step = np.abs(eff - base).max() / qmax
    assert np.abs(deq_h - deq_d).max() <= step + 1e-6


@needs_fp8
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", [130, 1000])
def test_fp8_parity_host_vs_device(backend, n):
    """fp8 ties at half-step boundaries can land a full e4m3 step apart
    across backends — the bound is in fp8-step units, deliberately loose."""
    import jax.numpy as jnp
    eff, base = _pair(n, seed=n)
    qh, sh, _, _ = hostcodec.encode_fp8(eff, base)
    qd, sd, numel, _ = ops.encode_fp8(jnp.asarray(eff), jnp.asarray(base),
                                      backend=backend)
    assert numel == n
    deq_h = hostcodec.decode_rows(qh, sh, n)
    deq_d = hostcodec.decode_rows(np.asarray(qd).astype(np.float32),
                                  np.asarray(sd), n)
    assert not np.isnan(deq_d).any()
    # one fp8 step of the largest magnitude in the row set
    bound = np.abs(eff - base).max() / 4.0 + 1e-6
    assert np.abs(deq_h - deq_d).max() <= bound


@pytest.mark.parametrize("backend", BACKENDS)
def test_residual_conservation_device_paths(backend):
    """Fused device encode's residual also conserves: deq + resid == delta
    to f32 rounding."""
    import jax.numpy as jnp
    eff, base = _pair(1000, seed=11)
    q, s, n, resid = ops.encode_quant(jnp.asarray(eff), jnp.asarray(base),
                                      qmax=127, backend=backend)
    deq = hostcodec.decode_rows(np.asarray(q), np.asarray(s), n)
    np.testing.assert_allclose(deq + np.asarray(resid), eff - base, atol=1e-5)


def test_device_chunked_encode_matches_single_shot():
    """Values past DEVICE_CHUNK_ROWS rows take the pipelined chunk path;
    row-aligned chunks with per-row scales must reproduce the single-shot
    executable bitwise."""
    import jax.numpy as jnp
    n = (ops.DEVICE_CHUNK_ROWS + 100) * 128 + 7
    eff, base = _pair(n, seed=12, scale=0.1)
    je, jb = jnp.asarray(eff), jnp.asarray(base)
    q, s, numel, resid = ops.encode_quant(je, jb, qmax=127)
    assert numel == n
    qs, ss, rs = ops._encode_fused(je, jb, 127, True)
    assert np.array_equal(q, np.asarray(qs))
    assert np.array_equal(s, np.asarray(ss))
    np.testing.assert_array_equal(resid,
                                  np.asarray(rs).reshape(-1)[:n])


def test_host_fast_path_skips_jax_dispatch():
    """numpy operands on the xla backend return numpy wire buffers computed
    by the host codec — bitwise equal to calling hostcodec directly."""
    eff, base = _pair(130, seed=13)
    q, s, n, resid = ops.encode_quant(eff, base, qmax=127, backend="xla")
    qh, sh, _, rh = hostcodec.encode_quant(eff, base, qmax=127)
    assert type(q) is np.ndarray
    assert np.array_equal(q, qh) and np.array_equal(s, sh)
    assert np.array_equal(resid, rh)


# -- wire codecs end to end ---------------------------------------------------


@pytest.mark.parametrize("wire", ["int4", "fp8"])
def test_narrow_tier_push_converges_with_error_feedback(wire):
    """A narrow-tier push stream converges on the global value: per-push
    quantisation error is carried by the residual, not lost."""
    if wire == "fp8" and not hostcodec.fp8_available():
        pytest.skip("ml_dtypes not installed")
    n = 256 << 8                              # 256 KB
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.set_wire_tiers(wire)
    lt.pull("w")
    lt.snapshot_base("w")
    LocalTier("q", gt).pull("w")              # wire interest: frame it
    rng = _rng(17)
    view = lt.replica("w").buf.view(np.float32)
    total = np.zeros(n, np.float32)
    for _ in range(6):
        u = (rng.normal(size=n) * 0.01).astype(np.float32)
        view[:] += u
        total += u
        lt.push_delta("w", wire=wire)
    got = np.frombuffer(gt.get("w", host="check"), np.float32)
    # after the final push one residual remains un-pushed: bounded by one
    # quantisation step of the last encode's per-row absmax (~N(0, 0.01)
    # updates plus carried residual → well under one update magnitude)
    assert np.abs(got - total).max() <= 0.01
    assert np.abs(got - total).mean() <= 2e-3


def test_int4_wire_frame_decodes_through_frame_api():
    eff, base = _pair(130, seed=19)
    frame, resid = get_codec("int4").encode(eff, base, backend="xla")
    assert frame.wire == "int4" and frame.payload.dtype == np.uint8
    deq = frame.decode()
    np.testing.assert_allclose(deq + resid, eff - base, atol=1e-6)
    q, s = frame.codes()
    assert q.dtype == np.int8 and np.abs(q.astype(np.int32)).max() <= 7


# -- WireCostModel ------------------------------------------------------------


def test_cost_model_bucket_clamps():
    assert WireCostModel.bucket(1) == WireCostModel.MIN_BUCKET
    assert WireCostModel.bucket(1 << 20) == 20
    assert WireCostModel.bucket(1 << 40) == WireCostModel.MAX_BUCKET


def test_cost_model_frame_bytes():
    vb = 128 * 4 * 8                          # 8 rows of f32
    assert WireCostModel.frame_bytes("exact", vb) == vb
    assert WireCostModel.frame_bytes("int8", vb) == 8 * 128 + 8 * 4
    assert WireCostModel.frame_bytes("int4", vb) == 8 * 64 + 8 * 4
    assert WireCostModel.frame_bytes("fp8", vb) == 8 * 128 + 8 * 4


def test_cost_model_predict_needs_evidence_then_learns():
    m = WireCostModel()
    assert m.predict("int8", 1 << 20) is None
    m.observe("int8", 1 << 20, 2_000_000, wall_ns=5_000_000)
    p = m.predict("int8", 1 << 20)
    assert p == pytest.approx(5_000_000)
    # EWMA moves toward new evidence without jumping
    m.observe("int8", 1 << 20, 4_000_000, wall_ns=8_000_000)
    p2 = m.predict("int8", 1 << 20)
    assert 5_000_000 < p2 < 8_000_000


def test_cost_model_rescales_from_nearest_bucket():
    m = WireCostModel()
    m.observe("exact", 1 << 20, 1_000_000, wall_ns=1_500_000)
    # 4 MB never observed: the 1 MB evidence rescales linearly
    p = m.predict("exact", 1 << 22)
    assert p == pytest.approx(6_000_000)


def test_cost_model_link_bandwidth_term():
    m = WireCostModel(link_bytes_per_s=1e6)   # 1 MB/s — glacial
    m.observe("exact", 1 << 20, 1_000, wall_ns=2_000)
    m.observe("int8", 1 << 20, 500_000, wall_ns=600_000)
    # exact ships 4x the bytes: on a slow link int8 must win
    assert m.predict("int8", 1 << 20) < m.predict("exact", 1 << 20)


def test_cost_model_seed_from_bench_schema():
    bench = {"value_kb": [64, 4096],
             "64kb": {"exact": {"encode_us_p50": 50.0, "push_us_p50": 100.0,
                                "bytes_per_push": 65536},
                      "int8": {"encode_us_p50": 150.0, "push_us_p50": 300.0,
                               "bytes_per_push": 17408},
                      "auto": {"push_us_p50": 99.0},
                      "crossover_mbps": {"int8": 100.0}},
             "4096kb": {"exact": {"encode_us_p50": 4000.0,
                                  "push_us_p50": 8000.0}}}
    m = WireCostModel()
    assert m.seed(bench) == 3                 # auto/crossover rows skipped
    assert m.predict("exact", 64 << 10) == pytest.approx(100.0 * 1e3)
    assert m.predict("int8", 64 << 10) == pytest.approx(300.0 * 1e3)
    snap = m.snapshot()
    assert 16 in snap["exact"] and 22 in snap["exact"]


# -- WirePolicy: measured-cost regime -----------------------------------------


def _armed(**kw):
    return wire_mod.enable_cost_model(**kw)


def test_policy_cost_mode_probes_unknown_then_argmins():
    m = _armed()
    pol = WirePolicy(tiers=("int8",))
    nb = 1 << 20
    # nothing observed: exact is first unknown → probe it
    assert pol.select(nb, np.float32) == "exact"
    m.observe("exact", nb, 1_000_000, wall_ns=2_000_000)
    # int8 still unknown → probed next
    assert pol.select(nb, np.float32) == "int8"
    m.observe("int8", nb, 500_000, wall_ns=900_000)
    assert pol.select(nb, np.float32) == "int8"     # measured cheapest
    m.observe("int8", nb, 9_000_000, wall_ns=20_000_000)
    assert pol.select(nb, np.float32) == "exact"    # evidence flipped it
    assert pol.flips >= 2


def test_policy_cost_mode_residual_ban_and_reprobe():
    m = _armed()
    pol = WirePolicy(tiers=("int8",), damping=3, probe_after=4)
    nb = 1 << 20
    m.observe("exact", nb, 1_000_000, wall_ns=2_000_000)
    m.observe("int8", nb, 100_000, wall_ns=200_000)
    assert pol.select(nb, np.float32) == "int8"
    # 3 consecutive over-cap residuals ban the tier despite its low cost
    for _ in range(3):
        pol.observe(delta_absmax=1.0, density=1.0,
                    residual_ratio=0.9, wire="int8")
    assert pol.select(nb, np.float32) == "exact"   # advances the ban clock
    # every probe_after-th select routes one re-qualification push onto the
    # banned tier (the assert above already advanced the clock once)
    wires = [pol.select(nb, np.float32) for _ in range(4)]
    assert wires.count("int8") == 1
    assert all(w == "exact" for w in wires if w != "int8")
    # the re-probe comes back clean → tier un-banned, wins again on cost
    pol.observe(delta_absmax=1.0, density=1.0,
                residual_ratio=0.01, wire="int8")
    assert pol.select(nb, np.float32) == "int8"


def test_policy_cost_mode_structural_fallbacks_hold():
    _armed()
    pol = WirePolicy(tiers=("int8", "int4"))
    assert pol.select(64, np.float32) == "exact"          # below min_bytes
    assert pol.select(1 << 20, np.int32) == "exact"       # non-float


def test_policy_legacy_regime_untouched_when_disarmed():
    pol = WirePolicy(tiers=("int8",), damping=2)
    nb = 1 << 20
    assert pol.select(nb, np.float32) == "int8"
    for _ in range(2):
        pol.observe(delta_absmax=1.0, density=1.0,
                    residual_ratio=0.9, wire="int8")
    assert pol.select(nb, np.float32) == "exact"
    assert pol.flips == 1


def test_auto_push_with_cost_model_takes_cheapest_wire():
    """End to end: an armed cost model seeded to favour int8 routes an
    ``auto`` push onto the int8 wire; spans aside, the global value still
    converges."""
    n = 256 << 8
    m = _armed()
    for w in available_wires():
        # seed: int8 measured far cheaper than anything else at this size
        ns = 100_000 if w == "int8" else 10_000_000
        m.observe(w, n * 4, ns, wall_ns=ns * 2)
    gt = GlobalTier()
    gt.set("w", np.zeros(n, np.float32).tobytes(), host="up")
    lt = LocalTier("h0", gt)
    lt.set_wire_tiers(*[w for w in available_wires() if w != "exact"])
    lt.pull("w")
    lt.snapshot_base("w")
    LocalTier("q", gt).pull("w")
    view = lt.replica("w").buf.view(np.float32)
    u = (_rng(23).normal(size=n) * 0.01).astype(np.float32)
    view[:] += u
    lt.push_delta("w", wire="auto")
    assert lt.wire_policy("w").wire == "int8"
    got = np.frombuffer(gt.get("w", host="check"), np.float32)
    assert np.abs(got - u).max() <= np.abs(u).max() / 254.0 + 1e-6
