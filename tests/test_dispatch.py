"""Event-driven dispatch: batch invocation, completion latches, latency
regression (no polling floor), and the lock-striped global tier under
concurrent multi-key access."""
import threading
import time

import numpy as np
import pytest

from repro.core import CompletionLatch, FaasmRuntime, FunctionDef
from repro.state.kv import GlobalTier
from repro.state.local import LocalTier


def _echo(api):
    api.write_call_output(b"echo:" + api.read_call_input())
    return 0


# ---------------------------------------------------------------------------
# invoke_many / wait_all
# ---------------------------------------------------------------------------

def test_invoke_many_results_ordered():
    rt = FaasmRuntime(n_hosts=2, capacity=4)
    try:
        def sq(api):
            i = int.from_bytes(api.read_call_input(), "little")
            api.write_call_output((i * i).to_bytes(4, "little"))
            return 0

        rt.upload(FunctionDef("sq", sq))
        cids = rt.invoke_many("sq", [i.to_bytes(2, "little")
                                     for i in range(32)])
        assert len(cids) == 32
        rcs = rt.wait_all(cids, timeout=30)
        assert rcs == [0] * 32
        outs = [int.from_bytes(rt.output(c), "little") for c in cids]
        assert outs == [i * i for i in range(32)]    # IDs follow input order
    finally:
        rt.shutdown()


def test_wait_all_isolates_per_call_failures():
    rt = FaasmRuntime(n_hosts=2, capacity=4)
    try:
        def flaky(api):
            i = int.from_bytes(api.read_call_input(), "little")
            if i % 3 == 0:
                raise RuntimeError(f"boom {i}")
            api.write_call_output(bytes([i]))
            return 0

        rt.upload(FunctionDef("flaky", flaky))
        cids = rt.invoke_many("flaky", [i.to_bytes(1, "little")
                                        for i in range(12)])
        rcs = rt.wait_all(cids, timeout=30)
        for i, (cid, rc) in enumerate(zip(cids, rcs)):
            if i % 3 == 0:
                assert rc != 0
                assert "boom" in rt.call(cid).error
            else:
                assert rc == 0
                assert rt.output(cid) == bytes([i])
    finally:
        rt.shutdown()


def test_wait_all_empty_and_timeout():
    rt = FaasmRuntime(n_hosts=1)
    try:
        assert rt.wait_all([], timeout=1) == []

        def slow(api):
            time.sleep(2.0)
            return 0

        rt.upload(FunctionDef("slow", slow))
        cids = rt.invoke_many("slow", [b""])
        with pytest.raises(TimeoutError):
            rt.wait_all(cids, timeout=0.05)
        assert rt.wait_all(cids, timeout=30) == [0]
    finally:
        rt.shutdown()


def test_chain_call_many_from_inside_a_faaslet():
    rt = FaasmRuntime(n_hosts=2, capacity=8)
    try:
        def worker(api):
            i = int.from_bytes(api.read_call_input(), "little")
            api.write_call_output((2 * i).to_bytes(4, "little"))
            return 0

        def fanout(api):
            cids = api.chain_call_many(
                "worker", [i.to_bytes(2, "little") for i in range(16)])
            rcs = api.await_all(cids)
            assert rcs == [0] * 16
            total = sum(int.from_bytes(api.get_call_output(c), "little")
                        for c in cids)
            api.write_call_output(total.to_bytes(4, "little"))
            return 0

        rt.upload(FunctionDef("worker", worker))
        rt.upload(FunctionDef("fanout", fanout))
        cid = rt.invoke("fanout")
        assert rt.wait(cid, timeout=30) == 0, rt.call(cid).error
        assert int.from_bytes(rt.output(cid), "little") == \
            sum(2 * i for i in range(16))
    finally:
        rt.shutdown()


def test_completion_latch_counts_down_once_per_call():
    latch = CompletionLatch(3)
    assert not latch.wait(0)
    latch.count_down()
    latch.count_down()
    assert not latch.wait(0)
    latch.count_down()
    assert latch.wait(0)
    assert CompletionLatch(0).wait(0)                # empty batch: already open


# ---------------------------------------------------------------------------
# event-driven latency: no 50 ms polling floor
# ---------------------------------------------------------------------------

def test_warm_invoke_latency_has_no_polling_floor():
    rt = FaasmRuntime(n_hosts=1, capacity=2)
    try:
        def noop(api):
            return 0

        rt.upload(FunctionDef("noop", noop))
        rt.wait(rt.invoke("noop"), timeout=10)       # warm the Faaslet
        # the old sleep-poll wait() floored every call at ~50 ms, so every
        # round would fail; a loaded CI box can produce one outlier round,
        # hence best-of-3 (a real polling floor shows up in all of them)
        best_p99 = float("inf")
        for _ in range(3):
            lats = []
            for _ in range(50):
                t0 = time.perf_counter()
                cid = rt.invoke("noop")
                assert rt.wait(cid, timeout=10) == 0
                lats.append(time.perf_counter() - t0)
            p99_ms = float(np.percentile(np.asarray(lats), 99)) * 1e3
            best_p99 = min(best_p99, p99_ms)
            if best_p99 < 25.0:
                break
        assert best_p99 < 25.0, \
            f"p99 {best_p99:.2f}ms suggests a polling floor"
    finally:
        rt.shutdown()


def test_straggler_speculation_fires_from_monitor_without_waiter():
    """The twin is spawned by the background monitor even when nobody has
    called wait() yet."""
    rt = FaasmRuntime(n_hosts=2, straggler_timeout=0.2)
    try:
        seen = {"n": 0}

        def sometimes_slow(api):
            seen["n"] += 1
            if seen["n"] == 1:
                time.sleep(3.0)
            api.write_call_output(b"ok")
            return 0

        rt.upload(FunctionDef("s", sometimes_slow))
        cid = rt.invoke("s")
        time.sleep(0.8)                              # no waiter during this
        call = rt.call(cid)
        assert call.twin_id is not None
        assert rt.wait(cid, timeout=10) == 0
        assert rt.output(cid) == b"ok"
    finally:
        rt.shutdown()


def test_heartbeat_monitor_fails_silent_host_and_requeues():
    """With heartbeat_timeout set (opt-in), the background monitor declares a
    silent host dead and re-executes its in-flight calls elsewhere."""
    rt = FaasmRuntime(n_hosts=2, heartbeat_timeout=0.3)
    try:
        state = {"n": 0}

        def stall_once(api):
            state["n"] += 1
            if state["n"] == 1:
                time.sleep(2.5)                  # no beat while stalled
            api.write_call_output(b"ok")
            return 0

        rt.upload(FunctionDef("stall", stall_once))
        cid = rt.invoke("stall")
        assert rt.wait(cid, timeout=30) == 0
        assert rt.call(cid).attempts == 2        # heartbeat kill + re-execute
        assert rt.output(cid) == b"ok"
        assert len(rt.alive_hosts()) == 1
    finally:
        rt.shutdown()


# ---------------------------------------------------------------------------
# lock-striped GlobalTier
# ---------------------------------------------------------------------------

def test_global_tier_semantics_preserved():
    gt = GlobalTier(chunk_size=8)
    gt.set("k", bytes(range(32)), host="h")
    assert gt.n_chunks("k") == 4
    assert gt.get_range("k", 8, 8, host="h") == bytes(range(8, 16))
    gt.set_range("k", 30, b"\xff\xff\xff", host="h")   # extends the value
    assert gt.size("k") == 33
    with pytest.raises(IndexError):
        gt.get_range("k", 30, 10)
    gt.append("k", b"xy", host="h")
    assert gt.size("k") == 35
    assert gt.version("k") >= 3
    gt.delete("k")
    assert not gt.exists("k")
    assert gt.version("k") == 0


def test_global_tier_transfer_metrics_across_stripes():
    gt = GlobalTier(chunk_size=8)
    for i in range(20):                              # keys land on many stripes
        gt.set(f"key{i}", bytes(16), host="h0")
    assert gt.bytes_pushed["h0"] == 20 * 16
    for i in range(20):
        gt.get(f"key{i}", host="h1")
    assert gt.bytes_pulled["h1"] == 20 * 16
    assert gt.total_transfer() == 2 * 20 * 16
    gt.reset_metrics()
    assert gt.total_transfer() == 0


def test_concurrent_multi_key_access_under_striped_locks():
    gt = GlobalTier(chunk_size=64, n_stripes=16)
    n_threads, n_iters, size = 8, 200, 256
    for t in range(n_threads):
        gt.set(f"k{t}", bytes(size), host="init")
    errors = []

    def hammer(t):
        key = f"k{t}"
        try:
            for i in range(n_iters):
                payload = bytes([i % 256]) * 64
                gt.set_range(key, (i % 4) * 64, payload, host=f"h{t}")
                back = gt.get_range(key, (i % 4) * 64, 64, host=f"h{t}")
                assert back == payload
        except Exception as e:                       # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=60)
    assert not errors
    for t in range(n_threads):
        assert gt.size(f"k{t}") == size
        # every thread's final writes landed intact
        last = (n_iters - 1) % 256
        assert gt.get_range(f"k{t}", ((n_iters - 1) % 4) * 64, 64,
                            host="check") == bytes([last]) * 64


def test_local_tier_chunk_transfers_do_not_cross_keys():
    """pull_chunk / push_dirty ride on get_range/set_range per key; bytes are
    attributed exactly, chunk-granular, per host."""
    gt = GlobalTier(chunk_size=8)
    gt.set("a", bytes(range(64)), host="up")
    gt.set("b", bytes(64), host="up")
    lt = LocalTier("h0", gt)
    gt.reset_metrics()
    lt.pull_range("a", 20, 4)                        # chunk 2 of "a" only
    assert gt.bytes_pulled["h0"] == 8
    lt.pull("b")
    r = lt.replica("b")
    r.buf[9] = 42
    lt.mark_dirty("b", 9, 1)
    moved = lt.push_dirty("b")
    assert moved == 8                                # one chunk of "b"
    assert gt.get("b", host="x")[9] == 42
    assert bytes(lt.replica("a").buf[20:24]) == bytes(range(20, 24))


def test_concurrent_runtime_calls_on_distinct_state_keys():
    """End-to-end: parallel Faaslets writing different keys through the host
    interface never corrupt each other under the striped tier."""
    rt = FaasmRuntime(n_hosts=2, capacity=8, chunk_size=64)
    try:
        def writer(api):
            i = int.from_bytes(api.read_call_input(), "little")
            key = f"slot{i}"
            api.set_state(key, bytes([i]) * 128)
            api.push_state(key)
            return 0

        rt.upload(FunctionDef("writer", writer))
        cids = rt.invoke_many("writer", [i.to_bytes(1, "little")
                                         for i in range(16)])
        assert rt.wait_all(cids, timeout=30) == [0] * 16
        for i in range(16):
            assert rt.global_tier.get(f"slot{i}", host="check") == \
                bytes([i]) * 128
    finally:
        rt.shutdown()


# ---------------------------------------------------------------------------
# locality-aware batch placement (state_hint)
# ---------------------------------------------------------------------------

def test_invoke_many_state_hint_prefers_replica_holder():
    """A batch declaring its state keys lands on the warm host whose local
    tier already holds them; without a hint it round-robins the warm pool."""
    rt = FaasmRuntime(n_hosts=3, capacity=8)
    try:
        rt.global_tier.set("wkey", bytes(4096), host="up")

        def touch(api):
            api.get_state("wkey", writable=False)
            return 0

        rt.upload(FunctionDef("touch", touch))
        for hid in rt.hosts:                  # all hosts warm for "touch"
            rt.schedulers[hid].register_warm("touch")
        holder = "host2"
        rt.hosts[holder].local_tier.pull("wkey")   # only host2 holds a replica

        cids = rt.invoke_many("touch", [b""] * 9, state_hint=["wkey"])
        assert rt.wait_all(cids, timeout=30) == [0] * 9
        assert {rt.call(c).host for c in cids} == {holder}

        # no hint: the same batch spreads over the whole warm pool
        cids = rt.invoke_many("touch", [b""] * 9)
        assert rt.wait_all(cids, timeout=30) == [0] * 9
        assert len({rt.call(c).host for c in cids}) > 1
    finally:
        rt.shutdown()


def test_state_hint_with_no_holder_falls_back_to_pool():
    rt = FaasmRuntime(n_hosts=2, capacity=4)
    try:
        rt.upload(FunctionDef("echo2", _echo))
        cids = rt.invoke_many("echo2", [b"a", b"b", b"c"],
                              state_hint=["nobody-has-this"])
        assert rt.wait_all(cids, timeout=30) == [0] * 3
    finally:
        rt.shutdown()


def test_state_hint_pins_key_to_consistent_holder():
    """With several holders, the key pins to ONE of them by rendezvous
    hashing — stable across batches (the replica stays hot there) instead
    of round-robining within the holder set."""
    import zlib
    rt = FaasmRuntime(n_hosts=3, capacity=8)
    try:
        rt.global_tier.set("pinkey", bytes(4096), host="up")

        def touch(api):
            api.get_state("pinkey", writable=False)
            return 0

        rt.upload(FunctionDef("touch", touch))
        for hid in rt.hosts:
            rt.schedulers[hid].register_warm("touch")
        holders = ["host0", "host2"]
        for hid in holders:
            rt.hosts[hid].local_tier.pull("pinkey")

        expected = max(holders,
                       key=lambda h: zlib.crc32(f"pinkey@{h}".encode()))
        for _ in range(2):                     # stable batch after batch
            cids = rt.invoke_many("touch", [b""] * 6, state_hint=["pinkey"])
            assert rt.wait_all(cids, timeout=30) == [0] * 6
            assert {rt.call(c).host for c in cids} == {expected}
    finally:
        rt.shutdown()


def test_per_call_state_hint_shards_disjoint_keys_across_holders():
    """One hint entry per call pins each call to the holder of *its own*
    key — a fan-out over disjoint keys shards across the holder set instead
    of landing wherever the batch-level vote pointed."""
    rt = FaasmRuntime(n_hosts=3, capacity=8)
    try:
        for k in ("ka", "kb"):
            rt.global_tier.set(k, bytes(4096), host="up")

        def touch(api):
            return 0

        rt.upload(FunctionDef("touch", touch))
        for hid in rt.hosts:
            rt.schedulers[hid].register_warm("touch")
        rt.hosts["host0"].local_tier.pull("ka")
        rt.hosts["host2"].local_tier.pull("kb")

        hints = [["ka"], ["kb"]] * 4
        cids = rt.invoke_many("touch", [b""] * 8, state_hint=hints)
        assert rt.wait_all(cids, timeout=30) == [0] * 8
        placed = [rt.call(c).host for c in cids]
        assert {placed[i] for i in range(0, 8, 2)} == {"host0"}
        assert {placed[i] for i in range(1, 8, 2)} == {"host2"}

        # a bare-string entry counts as one key; None falls back to the pool
        cids = rt.invoke_many("touch", [b""] * 3,
                              state_hint=["ka", None, ["kb"]])
        assert rt.wait_all(cids, timeout=30) == [0] * 3
        assert rt.call(cids[0]).host == "host0"
        assert rt.call(cids[2]).host == "host2"
    finally:
        rt.shutdown()


def test_state_hint_spills_to_next_holder_when_saturated():
    """Capacity weighting: a pinned holder without capacity is skipped and
    the batch lands on the next-ranked holder."""
    import zlib
    rt = FaasmRuntime(n_hosts=3, capacity=8)
    try:
        rt.global_tier.set("capkey", bytes(4096), host="up")

        def touch(api):
            api.get_state("capkey", writable=False)
            return 0

        rt.upload(FunctionDef("touch", touch))
        for hid in rt.hosts:
            rt.schedulers[hid].register_warm("touch")
        holders = ["host0", "host1"]
        for hid in holders:
            rt.hosts[hid].local_tier.pull("capkey")
        ranked = sorted(holders, reverse=True,
                        key=lambda h: zlib.crc32(f"capkey@{h}".encode()))
        pinned, spill = ranked
        rt.hosts[pinned].has_capacity = lambda: False     # saturate it
        cids = rt.invoke_many("touch", [b""] * 4, state_hint=["capkey"])
        assert rt.wait_all(cids, timeout=30) == [0] * 4
        assert {rt.call(c).host for c in cids} == {spill}

        # every holder saturated: the batch round-robins queueing across
        # the holder set instead of piling on the top-ranked one
        rt.hosts[spill].has_capacity = lambda: False
        cids = rt.invoke_many("touch", [b""] * 4, state_hint=["capkey"])
        assert rt.wait_all(cids, timeout=30) == [0] * 4
        assert {rt.call(c).host for c in cids} == set(holders)
    finally:
        rt.shutdown()


# ---------------------------------------------------------------------------
# time-sliced cancellation inside kernel dispatch
# ---------------------------------------------------------------------------

def test_cancel_event_honoured_inside_pure_compute_loop():
    """A loop that only dispatches kernels (no host-interface calls) still
    stops within a bounded slice once its cancel_event is set — the kernel
    dispatch wrappers run the installed time-sliced checkpoint."""
    from repro.kernels.common import resolve_backend

    rt = FaasmRuntime(n_hosts=1, capacity=2)
    try:
        started = threading.Event()

        def spin(api):
            started.set()
            t0 = time.monotonic()
            while time.monotonic() - t0 < 20.0:   # pure compute: no api calls
                resolve_backend("xla")            # the dispatch chokepoint
            return 0

        rt.upload(FunctionDef("spin", spin))
        cid = rt.invoke("spin")
        assert started.wait(timeout=10)
        t0 = time.monotonic()
        rt.call(cid).cancel_event.set()
        rc = rt.wait(cid, timeout=10)
        elapsed = time.monotonic() - t0
        call = rt.call(cid)
        assert rc == 1 and call.status == "cancelled"
        assert elapsed < 5.0                      # bounded slice, not 20 s
        assert rt.hosts["host0"].cancelled_execs >= 1
    finally:
        rt.shutdown()
