"""Optimizer / data-pipeline / checkpointing / compression substrate tests."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import SGD, AdamW, accumulate_grads, warmup_cosine, compression
from repro.data import PipelineConfig, make_batch, make_sparse_dataset, \
    hinge_loss, accuracy
from repro.checkpoint import Checkpointer, save_global_tier, restore_global_tier
from repro.configs import smoke_config, smoke_shape
from repro.state.kv import GlobalTier


def _quad_problem():
    params = {"w": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.asarray(0.5)}

    def loss_fn(p, batch=None):
        return (jnp.sum(p["w"] ** 2) + p["b"] ** 2), {}
    return params, loss_fn


def test_sgd_converges_on_quadratic():
    params, loss_fn = _quad_problem()
    opt = SGD(lr=0.1, momentum=0.9)
    state = opt.init(params)
    for _ in range(100):
        grads = jax.grad(lambda p: loss_fn(p)[0])(params)
        params, state = opt.update(grads, state, params)
    assert float(loss_fn(params)[0]) < 1e-3
    assert int(state.step) == 100


def test_adamw_steps_and_dtypes():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    opt = AdamW(lr=1e-2)
    state = opt.init(params)
    grads = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    new, state = opt.update(grads, state, params)
    assert new["w"].dtype == jnp.bfloat16
    assert state.mu["w"].dtype == jnp.float32
    assert float(jnp.abs(new["w"].astype(jnp.float32)).mean()) < 1.0


def test_warmup_cosine_schedule():
    sched = warmup_cosine(1.0, warmup=10, total=110, floor=0.1)
    assert float(sched(jnp.asarray(0))) < 0.2
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 0.15
    assert float(sched(jnp.asarray(109))) < 0.2


def test_grad_accumulation_matches_full_batch():
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (8, 4))
    params = {"w": W}
    x = jax.random.normal(jax.random.fold_in(key, 1), (16, 8))
    y = jax.random.normal(jax.random.fold_in(key, 2), (16, 4))
    batch = {"x": x, "y": y}

    def loss_fn(p, b):
        pred = b["x"] @ p["w"]
        return jnp.mean((pred - b["y"]) ** 2), {}

    g1, l1, _ = accumulate_grads(loss_fn, params, batch, 1)
    g4, l4, _ = accumulate_grads(loss_fn, params, batch, 4)
    np.testing.assert_allclose(l1, l4, rtol=1e-5)
    np.testing.assert_allclose(g1["w"], g4["w"], atol=1e-5, rtol=1e-5)


def test_compression_error_feedback_unbiased():
    """With error feedback, the *sum* of decoded pushes converges to the sum
    of the true gradients (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.normal(size=(32, 128)), jnp.float32)
              for _ in range(20)]
    state = compression.init_state({"g": g_true[0]})
    decoded_sum = np.zeros((32, 128), np.float32)
    for g in g_true:
        wire, dec, state = compression.compress_int8({"g": g}, state)
        decoded_sum += np.asarray(dec["g"])
    true_sum = np.asarray(sum(g_true))
    resid = np.asarray(state.residual["g"])
    np.testing.assert_allclose(decoded_sum + resid, true_sum, atol=1e-3)
    # wire format is ~4x smaller than f32
    nbytes = compression.wire_bytes_int8(wire)
    assert nbytes < 32 * 128 * 4 / 3


def test_topk_compression():
    g = {"g": jnp.asarray(np.random.default_rng(1).normal(size=(64,)),
                          jnp.float32)}
    state = compression.init_state(g)
    wire, dec, state = compression.compress_topk(g, state, frac=0.1)
    idx, vals = wire["g"]
    assert idx.shape[0] == 6                       # 10% of 64
    assert float(jnp.count_nonzero(dec["g"])) <= 6


def test_data_pipeline_determinism_and_sharding():
    cfg = smoke_config("qwen1.5-0.5b")
    shape = smoke_shape("train")
    a = make_batch(cfg, shape, PipelineConfig(seed=1, n_shards=2, shard=0), 5)
    b = make_batch(cfg, shape, PipelineConfig(seed=1, n_shards=2, shard=0), 5)
    c = make_batch(cfg, shape, PipelineConfig(seed=1, n_shards=2, shard=1), 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape[0] == shape.global_batch // 2
    # targets are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["targets"][:, :-1])


def test_sparse_dataset_planted_model():
    X, y, w_true = make_sparse_dataset(64, 256, density=0.2, seed=3)
    assert accuracy(w_true, X, y) == 1.0
    assert hinge_loss(np.zeros(64, np.float32), X, y) == 1.0


def test_checkpointer_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "nested": {"b": np.ones(4, np.int32)}}
    for step in (1, 2, 3):
        ck.save(step, tree, blocking=True, extra={"step": step})
    assert ck.steps() == [2, 3]                     # GC kept last 2
    restored, step, extra = ck.restore(tree)
    assert step == 3 and extra["step"] == 3
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["nested"]["b"], tree["nested"]["b"])


def test_checkpointer_async_and_atomic(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": np.zeros((128, 128), np.float32)}
    ck.save(10, tree, blocking=False)
    ck.wait()
    assert ck.latest_step() == 10
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_checkpoint_jax_arrays(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    ck.save(1, tree, blocking=True)
    restored, _, _ = ck.restore(tree)
    assert np.asarray(restored["w"]).dtype == np.asarray(tree["w"]).dtype


def test_global_tier_checkpoint(tmp_path):
    gt = GlobalTier()
    gt.set("a", b"alpha", host="x")
    gt.set("nested/key", bytes(100), host="x")
    path = save_global_tier(gt, str(tmp_path))
    gt2 = GlobalTier()
    n = restore_global_tier(gt2, str(tmp_path))
    assert n == 2
    assert gt2.get("a", host="y") == b"alpha"
    assert gt2.size("nested/key") == 100
