#!/usr/bin/env python3
"""Run the repo-specific state-fabric lint (repro.analysis.lint) over src/.

Part of the tier-1 gate (scripts/tier1.sh runs it before pytest): the
locking/wire-protocol discipline documented in docs/invariants.md is
enforced mechanically, not by review.  Exit 1 on any violation.

Usage:
  python scripts/faasmlint.py                # lint src/ (the gate)
  python scripts/faasmlint.py path [path..]  # lint specific files/trees
  python scripts/faasmlint.py --list-rules
"""
from __future__ import annotations

import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.analysis.lint import RULES, lint_paths    # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="files or directories (default: src/)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule names and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, desc in RULES.items():
            print(f"{name}: {desc}")
        return 0

    paths = args.paths or [os.path.join(_ROOT, "src")]
    violations = lint_paths(paths)
    for v in violations:
        print(v)
    if violations:
        print(f"faasmlint: {len(violations)} violation(s). Fix them, or "
              f"suppress a justified exception with "
              f"'# faasmlint: disable=<rule> -- <why>'.")
        return 1
    print(f"faasmlint: OK ({', '.join(RULES)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
