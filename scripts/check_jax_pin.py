#!/usr/bin/env python3
"""Fail fast when the installed JAX is outside the range supported by
``repro.kernels.common.tpu_compiler_params``.

The Pallas TPU compiler-params class has been renamed across JAX releases
(``TPUCompilerParams`` -> ``CompilerParams``); ``tpu_compiler_params``
resolves whichever exists at call time and silently returns ``None`` when it
can't.  That silence is fine inside a kernel call (defaults apply) but means
the *next* rename only surfaces as a slow drift in kernel behaviour.  This
check — run from ``scripts/tier1.sh`` — turns it into a loud, actionable
failure:

  * JAX older/newer than the explicitly supported range  -> exit 1
  * pltpu importable but neither params class resolvable -> exit 1
  * the ``kernels/state_push`` entry points (the wire codec dispatched from
    ``LocalTier.push_delta(wire="int8")``) fail to import or to quantise a
    trivial delta                                        -> exit 1
  * the ``repro.analysis`` entry points (faasmlint rules, sanitizer lock
    factories and hook installation) fail to resolve — a refactor silently
    orphaning the instrumentation                        -> exit 1
  * the ``repro.telemetry`` plane fails to install/uninstall its hooks or
    the disarmed compile-out (zero ring writes) breaks   -> exit 1
  * the ``repro.overload`` control plane (deadlines, retry budgets,
    breakers) fails to resolve, or its disarmed hooks stop compiling out
    to one pointer compare on a policy-less runtime      -> exit 1
  * the host-native wire codec (``state_push.hostcodec``) fails to
    quantise/conserve for any tier, the int4 nibble packing stops
    round-tripping, or the disarmed ``WireCostModel`` hook stops
    compiling out to one pointer compare                 -> exit 1

Invoked standalone:  python scripts/check_jax_pin.py
"""
from __future__ import annotations

import os
import re
import sys

# The range tpu_compiler_params is known to resolve against (ROADMAP
# "Kernel API pinning").  Bump MAX when a new JAX release is verified.
SUPPORTED_MIN = (0, 4, 26)
SUPPORTED_MAX_EXCLUSIVE = (0, 8, 0)


def _parse(version: str):
    nums = re.findall(r"\d+", version)[:3]
    if not nums:
        return None
    return tuple(int(n) for n in (nums + ["0", "0"])[:3])


def check_analysis_entry_points() -> int:
    """The isolation checker's entry points must resolve and its hooks must
    install/uninstall — run before the jax probes so a jax-less container
    still verifies the instrumentation isn't orphaned."""
    try:
        from repro.analysis import holds_stripe              # noqa: F401
        from repro.analysis.lint import RULES, lint_source
        from repro.analysis import sanitizer
        from repro import cancellation, faults
        from repro.state import kv, local, wire

        assert {"stripe-access", "lock-blocking", "wire-construct",
                "tier-copy", "fault-point", "metric-naming",
                "bounded-queue", "suppress-justify"} <= set(RULES), RULES
        # the fault layer must be disarmed at import and resolve its public
        # surface (the chaos gate in tier1.sh depends on it)
        assert faults.active() is None
        assert faults.point("wire-frame-drop") is False
        assert callable(faults.arm) and callable(faults.disarm)
        assert len(faults.FAULT_POINTS) == 11, faults.FAULT_POINTS
        assert {"queue-flood", "subscriber-stall",
                "deadline-clock-skew"} <= set(faults.FAULT_POINTS)
        # a seeded violation must still be caught
        probe = ("from repro.state.wire import WireFrame\n"
                 "f = WireFrame(wire='exact', numel=0, payload=None)\n")
        vs = lint_source(probe, "probe.py")
        assert any(v.rule == "wire-construct" for v in vs), vs
        # the sanitizer must install its hook state into the fabric modules
        st = sanitizer.enable()
        try:
            assert kv._SAN is st and local._SAN is st and wire._SAN is st
            assert cancellation._SAN_GUARD is not None
            assert isinstance(sanitizer.make_mutex("probe"),
                              sanitizer.SanLock)
        finally:
            sanitizer.disable()
        assert kv._SAN is None and cancellation._SAN_GUARD is None
    except Exception as e:
        print(f"check_jax_pin: FAIL — repro.analysis entry points do not "
              f"resolve: {e!r}\n"
              f"  scripts/faasmlint.py and the FAASM_SANITIZE hooks in "
              f"repro/state + repro/cancellation depend on these; fix "
              f"src/repro/analysis/ before trusting the tier-1 gate.")
        return 1
    return check_telemetry_entry_points()


def check_telemetry_entry_points() -> int:
    """The tracing plane must compile out when disarmed (one pointer
    compare per hook site, zero ring writes) and install/uninstall into
    every instrumented module — the bench_dispatch warm-p99 budget
    depends on the disarmed fast path staying free."""
    try:
        from repro import faults, telemetry
        from repro.analysis import sanitizer
        from repro.core import runtime
        from repro.state import kv, local
        from repro.telemetry import metrics, spans

        # disarmed: every hook slot is None — hook sites cost one compare
        assert not telemetry.enabled()
        for mod in (runtime, kv, local, faults):
            assert mod._TEL is None, mod
        # armed: one Tracer lands in every slot; disarm restores None
        t = telemetry.enable()
        try:
            for mod in (runtime, kv, local, faults):
                assert mod._TEL is t, mod
            assert telemetry.tracer() is t
        finally:
            telemetry.disable()
        for mod in (runtime, kv, local, faults):
            assert mod._TEL is None, mod
        # compile-out: building + exercising a fabric while disarmed must
        # leave a fresh tracer's write counter untouched
        probe = telemetry.spans.Tracer()
        assert probe.writes == 0 and probe.drain() == []
        # the sanitizer installs the drain guard into the spans module
        st = sanitizer.enable()
        try:
            assert spans._SAN_GUARD is not None
        finally:
            sanitizer.disable()
        assert spans._SAN_GUARD is None
        # the registry enforces the naming convention at registration
        try:
            metrics.Registry().counter("not_a_faasm_metric")
        except ValueError:
            pass
        else:
            raise AssertionError("bad metric name accepted")
        assert metrics.valid_name("faasm_tier_net_bytes")
    except Exception as e:
        print(f"check_jax_pin: FAIL — repro.telemetry entry points do not "
              f"resolve: {e!r}\n"
              f"  The span hooks in repro/core + repro/state and the "
              f"metrics registry depend on these; fix src/repro/telemetry/ "
              f"before trusting the tier-1 gate.")
        return 1
    return check_overload_entry_points()


def check_overload_entry_points() -> int:
    """The overload control plane must resolve its public surface and its
    disarmed hooks must compile out to one pointer compare each — the
    warm-path latency budget assumes a runtime built without an
    OverloadPolicy pays nothing for deadlines/shedding/breakers."""
    try:
        from repro import overload
        from repro.core.runtime import BatchTimeout, Call  # noqa: F401

        # return codes are part of the wire contract (serve.py re-exports
        # SHED_RC; scatter_gather keys retry decisions off DEADLINE_RC)
        assert overload.SHED_RC == -2 and overload.DEADLINE_RC == -3
        # deadline algebra: absolute expiry, positive-budget guard
        dl = overload.Deadline.after(60.0)
        assert not dl.expired() and 0.0 < dl.remaining() <= 60.0
        try:
            overload.Deadline.after(0.0)
        except ValueError:
            pass
        else:
            raise AssertionError("zero deadline budget accepted")
        # retry budget: token bucket spends whole tokens, refills by ratio
        rb = overload.RetryBudget(ratio=0.5, burst=2.0, initial=1.0)
        assert rb.try_spend() and not rb.try_spend()
        rb.on_success()
        assert 0.0 < rb.fill_ratio() <= 1.0
        # circuit breaker: failures trip it, allow() then refuses placement
        br = overload.CircuitBreaker(window=4, failure_ratio=0.5,
                                     min_volume=2, reset_timeout_s=60.0)
        assert br.allow() and br.state == br.CLOSED
        br.record(False)
        br.record(False)
        assert br.state == br.OPEN and not br.allow()
        # bounded primitives: queues refuse growth past their depth
        assert overload.bounded_queue(4).maxsize == 4
        cq = overload.CoalescingQueue(depth=2)
        assert cq.depth == 2
        # disarmed compile-out: a policy-less runtime leaves every overload
        # hook slot None and every fresh Call without a deadline, so the
        # hot-path checks are single pointer compares
        assert Call.__dataclass_fields__["deadline"].default is None
        from repro.core.runtime import FaasmRuntime
        rt = FaasmRuntime(n_hosts=1)
        try:
            assert rt.overload is None
            assert rt._retry_budget is None and rt._breakers is None
        finally:
            rt.shutdown()
        import inspect
        sig = inspect.signature(overload.OverloadPolicy)
        assert "max_queue_depth" in sig.parameters
    except Exception as e:
        print(f"check_jax_pin: FAIL — repro.overload entry points do not "
              f"resolve: {e!r}\n"
              f"  The admission/deadline/breaker hooks in repro/core/runtime "
              f"and the serve.py --max-queue-depth/--default-deadline-ms "
              f"flags depend on these; fix src/repro/overload.py before "
              f"trusting the tier-1 gate.")
        return 1
    return check_wire_entry_points()


def check_wire_entry_points() -> int:
    """The host-native wire codec and cost model must resolve *without*
    importing jax — ``LocalTier.push_delta`` takes the hostcodec fast path
    on every host-resident push, so a drift here is a data-plane outage,
    not a kernel nicety.  Runs before the jax probes on purpose: importing
    ``state_push.hostcodec`` must not pull in the device runtime."""
    try:
        import numpy as np
        from repro.kernels.state_push import hostcodec
        assert "jax" not in sys.modules, \
            "hostcodec import pulled in jax — host fast path is no longer " \
            "dispatch-free"

        # fused quantise: roundtrip + exact residual conservation per tier
        rng = np.random.default_rng(7)
        eff = rng.standard_normal(130).astype(np.float32)
        base = rng.standard_normal(130).astype(np.float32)
        delta = eff - base
        for qmax in (127, 7):
            q, s, n, resid = hostcodec.encode_quant(eff, base, qmax=qmax)
            assert n == 130 and q.shape == (2, 128) and s.shape == (2, 1)
            deq = hostcodec.decode_rows(q, s, n)
            assert np.abs(q).max() <= qmax
            assert np.allclose(deq + resid, delta, atol=1e-6), qmax
        # int4 nibble packing round-trips the full [-7, 7] code range
        codes = np.arange(-7, 8, dtype=np.int8)
        qz = np.zeros((1, 128), np.int8)
        qz[0, :15] = codes
        assert np.array_equal(hostcodec.unpack_int4(hostcodec.pack_int4(qz)),
                              qz)
        if hostcodec.fp8_available():
            q, s, n, resid = hostcodec.encode_fp8(eff, base)
            deq = hostcodec.decode_rows(q, s, n)
            assert not np.isnan(deq).any()
            assert np.allclose(deq + resid, delta, atol=1e-6)

        # wire layer: every advertised tier resolves a codec; the cost-model
        # hook is disarmed at import (one pointer compare per push) and the
        # enable/disable roundtrip restores that state
        from repro.state import wire
        assert wire._COST is None, "cost model armed at import"
        for w in wire.available_wires():
            assert wire.get_codec(w).name == w
        assert {"exact", "int8", "int4"} <= set(wire.available_wires())
        m = wire.enable_cost_model()
        try:
            assert wire._COST is m and wire.cost_model() is m
            assert m.predict("int8", 1 << 16) is None   # no evidence yet
            m.observe("int8", 1 << 16, 50_000, wall_ns=120_000)
            assert m.predict("int8", 1 << 16) is not None
        finally:
            wire.disable_cost_model()
        assert wire._COST is None
        # cost-mode policy: selects a sane wire for an f32 value
        pol = wire.WirePolicy(tiers=("int8", "int4"))
        w0 = pol.select(1 << 20, np.float32)
        assert w0 in wire.WIRES, w0
    except Exception as e:
        print(f"check_jax_pin: FAIL — wire codec entry points do not "
              f"resolve: {e!r}\n"
              f"  LocalTier.push_delta's host fast path, the int4/fp8 tiers "
              f"and WirePolicy's cost mode depend on these; fix "
              f"src/repro/kernels/state_push/hostcodec.py and "
              f"src/repro/state/wire.py before trusting the tier-1 gate.")
        return 1
    return 0


def main() -> int:
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    rc = check_analysis_entry_points()
    if rc:
        return rc

    try:
        import jax
    except ImportError as e:
        print(f"check_jax_pin: jax not importable ({e}); kernels will fall "
              "back to XLA — skipping pin check")
        return 0

    ver = _parse(jax.__version__)
    if ver is None:
        print(f"check_jax_pin: FAIL — cannot parse jax version "
              f"{jax.__version__!r}")
        return 1
    if not (SUPPORTED_MIN <= ver < SUPPORTED_MAX_EXCLUSIVE):
        lo = ".".join(map(str, SUPPORTED_MIN))
        hi = ".".join(map(str, SUPPORTED_MAX_EXCLUSIVE))
        print(f"check_jax_pin: FAIL — jax {jax.__version__} outside the "
              f"supported range [{lo}, {hi}) for tpu_compiler_params.\n"
              f"  Verify pltpu.CompilerParams/TPUCompilerParams still "
              f"resolve in src/repro/kernels/common.py, run the slow kernel "
              f"matrix (pytest -m slow), then bump the pin here.")
        return 1

    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError as e:
        print(f"check_jax_pin: pallas TPU backend not importable ({e}); "
              "interpret-mode tests cover the kernels — OK")
        return 0

    cls = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        print("check_jax_pin: FAIL — jax.experimental.pallas.tpu exposes "
              "neither CompilerParams nor TPUCompilerParams (another "
              "rename?).  Update tpu_compiler_params() in "
              "src/repro/kernels/common.py and this pin.")
        return 1

    # the quantised wire codec is dispatched from the state tier on every
    # int8 push_delta, delta pull and peer broadcast: make a JAX drift there
    # loud, not a slow failure at transfer time.  Runs after the pltpu
    # probes above so a pallas rename hits its targeted diagnostic first,
    # not this generic one.
    try:
        from repro.kernels.state_push import (apply_pull, dequantize,
                                              encode_pull, quantize_delta)
        from repro.kernels.state_push.kernel import (       # noqa: F401
            apply_delta_pallas, quantize_delta_pallas)
        import numpy as np
        q, s, n = quantize_delta(np.ones(4, np.float32),
                                 np.zeros(4, np.float32), backend="xla")
        deq = np.asarray(dequantize(q, s, n))
        assert n == 4 and abs(float(deq[0]) - 1.0) < 1e-2, (n, deq)
        # pull/broadcast direction: encode a catch-up delta and apply it to
        # a replica value (GlobalTier.pull_wire / LocalTier broadcast apply)
        q, s, n = encode_pull(np.full(4, 2.0, np.float32),
                              np.zeros(4, np.float32), backend="xla")
        got = np.asarray(apply_pull(np.ones(4, np.float32), q, s,
                                    backend="xla"))
        assert abs(float(got[0]) - 3.0) < 1e-2, got
    except Exception as e:
        print(f"check_jax_pin: FAIL — state_push kernel entry points do not "
              f"resolve under jax {jax.__version__}: {e!r}\n"
              f"  The wire fabric (LocalTier.push_delta/pull(wire='int8'), "
              f"GlobalTier.pull_wire, peer broadcast) dispatches these; fix "
              f"src/repro/kernels/state_push/ before trusting the tier.")
        return 1

    print(f"check_jax_pin: OK — jax {jax.__version__}, params class "
          f"pltpu.{cls.__name__}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
