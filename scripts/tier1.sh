#!/usr/bin/env bash
# Fast tier-1 gate: the ROADMAP verify command minus the slow interpret-mode
# kernel matrix (run `pytest -m slow` for the full kernel sweep).  The
# quantised-push and wire-fabric suites (tests/test_quantized_push.py,
# tests/test_wire_fabric.py — xla rows) run here; their pallas_interpret
# parametrisations ride in the slow sweep (conftest auto-marks them).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python scripts/check_jax_pin.py
python scripts/faasmlint.py
# Chaos smoke: the three fixed-seed fault-matrix storms under the
# sanitizer's attempt-fence shadow (the wider seeded sweep is slow-marked;
# see docs/fault_model.md), one traced chaos seed asserting the armed
# telemetry plane exports a well-formed Perfetto trace under FAASM_SANITIZE
# (docs/observability.md), and the overload-plane queue-flood smoke
# (bounded admission refuses, the dispatcher spills, nothing sheds — see
# docs/fault_model.md "Overload model").
FAASM_SANITIZE=1 python -m pytest -x -q -p no:cacheprovider \
    tests/test_chaos.py tests/test_telemetry.py -k smoke
exec python -m pytest -x -q -p no:cacheprovider -m "not slow" "$@"
