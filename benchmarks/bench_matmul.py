"""Paper Fig. 8: chained divide-and-conquer matmul — duration + transfer."""
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import FaasmRuntime, FunctionDef, chain, await_all
from repro.state.ddo import MatrixReadOnly


def run_matmul(n: int, splits: int, mode: str) -> dict:
    sys.path.insert(0, "examples")
    rng = np.random.default_rng(0)
    B = rng.standard_normal((n, n)).astype(np.float32)
    C = rng.standard_normal((n, n)).astype(np.float32)
    blk = n // splits
    rt = FaasmRuntime(n_hosts=2, capacity=4, isolation=mode)
    try:
        MatrixReadOnly.create(rt.global_tier, "B", B)
        MatrixReadOnly.create(rt.global_tier, "C", C)

        def multiply_block(api):
            i, j = np.frombuffer(api.read_call_input(), np.int32)
            c_cols = MatrixReadOnly(api, "C").columns(j * blk, (j + 1) * blk)
            b_full = np.frombuffer(bytes(api.get_state("B", writable=False)),
                                   np.float32).reshape(n, n, order="F")
            out = b_full[i * blk:(i + 1) * blk, :] @ c_cols
            api.runtime.global_tier.set(f"out/{int(i)}_{int(j)}", out.tobytes(),
                                        host=api.host.id)
            return 0

        def matmul_main(api):
            calls = [np.asarray([i, j], np.int32).tobytes()
                     for i in range(splits) for j in range(splits)]
            cids = chain(api, "multiply_block", calls)
            assert all(r == 0 for r in await_all(api, cids))
            return 0

        rt.upload(FunctionDef("multiply_block", multiply_block,
                              memory_limit=1 << 26))
        rt.upload(FunctionDef("matmul_main", matmul_main, memory_limit=1 << 26))
        rt.global_tier.reset_metrics()
        t0 = time.perf_counter()
        cid = rt.invoke("matmul_main")
        rc = rt.wait(cid, timeout=300)
        wall = time.perf_counter() - t0
        assert rc == 0, rt.call(cid).error
        return {"wall_s": wall, "transfer_mb": rt.transfer_bytes() / 1e6}
    finally:
        rt.shutdown()


def main() -> None:
    for n in (128, 256):
        for mode in ("faaslet", "container"):
            r = run_matmul(n, 2, mode)
            emit(f"fig8_matmul/{mode}/n{n}/wall", r["wall_s"] * 1e6,
                 f"transfer={r['transfer_mb']:.2f}MB")


if __name__ == "__main__":
    main()
