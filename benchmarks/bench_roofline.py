"""Roofline report: reads the dry-run artifacts and prints the per-cell table
(compute / memory / collective terms, dominant bottleneck, useful-FLOPs)."""
import glob
import json
import os

from benchmarks.common import emit

ART = "artifacts/dryrun"


def load_cells(mesh: str = "pod16x16", tag: str | None = None):
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        name = os.path.basename(path)[:-5]
        is_tagged = "__" in name.split("__", 2)[-1] if name.count("__") >= 2 else False
        if tag is None and name.count("__") >= 2:
            continue                      # skip perf-variant artifacts
        if tag is not None and not name.endswith("__" + tag):
            continue
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def main() -> None:
    cells = load_cells("pod16x16")
    if not cells:
        print("# no dry-run artifacts found; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun")
        return
    for rec in cells:
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        dom_time = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        emit(f"roofline/{rec['arch']}/{rec['shape']}", dom_time * 1e6,
             f"dom={r['dominant']} frac={r['roofline_fraction']:.3f} "
             f"useful={r['useful_flops_ratio']:.3f} "
             f"peakGiB={rec['memory']['peak_bytes'] / 2**30:.1f}")


if __name__ == "__main__":
    main()
