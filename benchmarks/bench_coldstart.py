"""Paper Tab. 3 + Fig. 10: cold-start footprint and churn.

Measures initialisation latency and memory footprint of Faaslets vs
Proto-Faaslet restore vs the container-sim baseline, and sustained cold-start
churn (instances created per second)."""
import time

import numpy as np

from benchmarks.common import emit
from repro.core import (CONTAINER_OVERHEAD_BYTES, FAASLET_OVERHEAD_BYTES,
                        Faaslet, ProtoFaaslet)


def _noop_init(f: Faaslet):
    f.brk(64 * 1024)
    f.write(0, b"x" * 1024)


def main() -> None:
    # --- init latency: fresh Faaslet vs Proto restore (Tab. 3) ------------------
    n = 200
    t0 = time.perf_counter()
    for _ in range(n):
        f = Faaslet("bench", "h0")
        _noop_init(f)
    fresh_us = (time.perf_counter() - t0) / n * 1e6

    f = Faaslet("bench", "h0")
    _noop_init(f)
    proto = ProtoFaaslet.capture(f)
    t0 = time.perf_counter()
    for _ in range(n):
        proto.restore("h0")
    restore_us = (time.perf_counter() - t0) / n * 1e6

    # container-sim: full re-init incl. a fresh private state copy (data ship)
    state = np.zeros(1 << 20, np.uint8)            # 1 MB "image layer"
    t0 = time.perf_counter()
    for _ in range(n):
        g = Faaslet("bench", "h0")
        _noop_init(g)
        _ = state.copy()
    container_us = (time.perf_counter() - t0) / n * 1e6

    emit("tab3_init/faaslet", fresh_us, "fresh faaslet init")
    emit("tab3_init/proto_restore", restore_us,
         f"{fresh_us / max(restore_us, 1e-9):.1f}x faster than fresh")
    emit("tab3_init/container_sim", container_us,
         f"{container_us / max(restore_us, 1e-9):.0f}x slower than proto")

    # --- memory footprint (Tab. 3) -------------------------------------------------
    emit("tab3_mem/faaslet_kb", FAASLET_OVERHEAD_BYTES / 1024, "per instance")
    emit("tab3_mem/container_kb", CONTAINER_OVERHEAD_BYTES / 1024,
         f"{CONTAINER_OVERHEAD_BYTES / FAASLET_OVERHEAD_BYTES:.0f}x faaslet")
    emit("tab3_mem/proto_snapshot_kb", proto.size_bytes() / 1024,
         "snapshot transport size")

    # --- churn (Fig. 10): sustained instance creations per second ----------------
    t0 = time.perf_counter()
    count = 0
    while time.perf_counter() - t0 < 1.0:
        proto.restore("h0")
        count += 1
    emit("fig10_churn/proto_per_s", 1e6 / count, f"{count} restores/s")
    t0 = time.perf_counter()
    count = 0
    while time.perf_counter() - t0 < 1.0:
        g = Faaslet("bench", "h0")
        _noop_init(g)
        count += 1
    emit("fig10_churn/fresh_per_s", 1e6 / count, f"{count} inits/s")


if __name__ == "__main__":
    main()
